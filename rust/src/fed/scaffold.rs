//! Scaffold (Karimireddy et al., 2020) — the paper's strongest
//! non-accelerated baseline (§4.7, Figure 9) — as a [`FedAlgorithm`].
//!
//! Client i keeps a control variate c_i (stored in `ClientState::h`);
//! the server keeps the global variate c. Local step:
//!     x ← x − γ·(∇f_i(x) − c_i + c)
//! After E steps (option II of the paper):
//!     c_i⁺ = c_i − c + (x_server − x_i)/(E·γ)
//!     uplink Δx = x_i − x_server and Δc = c_i⁺ − c_i
//!     server: x += mean(Δx);  c += (|S|/n)·mean(Δc)
//! Each direction carries TWO d-vector [`Message`]s per client — Scaffold's
//! well-known 2× communication overhead, which the bits-axis plots make
//! visible. By default both are dense; configured
//! `compress_up`/`compress_down` pipelines apply to *both* vectors of the
//! respective direction (x then c downlink; Δx then Δc uplink, a fixed
//! order). Stateful `ef(...)` pipelines are rejected at setup: one
//! residual memory cannot serve two interleaved streams (see
//! [`crate::compress::Pipeline::has_state`]).

use super::algorithm::{AlgoState, FedAlgorithm, RoundCtx, RoundOutcome, UplinkKind};
use super::message::{Message, SERVER};
use super::{Federation, RunConfig};
use crate::tensor;
use crate::util::rng::Rng;

/// Scaffold with option-II control-variate updates (see module docs).
pub struct Scaffold {
    c_global: Vec<f32>,
    /// Server-side randomness for a stochastic downlink codec.
    server_rng: Rng,
}

impl Scaffold {
    /// A fresh Scaffold (c and every c_i start at zero in `setup`).
    pub fn new() -> Scaffold {
        Scaffold {
            c_global: Vec::new(),
            server_rng: Rng::seed_from_u64(0),
        }
    }
}

impl Default for Scaffold {
    fn default() -> Self {
        Self::new()
    }
}

impl FedAlgorithm for Scaffold {
    fn name(&self) -> String {
        "scaffold".to_string()
    }

    fn log_name(&self, fed: &Federation, cfg: &RunConfig) -> String {
        format!("scaffold-{}-a{}", fed.model.name(), cfg.dirichlet_alpha)
    }

    fn log_meta(&self, cfg: &RunConfig) -> Vec<(String, String)> {
        vec![
            ("algorithm".into(), "scaffold".into()),
            ("gamma".into(), cfg.gamma.to_string()),
            ("local_steps".into(), cfg.local_steps.to_string()),
            ("alpha".into(), cfg.dirichlet_alpha.to_string()),
        ]
    }

    fn setup(&mut self, fed: &mut Federation, cfg: &RunConfig) {
        // Scaffold multiplexes two logical streams over each link (x/c
        // down, Δx/Δc up), but a stateful pipeline owns exactly one
        // residual memory per link — error feedback would bleed model mass
        // into the control-variate stream and vice versa. Reject rather
        // than silently corrupt (stateless chains/schedules are fine).
        assert!(
            !cfg.uplink_spec().has_state() && !cfg.downlink_spec().has_state(),
            "scaffold ships two vectors per direction; stateful ef(...) pipelines \
             need per-stream memory — use a stateless compress_up/compress_down spec"
        );
        self.c_global = vec![0.0f32; fed.x.len()];
        self.server_rng = fed.rng.derive(0x5CAF_F01D);
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundOutcome {
        let cfg = ctx.cfg;
        let round = ctx.round;
        let inv_e_gamma = 1.0 / (cfg.local_steps as f32 * cfg.gamma);

        // Downlink: x and c (2 vectors, through the downlink pipeline in a
        // fixed x-then-c order). The transport pins one availability
        // decision per client per round, so both broadcasts see the same
        // participant set; both target the full sampled set so server
        // egress is charged 2x per sampled client even for clients that
        // turn out to be unreachable.
        let x_msg = Message::through(
            round,
            SERVER,
            &ctx.fed.x,
            &mut ctx.fed.downlink,
            &mut self.server_rng,
        );
        let participants = ctx.transport.broadcast(&ctx.sampled, &x_msg);
        let c_msg = Message::through(
            round,
            SERVER,
            &self.c_global,
            &mut ctx.fed.downlink,
            &mut self.server_rng,
        );
        ctx.transport.broadcast(&ctx.sampled, &c_msg);
        let x = x_msg.to_dense();
        let c_ref = c_msg.to_dense();

        let trainer = ctx.fed.trainer.clone();
        let gamma = cfg.gamma;
        let local_steps = cfg.local_steps;
        // Returns (Δx, Δc, c_i⁺, loss_sum); the c_i refresh is committed
        // only once the uplink is known delivered, so a lossy transport
        // cannot advance a client variate the server never saw.
        let d = x.len();
        let results: Vec<(Message, Message, Vec<f32>, f64)> =
            ctx.map_clients_ws(&participants, |ci, state, ws| {
                let mut xi = ws.take_xi_primed(&x);
                let mut loss_sum = 0.0f64;
                // Effective control-variate correction: −c_i + c ⇒ pass
                // h = c_i − c to the Scaffnew-form step x − γ(g − h).
                let mut h_eff = vec![0.0f32; d];
                tensor::sub(&state.h, &c_ref, &mut h_eff);
                // Empty shards (million-client populations smaller than
                // the dataset leave most clients without examples) skip
                // local training: xi stays at the broadcast model, so
                // Δx = 0 and the option-II refresh stays well-defined.
                if !state.loader.is_empty() {
                    for _ in 0..local_steps {
                        let batch = state.loader.next_batch();
                        let loss = trainer.train_step_into(&xi[..d], &h_eff, &batch, gamma, ws);
                        std::mem::swap(&mut xi, &mut ws.step);
                        loss_sum += loss as f64;
                    }
                }
                // Option II variate refresh.
                let mut c_new = vec![0.0f32; d];
                for j in 0..d {
                    c_new[j] = state.h[j] - c_ref[j] + (x[j] - xi[j]) * inv_e_gamma;
                }
                let mut dx = vec![0.0f32; d];
                tensor::sub(&xi[..d], &x, &mut dx);
                let mut dc = vec![0.0f32; d];
                tensor::sub(&c_new, &state.h, &mut dc);
                ws.put_xi(xi);
                // Uplink pipeline, fixed Δx-then-Δc order per client.
                let dx_msg =
                    Message::through(round, ci as u32, &dx, &mut state.up, &mut state.rng);
                let dc_msg =
                    Message::through(round, ci as u32, &dc, &mut state.up, &mut state.rng);
                (dx_msg, dc_msg, c_new, loss_sum)
            });

        let loss_sum: f64 = results.iter().map(|(_, _, _, l)| l).sum();
        let n_trained = results.len();
        let mut deltas: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_trained);
        for ((dx_msg, dc_msg, c_new, _), &ci) in results.into_iter().zip(&participants) {
            let dx = ctx.transport.uplink(ci, dx_msg);
            let dc = ctx.transport.uplink(ci, dc_msg);
            if let (Some(dx), Some(dc)) = (dx, dc) {
                ctx.fed.clients[ci].lock().unwrap().h = c_new;
                deltas.push((dx.to_dense(), dc.to_dense()));
            }
        }

        // Server updates.
        let m = deltas.len().max(1) as f32;
        let scale_c = m / cfg.n_clients as f32 / m; // (|S|/n)·(1/|S|)
        for (dx, dc) in &deltas {
            tensor::axpy(1.0 / m, dx, &mut ctx.fed.x);
            tensor::axpy(scale_c, dc, &mut self.c_global);
        }

        RoundOutcome {
            local_steps: cfg.local_steps,
            train_loss: loss_sum / (n_trained * cfg.local_steps).max(1) as f64,
        }
    }

    fn uplink_kind(&self) -> UplinkKind {
        // The first uplink stream is Δx — already an additive delta, so a
        // straggler's buffered contribution is the decoded payload itself
        // (its Δc stream is forfeited, like any undelivered update).
        UplinkKind::Delta
    }

    fn save_state(&self) -> AlgoState {
        // Cross-round server state: the global variate c and the downlink
        // codec stream (per-client c_i live in `ClientState::h`, which the
        // federation snapshot covers).
        let mut state = AlgoState::new();
        state.push_vec("c_global", &self.c_global);
        state.push_rng("server_rng", &self.server_rng);
        state
    }

    fn restore_state(&mut self, mut state: AlgoState) -> Result<(), String> {
        self.c_global = state.take_vec("c_global")?;
        self.server_rng = state.take_rng("server_rng")?;
        state.finish()
    }
}
