//! The micro-kernel vocabulary a native compute plane plugs into the
//! model walks.
//!
//! [`MicroKernels`] is the *inner* interface of the backend layer: the
//! handful of dense-linear-algebra primitives `Model::forward_into_with` /
//! `grad_into_with` call per layer, plus the optimizer step and an
//! activation-storage hook. The outer interface — trainer construction,
//! codec verbs, registry — is the [`super::Backend`] trait; every native
//! `Backend` is just a named pair of (key, `&'static dyn MicroKernels`).
//!
//! Three implementations ship:
//! * [`ScalarKernels`] — delegates 1:1 to the canonical loops in
//!   [`crate::model::ops`] / [`crate::tensor`]. The `native` plane. All
//!   golden and identity pins are defined against this path.
//! * [`SimdKernels`] — routes through the AVX2 mirrors in
//!   [`super::simd`], which are bit-identical to scalar by construction
//!   (same accumulation order, no FMA) and fall back to the scalar loops
//!   when AVX2 is absent. The `native-simd` plane.
//! * [`Bf16Kernels`] — wraps another kernel set and rounds stored hidden
//!   activations onto the bf16 grid after every non-logit layer via
//!   [`MicroKernels::store_activations`]. The `native-bf16` plane:
//!   numerics deliberately differ from f32 (bounded by the tolerance
//!   goldens in `tests/backend_identity.rs`), so it is opt-in only and
//!   never selected by `auto`.

use crate::model::ops;

/// Object-safe micro-kernel set used by the native model walks.
///
/// Implementations MUST be either bit-identical to [`ScalarKernels`]
/// (same IEEE operation sequence per output element) or clearly documented
/// as a different numerics mode with its own tolerance pins — nothing in
/// between. The bit-identity contract is what lets `native`-family
/// backends share the repo's seed-level reproducibility goldens.
pub trait MicroKernels: std::fmt::Debug + Send + Sync {
    /// Short identifier used in logs and Debug output.
    fn name(&self) -> &'static str;

    /// C[m×n] += A[m×k]·B[k×n].
    fn matmul_acc(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize);

    /// C = A·B with fused `+bias[col]` (+ optional ReLU) epilogue; the
    /// Dense-layer forward.
    #[allow(clippy::too_many_arguments)]
    fn matmul_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    );

    /// C[m×n] = Aᵀ·B with A stored k×m; the weight-gradient orientation.
    fn matmul_at_b(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize);

    /// C[m×n] = A·Bᵀ with B stored n×k; the input-gradient orientation.
    fn matmul_a_bt(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize);

    /// `matmul_a_bt` with fused `+bias[row]` (+ optional ReLU) epilogue;
    /// the Conv-layer forward over im2col panels.
    #[allow(clippy::too_many_arguments)]
    fn matmul_a_bt_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    );

    /// Optimizer verb: `out = x − γ·(g − h)` (the Scaffnew
    /// control-variate step). Elementwise, so every implementation is
    /// bit-identical; overriding is purely a throughput decision.
    fn apply_step(&self, x: &[f32], g: &[f32], h: &[f32], gamma: f32, out: &mut [f32]) {
        crate::tensor::sgd_control_variate_step(x, g, h, gamma, out);
    }

    /// Storage hook applied to each *hidden* activation buffer right after
    /// a layer writes it (logits are never passed through). The default is
    /// the identity (full-f32 storage); [`Bf16Kernels`] overrides it to
    /// round onto the bf16 grid.
    fn store_activations(&self, _acts: &mut [f32]) {}
}

/// The canonical scalar plane (`native`): thin delegation to
/// [`crate::model::ops`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarKernels;

impl MicroKernels for ScalarKernels {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul_acc(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        ops::matmul_acc(a, b, c, m, k, n);
    }

    fn matmul_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        ops::matmul_bias_act(a, b, bias, c, m, k, n, relu);
    }

    fn matmul_at_b(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        ops::matmul_at_b(a, b, c, m, k, n);
    }

    fn matmul_a_bt(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        ops::matmul_a_bt(a, b, c, m, k, n);
    }

    fn matmul_a_bt_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        ops::matmul_a_bt_bias_act(a, b, bias, c, m, k, n, relu);
    }
}

/// The wide plane (`native-simd`): AVX2 mirrors of the scalar kernels,
/// bit-identical by construction (see [`super::simd`] module docs for the
/// per-kernel argument). Falls back to scalar loops at runtime when AVX2
/// is unavailable, so it is safe to select unconditionally.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimdKernels;

impl MicroKernels for SimdKernels {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn matmul_acc(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        super::simd::matmul_acc(a, b, c, m, k, n);
    }

    fn matmul_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        super::simd::matmul_bias_act(a, b, bias, c, m, k, n, relu);
    }

    fn matmul_at_b(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        super::simd::matmul_at_b(a, b, c, m, k, n);
    }

    fn matmul_a_bt(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        super::simd::matmul_a_bt(a, b, c, m, k, n);
    }

    fn matmul_a_bt_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        super::simd::matmul_a_bt_bias_act(a, b, bias, c, m, k, n, relu);
    }

    fn apply_step(&self, x: &[f32], g: &[f32], h: &[f32], gamma: f32, out: &mut [f32]) {
        super::simd::sgd_control_variate_step(x, g, h, gamma, out);
    }
}

/// The bf16-storage plane (`native-bf16`): compute stays f32 inside each
/// kernel, but every hidden activation buffer is rounded onto the bf16
/// grid before the next layer (and the backward pass) reads it — the
/// software model of an accelerator holding activations in bf16. Wraps an
/// inner kernel set for the arithmetic itself; we pin it over
/// [`ScalarKernels`] so its tolerance goldens are independent of the host's
/// AVX2 support.
#[derive(Debug, Clone, Copy)]
pub struct Bf16Kernels {
    /// Kernel set performing the actual f32 arithmetic.
    pub inner: &'static dyn MicroKernels,
}

impl MicroKernels for Bf16Kernels {
    fn name(&self) -> &'static str {
        "bf16-storage"
    }

    fn matmul_acc(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        self.inner.matmul_acc(a, b, c, m, k, n);
    }

    fn matmul_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        self.inner.matmul_bias_act(a, b, bias, c, m, k, n, relu);
    }

    fn matmul_at_b(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        self.inner.matmul_at_b(a, b, c, m, k, n);
    }

    fn matmul_a_bt(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        self.inner.matmul_a_bt(a, b, c, m, k, n);
    }

    fn matmul_a_bt_bias_act(
        &self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        self.inner.matmul_a_bt_bias_act(a, b, bias, c, m, k, n, relu);
    }

    fn apply_step(&self, x: &[f32], g: &[f32], h: &[f32], gamma: f32, out: &mut [f32]) {
        self.inner.apply_step(x, g, h, gamma, out);
    }

    fn store_activations(&self, acts: &mut [f32]) {
        super::bf16::round_slice_bf16(acts);
    }
}

/// Shared instance backing the `native` plane.
pub static SCALAR: ScalarKernels = ScalarKernels;
/// Shared instance backing the `native-simd` plane.
pub static SIMD: SimdKernels = SimdKernels;
/// Shared instance backing the `native-bf16` plane (bf16 storage over
/// scalar arithmetic).
pub static BF16: Bf16Kernels = Bf16Kernels { inner: &SCALAR };

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_kernels_delegate_to_ops_bitwise() {
        let mut rng = Rng::seed_from_u64(21);
        let (m, k, n) = (5, 9, 17);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut c0 = vec![0.0; m * n];
        let mut c1 = vec![0.0; m * n];
        ops::matmul_bias_act(&a, &b, &bias, &mut c0, m, k, n, true);
        SCALAR.matmul_bias_act(&a, &b, &bias, &mut c1, m, k, n, true);
        assert!(c0.iter().zip(&c1).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn simd_kernels_match_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(22);
        let (m, k, n) = (6, 13, 31); // remainders on every axis
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut c0 = vec![0.0; m * n];
        let mut c1 = vec![0.0; m * n];
        SCALAR.matmul_a_bt_bias_act(&a, &bt, &bias, &mut c0, m, k, n, true);
        SIMD.matmul_a_bt_bias_act(&a, &bt, &bias, &mut c1, m, k, n, true);
        assert!(c0.iter().zip(&c1).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn bf16_storage_hook_rounds_only_on_bf16_plane() {
        let mut acts = vec![1.0f32 + 1.0 / 512.0; 9]; // off the bf16 grid
        let copy = acts.clone();
        SCALAR.store_activations(&mut acts);
        assert_eq!(acts, copy, "scalar hook must be the identity");
        SIMD.store_activations(&mut acts);
        assert_eq!(acts, copy, "simd hook must be the identity");
        BF16.store_activations(&mut acts);
        for v in &acts {
            assert_eq!(*v, 1.0, "ties round to even on the bf16 grid");
        }
    }

    #[test]
    fn apply_step_is_bit_identical_across_planes() {
        let mut rng = Rng::seed_from_u64(23);
        let d = 1001;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let g: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut o0 = vec![0.0; d];
        let mut o1 = vec![0.0; d];
        let mut o2 = vec![0.0; d];
        SCALAR.apply_step(&x, &g, &h, 0.21, &mut o0);
        SIMD.apply_step(&x, &g, &h, 0.21, &mut o1);
        BF16.apply_step(&x, &g, &h, 0.21, &mut o2);
        assert!(o0.iter().zip(&o1).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(o0.iter().zip(&o2).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
