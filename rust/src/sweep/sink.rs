//! Structured result sink for sweeps: per-round JSONL and a per-run
//! summary CSV, both schema-versioned and **bit-deterministic** — the same
//! sweep produces byte-identical files at any `--threads` setting.
//!
//! # Layout
//!
//! ```text
//! <out>/<sweep-name>/
//!   summary.csv              one row per run (header below), canonical order
//!   rounds/<run_id>.jsonl    one JSON object per communication round
//! ```
//!
//! # Summary CSV schema (v5)
//!
//! ```text
//! schema,run_id,sweep,algo,dataset,model,transport,backend,rounds,
//! local_steps,p,alpha,gamma,seed,train_n,test_n,clients,sampled,
//! batch_size,eval_batch,eval_every,tau,data_dir,compress_up,
//! compress_down,scenario,faults,best_accuracy,final_accuracy,
//! final_train_loss,total_uplink_bits,total_downlink_bits,total_cost,
//! total_sim_secs,dropped_clients,stale_updates,churned_clients,
//! corrupt_frames,retransmits,backoff_secs,aborted_rounds
//! ```
//!
//! v2 appended the `compress_up`/`compress_down` columns to the
//! configuration prefix (they are result-affecting); v3 added the
//! `scenario` axis (`fed::sim` round runtime) to the prefix and the
//! `stale_updates`/`churned_clients` metric columns; v4 added the
//! `faults` axis ([`crate::fed::faults`] fault-injection plane) to the
//! prefix and the `corrupt_frames`/`retransmits`/`backoff_secs`/
//! `aborted_rounds` recovery columns; v5 renamed the `trainer` column to
//! `backend` in place (the [`crate::backend`] registry key — same
//! position, same column count, so positional consumers are unaffected)
//! and records the per-unit *effective* backend rather than the sweep-wide
//! CLI flag; the sweep-*file* schema is versioned separately and stayed at
//! [`crate::sweep::spec::SCHEMA_VERSION`] = 1.
//!
//! The columns through `data_dir` are the run's complete *result-affecting*
//! configuration — every `RunConfig` field except `threads` (results are
//! bit-invariant to worker counts), plus the algorithm/transport specs and
//! the compute-plane backend (`--backend` / the `backends` sweep axis) —
//! and form the `--resume` match
//! key (see [`summary_key`]); the rest are the run's result metrics. Fields
//! never contain commas except possibly a pathological `data_dir` path —
//! avoid commas in data directories.
//!
//! `best_accuracy`/`final_accuracy` are empty when the run never evaluated.
//! Floats use Rust's shortest-roundtrip formatting (lossless). During a
//! sweep, rows are appended in completion order (crash-resumable); on
//! completion the file is rewritten in canonical expansion order.
//!
//! # Round JSONL schema
//!
//! One compact JSON object per round with keys `schema`, `run`, `round`,
//! `local_steps`, `train_loss`, `test_loss`/`test_accuracy` (present only
//! on evaluation rounds), `uplink_bits`, `downlink_bits`,
//! `cum_uplink_bits`, `cum_downlink_bits`, `total_cost`, `sim_secs`,
//! `cum_sim_secs`, `dropped_clients`, `stale_updates`, `churned_clients`
//! (the last five only when a simulated transport or scenario produced
//! them), plus `corrupt_frames`, `retransmits`, `dup_frames`,
//! `backoff_secs`, `aborted` (only when the fault plane produced them).
//! Keys serialize in lexicographic order.
//!
//! Wall-clock time is deliberately **excluded** from both formats (it would
//! break bit-reproducibility); per-run wall time goes to the log output.
//! `tests/sweep_engine.rs` pins both schemas golden.

use super::spec::RunUnit;
use crate::metrics::{MetricsLog, RoundRecord};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Version of the *result* schema (summary CSV + round JSONL): stamped
/// into every row/line and matched by `--resume`, so results written under
/// an older schema are never silently reused.
pub const RESULT_SCHEMA: i64 = 5;

/// The pinned v5 summary header (also the golden-test reference).
pub const SUMMARY_HEADER: &str = "schema,run_id,sweep,algo,dataset,model,transport,backend,rounds,local_steps,p,alpha,gamma,seed,train_n,test_n,clients,sampled,batch_size,eval_batch,eval_every,tau,data_dir,compress_up,compress_down,scenario,faults,best_accuracy,final_accuracy,final_train_loss,total_uplink_bits,total_downlink_bits,total_cost,total_sim_secs,dropped_clients,stale_updates,churned_clients,corrupt_frames,retransmits,backoff_secs,aborted_rounds";

/// `<out>/<sweep>/summary.csv`.
pub fn summary_path(sweep_dir: &Path) -> PathBuf {
    sweep_dir.join("summary.csv")
}

/// `<out>/<sweep>/rounds/<run_id>.jsonl`.
pub fn rounds_path(sweep_dir: &Path, run_id: &str) -> PathBuf {
    sweep_dir.join("rounds").join(format!("{run_id}.jsonl"))
}

fn opt_f64(v: Option<f64>) -> String {
    v.map(|x| x.to_string()).unwrap_or_default()
}

/// The configuration prefix of a summary row (everything before the metric
/// columns: `schema` through `data_dir` — every result-affecting field of
/// the run's [`crate::fed::RunConfig`] plus the algorithm/transport specs
/// and the compute-plane backend; `threads` is deliberately excluded since
/// results are bit-invariant to it). This is the key `--resume` matches
/// existing rows against, so a resumed sweep can never silently reuse a
/// result produced under different settings, including a different
/// `--backend`.
pub fn summary_key(sweep: &str, backend: &str, unit: &RunUnit) -> String {
    let cfg = &unit.cfg;
    format!(
        "{schema},{id},{sweep},{algo},{dataset},{model},{transport},{backend},{rounds},{local_steps},{p},{alpha},{gamma},{seed},{train_n},{test_n},{clients},{sampled},{batch_size},{eval_batch},{eval_every},{tau},{data_dir},{compress_up},{compress_down},{scenario},{faults}",
        schema = RESULT_SCHEMA,
        id = unit.id,
        algo = unit.algo,
        dataset = cfg.dataset.key(),
        model = unit.model_key(),
        transport = unit.transport,
        rounds = cfg.rounds,
        local_steps = cfg.local_steps,
        p = cfg.p,
        alpha = cfg.dirichlet_alpha,
        gamma = cfg.gamma,
        seed = cfg.seed,
        train_n = cfg.train_n,
        test_n = cfg.test_n,
        clients = cfg.n_clients,
        sampled = cfg.clients_per_round,
        batch_size = cfg.batch_size,
        eval_batch = cfg.eval_batch,
        eval_every = cfg.eval_every,
        tau = cfg.tau,
        data_dir = cfg.data_dir.display(),
        compress_up = cfg.compress_up,
        compress_down = cfg.compress_down,
        scenario = cfg.scenario,
        faults = cfg.faults,
    )
}

/// Render one summary row for a finished run (no trailing newline).
pub fn summary_row(sweep: &str, backend: &str, unit: &RunUnit, log: &MetricsLog) -> String {
    let last = log.records.last();
    let dropped: u64 = log.records.iter().map(|r| r.dropped_clients).sum();
    let stale: u64 = log.records.iter().map(|r| r.stale_updates).sum();
    let churned: u64 = log.records.iter().map(|r| r.churned_clients).sum();
    let corrupt: u64 = log.records.iter().map(|r| r.corrupt_frames).sum();
    let retrans: u64 = log.records.iter().map(|r| r.retransmits).sum();
    let backoff: f64 = log.records.iter().map(|r| r.backoff_secs).sum();
    let aborted: u64 = log.records.iter().map(|r| r.aborted).sum();
    format!(
        "{key},{best},{fin},{loss},{up},{down},{cost},{sim},{dropped},{stale},{churned},{corrupt},{retrans},{backoff},{aborted}",
        key = summary_key(sweep, backend, unit),
        best = opt_f64(log.best_accuracy()),
        fin = opt_f64(log.final_accuracy()),
        loss = opt_f64(log.final_train_loss()),
        up = log.total_uplink_bits(),
        down = last.map_or(0, |r| r.cum_downlink_bits),
        cost = opt_f64(last.map(|r| r.total_cost)),
        sim = opt_f64(last.map(|r| r.cum_sim_secs)),
    )
}

/// Render one round as a compact JSONL line (no trailing newline).
pub fn round_line(run_id: &str, r: &RoundRecord) -> String {
    let mut o = Json::obj();
    o.set("schema", (RESULT_SCHEMA as u64).into());
    o.set("run", run_id.into());
    o.set("round", r.round.into());
    o.set("local_steps", r.local_steps.into());
    o.set("train_loss", r.train_loss.into());
    if let Some(l) = r.test_loss {
        o.set("test_loss", l.into());
    }
    if let Some(a) = r.test_accuracy {
        o.set("test_accuracy", a.into());
    }
    o.set("uplink_bits", r.uplink_bits.into());
    o.set("downlink_bits", r.downlink_bits.into());
    o.set("cum_uplink_bits", r.cum_uplink_bits.into());
    o.set("cum_downlink_bits", r.cum_downlink_bits.into());
    o.set("total_cost", r.total_cost.into());
    if r.sim_secs > 0.0
        || r.cum_sim_secs > 0.0
        || r.dropped_clients > 0
        || r.stale_updates > 0
        || r.churned_clients > 0
    {
        o.set("sim_secs", r.sim_secs.into());
        o.set("cum_sim_secs", r.cum_sim_secs.into());
        o.set("dropped_clients", r.dropped_clients.into());
        o.set("stale_updates", r.stale_updates.into());
        o.set("churned_clients", r.churned_clients.into());
    }
    if r.corrupt_frames > 0
        || r.retransmits > 0
        || r.dup_frames > 0
        || r.backoff_secs > 0.0
        || r.aborted > 0
    {
        o.set("corrupt_frames", r.corrupt_frames.into());
        o.set("retransmits", r.retransmits.into());
        o.set("dup_frames", r.dup_frames.into());
        o.set("backoff_secs", r.backoff_secs.into());
        o.set("aborted", r.aborted.into());
    }
    o.to_string_compact()
}

/// Write `bytes` to `path` atomically: the content lands in `<path>.tmp`,
/// is flushed and fsynced, and only then renamed over the target — a crash
/// at any instant leaves either the old complete file or the new complete
/// file, never a truncated hybrid. Shared by the summary and per-round
/// writers (and the same discipline [`crate::ckpt::Snapshot`] uses for
/// checkpoint files).
fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension(match path.extension().and_then(|e| e.to_str()) {
        Some(ext) => format!("{ext}.tmp"),
        None => "tmp".to_string(),
    });
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.flush()?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Write the full per-round JSONL file for one run (atomically — see
/// [`write_atomic`]).
pub fn write_rounds_jsonl(
    sweep_dir: &Path,
    run_id: &str,
    log: &MetricsLog,
) -> std::io::Result<()> {
    let path = rounds_path(sweep_dir, run_id);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    for r in &log.records {
        out.push_str(&round_line(run_id, r));
        out.push('\n');
    }
    write_atomic(&path, out.as_bytes())
}

/// Read an existing summary file into `run_id -> row` (resume support).
/// A missing file is an empty map; rows with an unknown schema version are
/// ignored so `--resume` never trusts stale-format results, and a torn
/// final line (the file does not end in a newline — a crash mid-append)
/// is dropped so a partially-written row is re-executed rather than
/// resumed as a complete result.
pub fn read_summary_rows(path: &Path) -> BTreeMap<String, String> {
    let mut rows = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return rows;
    };
    let complete = text.ends_with('\n');
    let mut lines: Vec<&str> = text.lines().collect();
    if !complete {
        lines.pop();
    }
    let want_schema = RESULT_SCHEMA.to_string();
    for line in lines.into_iter().skip(1) {
        let mut fields = line.split(',');
        let schema_ok = fields.next() == Some(want_schema.as_str());
        if let (true, Some(id)) = (schema_ok, fields.next()) {
            rows.insert(id.to_string(), line.to_string());
        }
    }
    rows
}

/// Rewrite the summary file with `rows` in canonical (expansion) order
/// (atomically — see [`write_atomic`]; the canonical rewrite can never
/// destroy the crash-resumable progress rows it replaces).
pub fn write_summary(path: &Path, rows: &[String]) -> std::io::Result<()> {
    let mut out = String::with_capacity(SUMMARY_HEADER.len() + 1 + rows.len() * 128);
    out.push_str(SUMMARY_HEADER);
    out.push('\n');
    for row in rows {
        out.push_str(row);
        out.push('\n');
    }
    write_atomic(path, out.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            local_steps: 7,
            train_loss: 0.5,
            test_loss: (round == 1).then_some(0.25),
            test_accuracy: (round == 1).then_some(0.75),
            uplink_bits: 100,
            downlink_bits: 200,
            cum_uplink_bits: 100 * (round as u64 + 1),
            cum_downlink_bits: 200 * (round as u64 + 1),
            total_cost: 1.07 * (round + 1) as f64,
            wall_secs: 123.0, // must not leak into the sink
            sim_secs: 0.0,
            cum_sim_secs: 0.0,
            dropped_clients: 0,
            stale_updates: 0,
            churned_clients: 0,
            corrupt_frames: 0,
            retransmits: 0,
            dup_frames: 0,
            backoff_secs: 0.0,
            aborted: 0,
        }
    }

    #[test]
    fn round_line_is_pinned_and_excludes_wall_clock() {
        let line = round_line("r000-x", &record(0));
        assert_eq!(
            line,
            "{\"cum_downlink_bits\":200,\"cum_uplink_bits\":100,\"downlink_bits\":200,\
             \"local_steps\":7,\"round\":0,\"run\":\"r000-x\",\"schema\":5,\
             \"total_cost\":1.07,\"train_loss\":0.5,\"uplink_bits\":100}"
        );
        let eval = round_line("r000-x", &record(1));
        assert!(eval.contains("\"test_accuracy\":0.75"));
        assert!(eval.contains("\"test_loss\":0.25"));
        assert!(!eval.contains("wall"), "{eval}");
    }

    #[test]
    fn summary_roundtrips_through_reader() {
        let dir = std::env::temp_dir().join(format!("fedcomloc_sink_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = summary_path(&dir);
        let rows = vec![
            format!("{RESULT_SCHEMA},r000-a,s,fedavg,mnist,mlp,inproc,native,5,10,0.1,0.7,0.05,42,600,150,6,3,16,32,2,0.01,data,none,none,sync,none,0.8,0.7,0.3,1,2,3,0,0,0,0,0,0,0,0"),
            format!("{RESULT_SCHEMA},r001-b,s,scaffold,mnist,mlp,inproc,native,5,10,0.1,0.7,0.05,42,600,150,6,3,16,32,2,0.01,data,q8,none,semisync:2@0.5,corrupt:0.02,,,,1,2,3,0,0,1,1,4,2,1.5,1"),
        ];
        write_summary(&path, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(SUMMARY_HEADER));
        let back = read_summary_rows(&path);
        assert_eq!(back.len(), 2);
        assert_eq!(back.get("r000-a"), Some(&rows[0]));
        // Foreign-schema rows (e.g. pre-compression v1 results) are ignored.
        write_summary(&path, &["1,r009-z,s,x,m,m,t,native,1,1,0,0,0,0,1,1,1,1,1,1,1,0,d,,,,,0,0,0,0,0".to_string()])
            .unwrap();
        assert!(read_summary_rows(&path).is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_partial_write_recovers_to_complete_rows_only() {
        let dir = std::env::temp_dir().join(format!("fedcomloc_sink_trunc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = summary_path(&dir);
        let complete = format!("{RESULT_SCHEMA},r000-a,s,fedavg,mnist,mlp,inproc,native,5,10,0.1,0.7,0.05,42,600,150,6,3,16,32,2,0.01,data,none,none,sync,none,0.8,0.7,0.3,1,2,3,0,0,0,0,0,0,0,0");
        let torn = format!("{RESULT_SCHEMA},r001-b,s,scaffold,mnist,mlp,inproc,nat");
        // Simulate a crash mid-append: one complete row, then a row cut
        // short with no trailing newline.
        std::fs::write(&path, format!("{SUMMARY_HEADER}\n{complete}\n{torn}")).unwrap();
        let rows = read_summary_rows(&path);
        assert_eq!(rows.len(), 1, "torn final row must be dropped: {rows:?}");
        assert_eq!(rows.get("r000-a"), Some(&complete));
        // Truncation *inside* an earlier row (crash mid-rewrite of a
        // non-atomic writer) must also never panic; the reader just keeps
        // whatever rows are still well-formed lines.
        std::fs::write(&path, format!("{SUMMARY_HEADER}\n{}", &complete[..40])).unwrap();
        let _ = read_summary_rows(&path);

        // The atomic writer never leaves a .tmp behind and the target is
        // always complete after it returns.
        write_summary(&path, &[complete.clone()]).unwrap();
        assert!(!path.with_extension("csv.tmp").exists());
        assert_eq!(read_summary_rows(&path).len(), 1);

        // write_rounds_jsonl goes through the same atomic path.
        let log = MetricsLog::new("r000-a");
        write_rounds_jsonl(&dir, "r000-a", &log).unwrap();
        assert!(rounds_path(&dir, "r000-a").is_file());
        assert!(!dir.join("rounds").join("r000-a.jsonl.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
