//! Dirichlet label-skew federated partitioning (paper §4 "Heterogeneous
//! Setting", Appendix B.1; FedLab-style LDA partitioning).
//!
//! For each class c, draw proportions over the n clients from Dir(α·1_n)
//! and split that class's examples accordingly. Smaller α ⇒ each class
//! concentrates on fewer clients ⇒ more heterogeneity (Figure 11). α → ∞
//! approaches a uniform IID split.

use super::Dataset;
use crate::util::rng::Rng;

/// Partition result: per-client example indices into the source dataset.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Example indices per client, in client order.
    pub client_indices: Vec<Vec<usize>>,
    /// The Dirichlet concentration this partition was drawn with.
    pub alpha: f64,
}

impl Partition {
    /// Number of clients the data was split over.
    pub fn num_clients(&self) -> usize {
        self.client_indices.len()
    }

    /// Per-client class histogram (rows: clients, cols: classes) — the data
    /// behind the paper's Figure 11 visualization.
    pub fn class_histogram(&self, data: &Dataset) -> Vec<Vec<usize>> {
        self.client_indices
            .iter()
            .map(|idx| {
                let mut h = vec![0usize; data.num_classes];
                for &i in idx {
                    h[data.labels[i] as usize] += 1;
                }
                h
            })
            .collect()
    }

    /// Mean (over clients) total-variation distance between the client's
    /// class distribution and the global one — a scalar heterogeneity gauge
    /// used in tests and data-stats output.
    pub fn heterogeneity_tv(&self, data: &Dataset) -> f64 {
        let global = data.class_counts();
        let gtotal: usize = global.iter().sum();
        let gdist: Vec<f64> = global.iter().map(|&c| c as f64 / gtotal as f64).collect();
        let hists = self.class_histogram(data);
        let mut acc = 0.0;
        let mut counted = 0usize;
        for h in &hists {
            let total: usize = h.iter().sum();
            if total == 0 {
                continue;
            }
            let tv: f64 = h
                .iter()
                .zip(&gdist)
                .map(|(&c, &g)| (c as f64 / total as f64 - g).abs())
                .sum::<f64>()
                / 2.0;
            acc += tv;
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            acc / counted as f64
        }
    }
}

/// Dirichlet partition of `data` into `n_clients` shards.
///
/// Guarantees: every example is assigned exactly once; every client receives
/// at least `min_per_client` examples (rebalanced from the largest shards —
/// without this, tiny-α draws can leave clients empty, which would make the
/// paper's 10-of-100 sampling degenerate).
pub fn partition(
    data: &Dataset,
    n_clients: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> Partition {
    assert!(n_clients > 0);
    assert!(alpha > 0.0, "Dirichlet alpha must be positive");
    // Bucket example ids by class, shuffled.
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.num_classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    for bucket in &mut by_class {
        rng.shuffle(bucket);
    }

    let mut client_indices: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for bucket in &by_class {
        if bucket.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, n_clients);
        // Largest-remainder allocation of bucket.len() items by props.
        let n = bucket.len();
        let mut alloc: Vec<usize> = props.iter().map(|&p| (p * n as f64).floor() as usize).collect();
        let mut assigned: usize = alloc.iter().sum();
        // Distribute the remainder to the largest fractional parts.
        let mut frac: Vec<(f64, usize)> = props
            .iter()
            .enumerate()
            .map(|(i, &p)| (p * n as f64 - (p * n as f64).floor(), i))
            .collect();
        frac.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut fi = 0;
        while assigned < n {
            alloc[frac[fi % n_clients].1] += 1;
            assigned += 1;
            fi += 1;
        }
        let mut cursor = 0;
        for (client, &take) in alloc.iter().enumerate() {
            client_indices[client].extend_from_slice(&bucket[cursor..cursor + take]);
            cursor += take;
        }
        debug_assert_eq!(cursor, n);
    }

    // Rebalance: top up clients below the floor from the largest shards.
    let floor = min_per_client.min(data.len() / n_clients.max(1));
    loop {
        let (small_i, small_n) = client_indices
            .iter()
            .enumerate()
            .map(|(i, v)| (i, v.len()))
            .min_by_key(|&(_, n)| n)
            .unwrap();
        if small_n >= floor {
            break;
        }
        let (big_i, _) = client_indices
            .iter()
            .enumerate()
            .map(|(i, v)| (i, v.len()))
            .max_by_key(|&(_, n)| n)
            .unwrap();
        let moved = client_indices[big_i].pop().expect("donor shard empty");
        client_indices[small_i].push(moved);
    }

    for shard in &mut client_indices {
        rng.shuffle(shard);
    }
    Partition {
        client_indices,
        alpha,
    }
}

/// A candidate for the largest-remainder bonus units, ordered so a bounded
/// `BinaryHeap` keeps the *best* `cap` candidates with the *worst* on top:
/// "greater" = worse = smaller fractional part, ties broken toward the
/// larger client index (the eager path's stable descending sort hands
/// bonus units to smaller indices first on ties).
struct RemainderCand {
    frac: f64,
    idx: usize,
}

impl Ord for RemainderCand {
    fn cmp(&self, o: &RemainderCand) -> std::cmp::Ordering {
        o.frac.total_cmp(&self.frac).then(self.idx.cmp(&o.idx))
    }
}
impl PartialOrd for RemainderCand {
    fn partial_cmp(&self, o: &RemainderCand) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl PartialEq for RemainderCand {
    fn eq(&self, o: &RemainderCand) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}
impl Eq for RemainderCand {}

/// A Dirichlet partition stored sparsely: only non-empty shards are
/// materialized, so memory is O(examples) rather than O(n_clients) — the
/// representation behind the million-client federation engine. Built by
/// [`partition_streaming`], which consumes the *exact* RNG stream of the
/// eager [`partition`] and produces element-identical shards for every
/// client (the eager path stays as the reference implementation).
#[derive(Debug, Clone)]
pub struct SparsePartition {
    n_clients: usize,
    /// The Dirichlet concentration this partition was drawn with.
    pub alpha: f64,
    /// Non-empty shards only, ascending by client id.
    shards: Vec<(usize, Vec<usize>)>,
}

impl SparsePartition {
    /// Number of clients the data was split over (including the implicit
    /// empty shards).
    pub fn num_clients(&self) -> usize {
        self.n_clients
    }

    /// Client `k`'s example indices; the empty slice for clients that
    /// received no examples. O(log #nonempty).
    pub fn shard(&self, client: usize) -> &[usize] {
        assert!(client < self.n_clients, "client {client} out of range");
        match self.shards.binary_search_by_key(&client, |&(c, _)| c) {
            Ok(i) => &self.shards[i].1,
            Err(_) => &[],
        }
    }

    /// Number of clients that actually hold examples.
    pub fn num_nonempty(&self) -> usize {
        self.shards.len()
    }

    /// The non-empty shards, ascending by client id.
    pub fn nonempty(&self) -> impl Iterator<Item = (usize, &[usize])> {
        self.shards.iter().map(|(c, s)| (*c, s.as_slice()))
    }

    /// Per-client class histogram, dense over all clients — identical to
    /// [`Partition::class_histogram`]. O(n_clients × classes) output:
    /// meant for reports and identity tests at inspection scale, not for
    /// million-client runs.
    pub fn class_histogram(&self, data: &Dataset) -> Vec<Vec<usize>> {
        let mut hists = vec![vec![0usize; data.num_classes]; self.n_clients];
        for (c, shard) in self.nonempty() {
            for &i in shard {
                hists[c][data.labels[i] as usize] += 1;
            }
        }
        hists
    }

    /// Mean (over non-empty clients) total-variation distance to the global
    /// class distribution — same accumulation order and result as
    /// [`Partition::heterogeneity_tv`] (which skips empty shards), but
    /// without materializing the empty rows.
    pub fn heterogeneity_tv(&self, data: &Dataset) -> f64 {
        let global = data.class_counts();
        let gtotal: usize = global.iter().sum();
        let gdist: Vec<f64> = global.iter().map(|&c| c as f64 / gtotal as f64).collect();
        let mut acc = 0.0;
        let mut counted = 0usize;
        let mut h = vec![0usize; data.num_classes];
        for (_, shard) in self.nonempty() {
            h.iter_mut().for_each(|x| *x = 0);
            for &i in shard {
                h[data.labels[i] as usize] += 1;
            }
            let total: usize = h.iter().sum();
            if total == 0 {
                continue;
            }
            let tv: f64 = h
                .iter()
                .zip(&gdist)
                .map(|(&c, &g)| (c as f64 / total as f64 - g).abs())
                .sum::<f64>()
                / 2.0;
            acc += tv;
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            acc / counted as f64
        }
    }
}

/// Streaming Dirichlet partition: same draws, same shards as the eager
/// [`partition`] for every `(data, n_clients, alpha, min_per_client, seed)`,
/// in O(examples) memory regardless of `n_clients`.
///
/// Two regimes:
///
/// * `n_clients ≤ examples` — the eager path's own memory is already
///   O(examples), so it runs verbatim and the result is wrapped sparsely
///   (bit-identity by construction).
/// * `n_clients > examples` — the eager rebalance floor
///   `min_per_client.min(len / n_clients)` is 0, so rebalancing is a no-op
///   and each class's Dir(α·1_n) draw is replayed in two streaming passes:
///   a cloned generator accumulates the gamma sum left-to-right exactly as
///   `Rng::dirichlet`'s `iter().sum()` does, then the real generator
///   re-draws each gamma and derives `floor(p·n)` / fractional parts on
///   the fly, keeping only non-zero allocations and a bounded heap of the
///   best remainder candidates. The largest-remainder bonus count
///   R = n − Σfloor satisfies R ≤ min(n_clients, bucket_len) (each
///   fractional part is < 1), so a heap capped there always contains the
///   true winners, replicated in the eager sort order (frac descending,
///   index ascending on ties).
pub fn partition_streaming(
    data: &Dataset,
    n_clients: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> SparsePartition {
    assert!(n_clients > 0);
    assert!(alpha > 0.0, "Dirichlet alpha must be positive");
    if n_clients <= data.len() {
        let eager = partition(data, n_clients, alpha, min_per_client, rng);
        let shards = eager
            .client_indices
            .into_iter()
            .enumerate()
            .filter(|(_, s)| !s.is_empty())
            .collect();
        return SparsePartition { n_clients, alpha, shards };
    }

    // Million-client regime: stream every class's Dirichlet draw.
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.num_classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    for bucket in &mut by_class {
        rng.shuffle(bucket);
    }

    use std::collections::HashMap;
    let mut shard_map: HashMap<usize, Vec<usize>> = HashMap::new();
    for bucket in &by_class {
        if bucket.is_empty() {
            continue;
        }
        let n = bucket.len();
        let nf = n as f64;

        // Pass 1 (cloned generator): the gamma sum, accumulated
        // left-to-right exactly like `dirichlet`'s `v.iter().sum()`.
        let mut probe = rng.clone();
        let mut sum = 0.0f64;
        for _ in 0..n_clients {
            sum += probe.gamma(alpha);
        }

        // Sparse allocation for this class: (client, count), ascending.
        let mut alloc: Vec<(usize, usize)>;
        if sum <= 0.0 {
            // Degenerate draw: `dirichlet` burns the k gammas, then
            // one-hots a uniform index — whole bucket to that client.
            for _ in 0..n_clients {
                rng.gamma(alpha);
            }
            let idx = rng.below_usize(n_clients);
            alloc = vec![(idx, n)];
        } else {
            // Pass 2 (real generator): floors and remainder candidates.
            let cap = n_clients.min(n);
            let mut floors: Vec<(usize, usize)> = Vec::new();
            let mut assigned = 0usize;
            let mut heap: std::collections::BinaryHeap<RemainderCand> =
                std::collections::BinaryHeap::with_capacity(cap + 1);
            for i in 0..n_clients {
                let p = rng.gamma(alpha) / sum;
                let t = p * nf;
                let fl = t.floor();
                let frac = t - fl;
                let fl = fl as usize;
                if fl > 0 {
                    floors.push((i, fl));
                    assigned += fl;
                }
                let cand = RemainderCand { frac, idx: i };
                if heap.len() < cap {
                    heap.push(cand);
                } else if let Some(worst) = heap.peek() {
                    if cand.cmp(worst) == std::cmp::Ordering::Less {
                        heap.pop();
                        heap.push(cand);
                    }
                }
            }
            let r = n - assigned;
            let mut winners = heap.into_vec();
            winners.sort_by(|a, b| b.frac.total_cmp(&a.frac).then(a.idx.cmp(&b.idx)));
            let mut bonus: Vec<usize> = winners.into_iter().take(r).map(|w| w.idx).collect();
            bonus.sort_unstable();
            // Merge floors and bonus units, ascending by client.
            alloc = Vec::with_capacity(floors.len() + bonus.len());
            let (mut fi, mut bi) = (0, 0);
            while fi < floors.len() || bi < bonus.len() {
                let fc = floors.get(fi).map(|&(c, _)| c);
                let bc = bonus.get(bi).copied();
                match (fc, bc) {
                    (Some(f), Some(b)) if f == b => {
                        alloc.push((f, floors[fi].1 + 1));
                        fi += 1;
                        bi += 1;
                    }
                    (Some(f), Some(b)) if f < b => {
                        alloc.push((f, floors[fi].1));
                        fi += 1;
                    }
                    (Some(_), Some(b)) => {
                        alloc.push((b, 1));
                        bi += 1;
                    }
                    (Some(f), None) => {
                        alloc.push((f, floors[fi].1));
                        fi += 1;
                    }
                    (None, Some(b)) => {
                        alloc.push((b, 1));
                        bi += 1;
                    }
                    (None, None) => unreachable!(),
                }
            }
        }

        // Slice the shuffled bucket in ascending client order — the same
        // cursor walk as the eager `alloc.iter().enumerate()` loop, which
        // only advances on non-zero takes.
        let mut cursor = 0;
        for &(client, take) in &alloc {
            shard_map
                .entry(client)
                .or_default()
                .extend_from_slice(&bucket[cursor..cursor + take]);
            cursor += take;
        }
        debug_assert_eq!(cursor, n);
    }

    // Rebalance floor is min_per_client.min(len / n_clients) = 0 here, so
    // the eager top-up loop breaks immediately — nothing to replicate.
    // Final shuffles: the eager path walks shards in client order; empty
    // and single-element shards consume no draws, so shuffling only the
    // non-empty shards in ascending id order is draw-for-draw identical.
    let mut shards: Vec<(usize, Vec<usize>)> = shard_map.into_iter().collect();
    shards.sort_unstable_by_key(|&(c, _)| c);
    for (_, shard) in &mut shards {
        rng.shuffle(shard);
    }
    SparsePartition { n_clients, alpha, shards }
}

/// Render the Figure 11-style per-client class distribution as text (rows:
/// first `max_clients` clients; one bar per class).
pub fn render_histogram(partition: &Partition, data: &Dataset, max_clients: usize) -> String {
    let hist = partition.class_histogram(data);
    let mut out = String::new();
    out.push_str(&format!(
        "client-class distribution (alpha={}, showing {} of {} clients)\n",
        partition.alpha,
        max_clients.min(hist.len()),
        hist.len()
    ));
    for (c, h) in hist.iter().take(max_clients).enumerate() {
        let total: usize = h.iter().sum();
        out.push_str(&format!("client {c:>3} ({total:>5} ex): "));
        for &count in h {
            let frac = if total == 0 { 0.0 } else { count as f64 / total as f64 };
            let bar = (frac * 20.0).round() as usize;
            out.push_str(&format!("{:>4}|{}", count, "#".repeat(bar)));
            out.push(' ');
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, DatasetSpec};

    fn dataset(n: usize) -> Dataset {
        let mut rng = Rng::seed_from_u64(9);
        synthetic::generate(&DatasetSpec::mnist(), n, 10, &mut rng).train
    }

    #[test]
    fn partition_covers_all_examples_once() {
        let data = dataset(2000);
        let mut rng = Rng::seed_from_u64(1);
        let p = partition(&data, 100, 0.7, 5, &mut rng);
        let mut seen = vec![false; data.len()];
        for shard in &p.client_indices {
            for &i in shard {
                assert!(!seen[i], "example {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some examples unassigned");
    }

    #[test]
    fn min_per_client_enforced() {
        let data = dataset(2000);
        let mut rng = Rng::seed_from_u64(2);
        let p = partition(&data, 100, 0.1, 5, &mut rng);
        assert!(p.client_indices.iter().all(|s| s.len() >= 5));
    }

    #[test]
    fn smaller_alpha_is_more_heterogeneous() {
        let data = dataset(4000);
        let mut tvs = Vec::new();
        for &alpha in &[0.1, 0.5, 1.0, 10.0, 1000.0] {
            let mut rng = Rng::seed_from_u64(3);
            let p = partition(&data, 20, alpha, 1, &mut rng);
            tvs.push(p.heterogeneity_tv(&data));
        }
        // TV distance should decrease (weakly) as alpha grows.
        for w in tvs.windows(2) {
            assert!(
                w[0] >= w[1] - 0.02,
                "heterogeneity not monotone: {tvs:?}"
            );
        }
        assert!(tvs[0] > 0.4, "alpha=0.1 should be very skewed: {tvs:?}");
        assert!(*tvs.last().unwrap() < 0.15, "alpha=1000 nearly IID: {tvs:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let data = dataset(500);
        let mut r1 = Rng::seed_from_u64(4);
        let mut r2 = Rng::seed_from_u64(4);
        let p1 = partition(&data, 10, 0.7, 1, &mut r1);
        let p2 = partition(&data, 10, 0.7, 1, &mut r2);
        assert_eq!(p1.client_indices, p2.client_indices);
    }

    #[test]
    fn histogram_shape_and_render() {
        let data = dataset(500);
        let mut rng = Rng::seed_from_u64(5);
        let p = partition(&data, 10, 0.3, 1, &mut rng);
        let h = p.class_histogram(&data);
        assert_eq!(h.len(), 10);
        assert_eq!(h[0].len(), 10);
        let total: usize = h.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(total, data.len());
        let text = render_histogram(&p, &data, 5);
        assert!(text.contains("client   0"));
    }
}
