//! Table 2 + Figures 2/12: Dirichlet heterogeneity × sparsity grid.

mod common;

use fedcomloc::fed::{run, RunConfig};

fn main() {
    println!("== Table 2: α × K accuracy grid (bench scale) ==");
    let trainer = common::mlp_trainer();
    let alphas = [0.1, 0.3, 0.7, 1.0];
    let densities = [1.0, 0.10, 0.50];
    print!("{:<10}", "");
    for a in alphas {
        print!("{:>12}", format!("α={a}"));
    }
    println!();
    let mut grid = Vec::new();
    for &density in &densities {
        print!("{:<10}", format!("K={:.0}%", density * 100.0));
        let mut row = Vec::new();
        for &alpha in &alphas {
            let cfg = RunConfig {
                dirichlet_alpha: alpha,
                ..common::mnist_cfg()
            };
            let spec = common::fedcomloc_topk(density);
            let acc = run(&cfg, trainer.clone(), &spec)
                .best_accuracy()
                .unwrap_or(0.0);
            print!("{acc:>12.4}");
            row.push(acc);
        }
        println!();
        grid.push(row);
    }
    println!("\n  paper shape: accuracy rises with α; K=10% is the most α-sensitive row.");
    let k10 = &grid[1];
    println!(
        "  K=10% spread (α=1.0 − α=0.1): {:+.4} (paper: +0.0701 absolute)",
        k10.last().unwrap() - k10.first().unwrap()
    );
}
