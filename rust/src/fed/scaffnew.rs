//! FedComLoc (paper Algorithm 1): Scaffnew/ProxSkip local training with
//! compression, in the three variants of §3.2 — as a [`FedAlgorithm`].
//!
//! Iteration structure. The server pre-commits to the Bernoulli(p) coin
//! sequence θ_0..θ_{T−1} (Algorithm 1 line 2); a *communication round* is a
//! maximal run of θ=0 iterations followed by the θ=1 iteration that
//! triggers aggregation, so segment lengths are Geometric(p) with mean 1/p
//! — the paper's "average of 10 local iterations per round" at p = 0.1.
//!
//! Client sampling (paper §4: 10 of 100 per round) is owned by the drive
//! loop; the sampled set receives the current global model over the
//! transport, runs the whole segment locally, and participates in the
//! aggregation; control variates h_i of unsampled (or dropped) clients stay
//! frozen.
//!
//! **Compression is directional.** The driver itself is variant-agnostic
//! about the wire: every client upload goes through that client's uplink
//! [`crate::compress::Pipeline`] ([`super::ClientState::up`]) and, when
//! the federation's downlink pipeline is non-identity, the aggregated
//! model is compressed server-side, retained, and rebroadcast in its
//! compressed form with the h-refresh (line 16) using the *compressed*
//! x_{t+1} — faithful to Algorithm 1 lines 11–12/16. The legacy variants
//! are shims over this: `-Com` installs its compressor as every client's
//! uplink pipeline, `-Global` as the downlink pipeline, and `-Local`
//! applies C(x) in-graph inside each local step (the TopK Pallas kernel)
//! with a dense wire. `compress_up`/`compress_down` in
//! [`super::RunConfig`] configure the same two pipelines directly — e.g.
//! `fedcomloc` + `compress_down=topk:0.3` *is* FedComLoc-Global, and
//! setting both gives LoCoDL-style bidirectional compression.
//!
//! Wire shape per round: one downlink broadcast (dense, or the retained
//! compressed model) and one uplink [`Message`] per participant.
//!
//! Invariant (tested): with an uncompressed downlink, Σ_i h_i stays 0 —
//! each round's updates sum to (p/γ)·(m·mean(ε) − Σ ε) = 0.

use super::algorithm::{AlgoState, FedAlgorithm, RoundCtx, RoundOutcome};
use super::message::{Message, SERVER};
use super::{Federation, RunConfig, Variant};
use crate::compress::CompressorSpec;
use crate::util::rng::Rng;

/// One client's segment result (the uplink message plus local stats).
struct Segment {
    upload: Message,
    loss_sum: f64,
    steps: usize,
}

/// Draw the next segment length: iterations until (and including) the next
/// θ=1 coin. Shared server/worker stream per Algorithm 1 lines 2–3.
pub fn next_segment_len(coin_rng: &mut Rng, p: f64) -> usize {
    let mut len = 1;
    while !coin_rng.bernoulli(p) {
        len += 1;
    }
    len
}

/// FedComLoc in its -Com / -Local / -Global variants.
pub struct FedComLoc {
    variant: Variant,
    /// The variant's inline compressor spec (wire shim for -Com/-Global,
    /// in-graph mask density source for -Local).
    spec: CompressorSpec,
    /// Density for the -Local in-graph masked step (TopK only).
    local_density: Option<f64>,
    /// Algorithm 1's server coin stream (derived in `setup`).
    coin_rng: Rng,
    /// Server-side compression randomness for the downlink pipeline.
    server_rng: Rng,
    /// (p/γ) for the control-variate refresh.
    p_over_gamma: f32,
    /// A non-identity downlink retains the compressed model message
    /// between rounds so subsequent downlinks ship (and are billed at)
    /// the compressed form.
    downlink_msg: Option<Message>,
    /// Per-round decoded-uplink buffers, reused across rounds (grown on
    /// demand, never shrunk) — the server-side twin of the workers'
    /// workspaces.
    delivery: Vec<Vec<f32>>,
}

/// The in-graph mask density a compressor spec supplies to the -Local
/// variant: `Some` exactly for a pure `topk:<density>` spec, parsed from
/// the spec *key* (the user's exact string — the `{:.2}` display name
/// would round 0.125 to 0.12), `None` otherwise (the registry rejects
/// maskless non-identity -Local specs at build time). The density range
/// was already validated by [`CompressorSpec::parse`], so any value that
/// parses here is in (0, 1].
pub(crate) fn local_mask_density(spec: &CompressorSpec) -> Option<f64> {
    spec.key()
        .trim()
        .to_ascii_lowercase()
        .strip_prefix("topk:")
        .and_then(|rest| rest.parse::<f64>().ok())
}

impl FedComLoc {
    /// FedComLoc in `variant`, with the variant's inline compressor spec
    /// (for -Local, a TopK spec also supplies the in-graph mask density).
    pub fn new(variant: Variant, spec: CompressorSpec) -> FedComLoc {
        let local_density = local_mask_density(&spec);
        FedComLoc {
            variant,
            spec,
            local_density,
            coin_rng: Rng::seed_from_u64(0),
            server_rng: Rng::seed_from_u64(0),
            p_over_gamma: 0.0,
            downlink_msg: None,
            delivery: Vec::new(),
        }
    }
}

impl FedAlgorithm for FedComLoc {
    fn name(&self) -> String {
        format!("fedcomloc-{}[{}]", self.variant.name(), self.spec.name())
    }

    fn log_name(&self, fed: &Federation, cfg: &RunConfig) -> String {
        format!(
            "fedcomloc-{}[{}]-{}-a{}",
            self.variant.name(),
            self.spec.name(),
            fed.model.name(),
            cfg.dirichlet_alpha
        )
    }

    fn log_meta(&self, cfg: &RunConfig) -> Vec<(String, String)> {
        vec![
            ("algorithm".into(), format!("fedcomloc-{}", self.variant.name())),
            ("compressor".into(), self.spec.name()),
            ("p".into(), cfg.p.to_string()),
            ("gamma".into(), cfg.gamma.to_string()),
            ("alpha".into(), cfg.dirichlet_alpha.to_string()),
            ("clients".into(), cfg.n_clients.to_string()),
            ("sampled".into(), cfg.clients_per_round.to_string()),
        ]
    }

    fn setup(&mut self, fed: &mut Federation, cfg: &RunConfig) {
        // Legacy shim: the variant's inline compressor becomes the
        // directional pipeline it historically drove.
        match self.variant {
            Variant::Com => fed.install_uplink_shim(&self.spec, cfg),
            Variant::Global => fed.install_downlink_shim(&self.spec, cfg),
            Variant::Local => {}
        }
        self.coin_rng = fed.rng.derive(0x5EED_C019);
        self.server_rng = fed.rng.derive(0x5E2E_5EED);
        self.p_over_gamma = (cfg.p / cfg.gamma as f64) as f32;
        self.downlink_msg = None;
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundOutcome {
        let cfg = ctx.cfg;
        let seg_len = next_segment_len(&mut self.coin_rng, cfg.p);

        // ---- downlink: broadcast current model to the sampled set ----
        let msg = match &self.downlink_msg {
            Some(m) => {
                // The retained compressed payload is rebroadcast as this
                // round's message, so re-stamp the header.
                let mut m = m.clone();
                m.header.round = ctx.round as u32;
                m
            }
            None => Message::dense(ctx.round, SERVER, &ctx.fed.x),
        };
        let participants = ctx.transport.broadcast(&ctx.sampled, &msg);
        let x = msg.to_dense();

        // ---- local segments in parallel (workspace fast path) ----
        let trainer = ctx.fed.trainer.clone();
        let gamma = cfg.gamma;
        let round = ctx.round;
        let (variant, local_density) = (self.variant, self.local_density);
        let d = x.len();
        let results: Vec<Segment> = ctx.map_clients_ws(&participants, |ci, state, ws| {
            // The local iterate x_i lives in the worker's workspace and
            // ping-pongs with the fused-step output: moving a Vec out and
            // swapping are pointer operations, so a warm segment performs
            // no heap allocation besides the uplink message itself.
            let mut xi = ws.take_xi_primed(&x);
            let mut loss_sum = 0.0f64;
            // Empty shards (million-client populations smaller than the
            // dataset leave most clients without examples) skip the local
            // segment: the client echoes the broadcast model back.
            if !state.loader.is_empty() {
                for _ in 0..seg_len {
                    let batch = state.loader.next_batch();
                    let loss = match (variant, local_density) {
                        (Variant::Local, Some(density)) => trainer.train_step_masked_into(
                            &xi[..d],
                            &state.h,
                            &batch,
                            gamma,
                            density,
                            ws,
                        ),
                        _ => trainer.train_step_into(&xi[..d], &state.h, &batch, gamma, ws),
                    };
                    std::mem::swap(&mut xi, &mut ws.step);
                    loss_sum += loss as f64;
                }
            }
            // ---- uplink: transmit x̂ through the client's pipeline ----
            let upload =
                Message::through(round, ci as u32, &xi[..d], &mut state.up, &mut state.rng);
            ws.put_xi(xi);
            Segment {
                upload,
                loss_sum,
                steps: seg_len,
            }
        });

        // ---- uplink delivery on the coordinator thread ----
        let total_steps: usize = results.iter().map(|r| r.steps).sum();
        let loss_sum: f64 = results.iter().map(|r| r.loss_sum).sum();
        // Decode into the per-round delivery buffers retained on self —
        // the ε_i reconstructions, decoded from the wire format alone (no
        // compressor instance needed), with zero steady-state allocation.
        let mut delivered: Vec<(usize, usize)> = Vec::with_capacity(results.len());
        let mut used = 0usize;
        for (seg, &ci) in results.into_iter().zip(&participants) {
            if let Some(received) = ctx.transport.uplink(ci, seg.upload) {
                if self.delivery.len() == used {
                    self.delivery.push(Vec::new());
                }
                received.to_dense_into(&mut self.delivery[used]);
                delivered.push((ci, used));
                used += 1;
            }
        }

        if used > 0 {
            // ---- aggregate (Algorithm 1 line 10) ----
            let rows: Vec<&[f32]> = self.delivery[..used].iter().map(|e| e.as_slice()).collect();
            crate::tensor::mean_into(&rows, &mut ctx.fed.x);
            // Compress the aggregated model server-side (lines 11–12) when
            // a downlink pipeline is configured; subsequent downlinks ship
            // the compressed form and the h-refresh sees the compressed x.
            if !ctx.fed.downlink.is_identity() {
                let enc = ctx.fed.downlink.compress(&ctx.fed.x, round, &mut self.server_rng);
                let global = Message::from_compressed(round, SERVER, enc);
                ctx.fed.x = global.to_dense();
                self.downlink_msg = Some(global);
            }

            // ---- control-variate refresh (line 16) for participants ----
            for &(ci, slot) in &delivered {
                let mut state = ctx.fed.clients[ci].lock().unwrap();
                crate::tensor::control_variate_update(
                    &mut state.h,
                    &ctx.fed.x,
                    &self.delivery[slot],
                    self.p_over_gamma,
                );
            }
        }

        RoundOutcome {
            local_steps: seg_len,
            train_loss: loss_sum / total_steps.max(1) as f64,
        }
    }

    fn save_state(&self) -> AlgoState {
        // Cross-round server state: the two RNG streams plus the retained
        // compressed downlink. `p_over_gamma`/`delivery` are re-derived or
        // scratch; the EF residuals of the pipelines live with the
        // federation, not here.
        let mut state = AlgoState::new();
        state.push_rng("coin_rng", &self.coin_rng);
        state.push_rng("server_rng", &self.server_rng);
        state.push_msg("downlink_msg", &self.downlink_msg);
        state
    }

    fn restore_state(&mut self, mut state: AlgoState) -> Result<(), String> {
        self.coin_rng = state.take_rng("coin_rng")?;
        self.server_rng = state.take_rng("server_rng")?;
        self.downlink_msg = state.take_msg("downlink_msg")?;
        state.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_lengths_geometric() {
        let mut rng = Rng::seed_from_u64(1);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| next_segment_len(&mut rng, 0.1) as f64).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.3, "mean={mean}");
        let mut rng = Rng::seed_from_u64(2);
        let mean: f64 =
            (0..n).map(|_| next_segment_len(&mut rng, 0.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn density_extraction_accepts_only_pure_topk() {
        assert_eq!(
            local_mask_density(&CompressorSpec::parse("topk:0.25").unwrap()),
            Some(0.25)
        );
        // Exact, not display-rounded: 0.125 must not become 0.12, and a
        // sub-percent density must not collapse to 0.00.
        assert_eq!(
            local_mask_density(&CompressorSpec::parse("topk:0.125").unwrap()),
            Some(0.125)
        );
        assert_eq!(
            local_mask_density(&CompressorSpec::parse("topk:0.001").unwrap()),
            Some(0.001)
        );
        // Everything else — quantizers, chains (whose trailing stages the
        // -Local variant would silently drop), EF, schedules — yields None
        // and is rejected by the registry builder for -Local.
        for spec in ["q:8", "topk:0.5|q8", "ef(topk:0.1)", "randk:0.2"] {
            assert_eq!(
                local_mask_density(&CompressorSpec::parse(spec).unwrap()),
                None,
                "{spec}"
            );
        }
    }
}
