//! The federated algorithm API: one trait, one generic drive loop.
//!
//! A [`FedAlgorithm`] implements exactly the algorithm-specific part of a
//! communication round — local objectives, what goes on the wire, how the
//! server folds updates back in — while [`drive`] owns everything every
//! algorithm used to copy-paste: federation construction, client sampling,
//! the evaluation cadence, per-round [`crate::fed::RoundLogger`]
//! bookkeeping, and the worker pool (via [`RoundCtx::map_clients`]).
//!
//! Communication goes through the [`Transport`] in the [`RoundCtx`]:
//! algorithms build [`Message`]s, `broadcast` them down and `uplink` them
//! back, and never touch bit accounting — the transport measures real
//! payloads, and a [`crate::fed::transport::SimNet`] can inject latency,
//! bandwidth limits, and client dropout under any algorithm unchanged.
//!
//! ```text
//! drive ──► sample S_r ──► algo.round(ctx) ──► transport.end_round()
//!                │                 │
//!                │          broadcast(model) ─► map_clients(train)
//!                │                 ▲                   │
//!                └─────────────────┴── uplink(update) ◄┘
//! ```

use super::transport::Transport;
use super::{ClientState, Federation, RoundLogger, RunConfig};
use crate::metrics::MetricsLog;
use crate::model::{LocalTrainer, Workspace};
use std::sync::Arc;

/// What one communication round reports back to the drive loop. Wire usage
/// is *not* part of this: the transport measures it.
#[derive(Debug, Clone, Copy)]
pub struct RoundOutcome {
    /// Local iterations each participating client executed this round.
    pub local_steps: usize,
    /// Mean training loss over participants' local steps.
    pub train_loss: f64,
}

/// Per-round context handed to [`FedAlgorithm::round`].
pub struct RoundCtx<'a> {
    /// The run's configuration.
    pub cfg: &'a RunConfig,
    /// Shared run state (model params, clients, worker pool).
    pub fed: &'a mut Federation,
    /// The channel every client/server message must cross.
    pub transport: &'a mut dyn Transport,
    /// Communication-round index (0-based).
    pub round: usize,
    /// The sampled participant set S_r for this round (drawn by [`drive`];
    /// the transport may still drop members at broadcast time).
    pub sampled: Vec<usize>,
}

impl RoundCtx<'_> {
    /// Fork-join over `clients` on the federation's worker pool, with each
    /// client's persistent state locked for the duration of the closure.
    /// Results come back in input order.
    pub fn map_clients<R, F>(&self, clients: &[usize], f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &mut ClientState) -> R + Sync,
    {
        let states = &self.fed.clients;
        self.fed.pool.map(clients, |_, &ci| {
            let mut state = states[ci].lock().unwrap();
            f(ci, &mut state)
        })
    }

    /// [`RoundCtx::map_clients`] with the executing worker's private
    /// [`Workspace`] locked alongside the client state — the hot-path
    /// variant all shipped algorithms use. Worker slot `w` locks exactly
    /// `fed.workspaces[w]`, so workspace locks never contend and scratch
    /// stays warm across rounds (see `model::workspace` ownership rules).
    pub fn map_clients_ws<R, F>(&self, clients: &[usize], f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &mut ClientState, &mut Workspace) -> R + Sync,
    {
        let states = &self.fed.clients;
        let workspaces = &self.fed.workspaces;
        self.fed.pool.map_worker(clients, |w, _, &ci| {
            let mut state = states[ci].lock().unwrap();
            let mut ws = workspaces[w].lock().unwrap();
            f(ci, &mut state, &mut ws)
        })
    }
}

/// What a client's uplink payload *means* — how a semi-synchronous
/// scenario must turn a straggler's late message into an additive update
/// (see [`crate::fed::sim`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UplinkKind {
    /// The uplink carries the client's full local model x_i; a straggler's
    /// contribution is the difference against the broadcast it trained
    /// from (FedAvg, FedComLoc, FedDyn).
    Model,
    /// The uplink carries an additive delta already (Scaffold's Δx).
    Delta,
}

/// A federated algorithm, drivable by [`drive`]. Implementations hold all
/// algorithm-local server state (control variates, regularizer state, coin
/// streams) and initialize it in [`FedAlgorithm::setup`].
pub trait FedAlgorithm: Send {
    /// Display name, e.g. `fedcomloc-com[topk(0.30)]`.
    fn name(&self) -> String;

    /// Run name for the [`MetricsLog`] (kept format-stable across the API
    /// migration so downstream tooling sees identical logs).
    fn log_name(&self, fed: &Federation, cfg: &RunConfig) -> String;

    /// Metadata key/value pairs recorded on the [`MetricsLog`].
    fn log_meta(&self, cfg: &RunConfig) -> Vec<(String, String)>;

    /// One-time initialization after [`Federation`] construction.
    fn setup(&mut self, _fed: &mut Federation, _cfg: &RunConfig) {}

    /// Execute one communication round.
    fn round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundOutcome;

    /// One-time teardown after the last round.
    fn finalize(&mut self, _fed: &mut Federation, _cfg: &RunConfig) {}

    /// What this algorithm's first uplink stream per client carries (how
    /// the scenario engine folds a straggler's late update). Most drivers
    /// upload the local model; override for delta-valued uplinks.
    fn uplink_kind(&self) -> UplinkKind {
        UplinkKind::Model
    }
}

/// Run `algo` to completion on a fresh [`Federation`].
pub fn drive(
    cfg: &RunConfig,
    trainer: Arc<dyn LocalTrainer>,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn Transport,
) -> MetricsLog {
    let mut fed = Federation::new(cfg, trainer);
    drive_federation(cfg, &mut fed, algo, transport)
}

/// Run `algo` to completion on an existing [`Federation`] (useful for tests
/// that inspect federation state afterwards).
///
/// This is the single round loop all algorithms share: sample S_r, run the
/// algorithm's round, drain the transport's accounting, evaluate on the
/// configured cadence, and record one [`crate::metrics::RoundRecord`].
pub fn drive_federation(
    cfg: &RunConfig,
    fed: &mut Federation,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn Transport,
) -> MetricsLog {
    let name = algo.log_name(fed, cfg);
    let mut log = MetricsLog::new(&name);
    for (key, value) in algo.log_meta(cfg) {
        log = log.with_meta(&key, value);
    }
    // Directional pipelines are run-level config, not algorithm state, so
    // the drive loop records them (only when set, keeping legacy logs
    // byte-stable).
    if cfg.compress_up != "none" {
        log = log.with_meta("compress_up", &cfg.compress_up);
    }
    if cfg.compress_down != "none" {
        log = log.with_meta("compress_down", &cfg.compress_down);
    }
    if cfg.scenario != "sync" {
        log = log.with_meta("scenario", &cfg.scenario);
    }
    algo.setup(fed, cfg);
    let mut logger = RoundLogger::new(cfg, log);
    for round in 0..cfg.rounds {
        logger.begin_round();
        let sampled = fed.sample_clients(cfg.clients_per_round);
        let outcome = {
            // Explicit reborrows: the ctx borrows end with this block.
            let mut ctx = RoundCtx {
                cfg,
                fed: &mut *fed,
                transport: &mut *transport,
                round,
                sampled,
            };
            algo.round(&mut ctx)
        };
        let report = transport.end_round();
        let eval = if (round + 1) % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(fed.evaluate())
        } else {
            None
        };
        if let Some(e) = &eval {
            log::info!(
                "[{name}] round {round}: loss {:.4} acc {:.4} up {} bits",
                outcome.train_loss,
                e.accuracy,
                report.usage.uplink_bits
            );
        }
        logger.end_round(round, outcome.local_steps, outcome.train_loss, &report, eval);
    }
    algo.finalize(fed, cfg);
    logger.finish()
}
