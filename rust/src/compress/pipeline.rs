//! Composable compression pipelines: the stateless [`Chain`] combinator
//! and the stateful per-link [`Pipeline`] instance.
//!
//! [`Chain`] is the generic composition Cₙ∘…∘C₁ behind `|`-joined specs
//! (`topk:0.1|q8`). It retires the seed's hard-coded `DoubleCompress`: a
//! two-stage chain of a support sparsifier (TopK/RandK) followed by a
//! quantizer emits the **fused** [`super::Codec::SparseQuantized`] wire
//! layout — survivor indices + per-survivor-bucket quantized values —
//! through exactly the canonical `encode_sparse_quantized_into` encoder the
//! seed used, so `topk:<d>|q<b>` wire bytes are byte-identical to the
//! retired `DoubleCompress` (pinned below and by `tests/api_regression.rs`
//! through the legacy `topk:<d>+q:<b>` spelling). Any other composition
//! falls back to applying the leading stages semantically and serializing
//! with the final stage's codec, which keeps every chain self-describing
//! on the wire.
//!
//! [`Pipeline`] is what a *link* owns — per (client, direction), built from
//! a [`super::CompressorSpec`] by `Federation`. Plain chains delegate
//! straight to the stateless [`Compressor`] impls (bit-identical by
//! construction); `ef(...)` adds per-link [`ErrorFeedback`] memory and
//! `sched:...` re-parameterizes its family from the communication-round
//! index. Stochastic draws come from the caller's RNG stream (the client's
//! persistent stream for uplinks, the server's for broadcasts), so
//! pipelines never hold RNG state of their own.

use super::ef::ErrorFeedback;
use super::schedule::Schedule;
use super::{quantize, CodecMeta, Compressed, Compressor};
use crate::util::rng::Rng;

/// Generic composition C₂∘C₁ (or longer), the `|` combinator.
pub struct Chain {
    stages: Vec<Box<dyn Compressor>>,
}

impl Chain {
    /// Compose `stages` left-to-right (at least two).
    pub fn new(stages: Vec<Box<dyn Compressor>>) -> Chain {
        assert!(stages.len() >= 2, "a chain needs at least two stages");
        Chain { stages }
    }

    /// The fused sparsifier→quantizer parameters, when this chain is
    /// exactly that shape: (survivor count for dim d, quantizer bits,
    /// quantizer bucket).
    fn fused_params(&self, d: usize) -> Option<(usize, u32, usize)> {
        if self.stages.len() != 2 {
            return None;
        }
        let k = self.stages[0].support_size(d)?;
        let (bits, bucket) = self.stages[1].quantizer_params()?;
        Some((k, bits, bucket))
    }
}

impl Compressor for Chain {
    fn name(&self) -> String {
        let names: Vec<String> = self.stages.iter().map(|s| s.name()).collect();
        names.join("+")
    }

    fn compress_into(&self, x: &[f32], rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta {
        let d = x.len();
        if let Some((_, bits, bucket)) = self.fused_params(d) {
            // Sparsifier→quantizer: the seed's double-compression layout.
            // Select the support, then quantize the survivor sequence in
            // its own buckets — the canonical encoder, not a copy of it.
            let idx = self.stages[0]
                .select_support(x, rng)
                .expect("support_size implies select_support");
            let vals: Vec<f32> = idx.iter().map(|&i| x[i]).collect();
            return quantize::encode_sparse_quantized_into(d, &idx, &vals, bits, bucket, rng, payload);
        }
        // Generic composition: apply the leading stages semantically, then
        // serialize with the final stage's codec (self-describing wire).
        let mut y = x.to_vec();
        let (last, leading) = self.stages.split_last().expect("chain is non-empty");
        for stage in leading {
            stage.apply(&mut y, rng);
        }
        last.compress_into(&y, rng, payload)
    }

    fn decompress(&self, c: &Compressed) -> Vec<f32> {
        super::decode_payload(c.codec, c.dim, &c.payload)
    }

    fn nominal_bits(&self, d: usize) -> u64 {
        match self.fused_params(d) {
            // The encoder's maximal layout, via the same formula it sizes
            // buffers with (shared with the seed's DoubleCompress bound).
            Some((k, bits, bucket)) => quantize::sparse_quantized_wire_bits(d, k, bits, bucket),
            None => self.stages.last().expect("non-empty").nominal_bits(d),
        }
    }
}

/// The compiled form of one pipeline node.
enum Node {
    /// A stateless compressor (atom or [`Chain`]) — the canonical impls.
    Plain(Box<dyn Compressor>),
    /// Error feedback around an inner pipeline.
    Ef {
        /// Per-link residual memory.
        fb: ErrorFeedback,
        /// The wrapped pipeline whose codec goes on the wire.
        inner: Box<Node>,
    },
    /// A round-indexed schedule over one compressor family.
    Sched {
        /// The parsed schedule.
        sched: Schedule,
        /// The run length the schedule interpolates over.
        total_rounds: usize,
    },
}

impl Node {
    fn compress_into(
        &mut self,
        x: &[f32],
        round: usize,
        rng: &mut Rng,
        payload: &mut Vec<u8>,
    ) -> CodecMeta {
        match self {
            Node::Plain(c) => c.compress_into(x, rng, payload),
            Node::Ef { fb, inner } => {
                let m = fb.shift(x);
                let meta = inner.compress_into(m, round, rng, payload);
                fb.absorb(&meta, payload);
                meta
            }
            Node::Sched {
                sched,
                total_rounds,
            } => sched.compress_into(round, *total_rounds, x, rng, payload),
        }
    }

    fn nominal_bits(&self, d: usize, round: usize) -> u64 {
        match self {
            Node::Plain(c) => c.nominal_bits(d),
            Node::Ef { inner, .. } => inner.nominal_bits(d, round),
            Node::Sched {
                sched,
                total_rounds,
            } => sched.nominal_bits(round, *total_rounds, d),
        }
    }

    fn display(&self) -> String {
        match self {
            Node::Plain(c) => c.name(),
            Node::Ef { inner, .. } => format!("ef({})", inner.display()),
            Node::Sched { sched, .. } => sched.key(),
        }
    }

    fn has_state(&self) -> bool {
        // Schedules are pure functions of the round index; only error
        // feedback carries memory between calls.
        matches!(self, Node::Ef { .. })
    }

    fn collect_residuals(&self, out: &mut Vec<Vec<f32>>) {
        if let Node::Ef { fb, inner } = self {
            out.push(fb.residual().to_vec());
            inner.collect_residuals(out);
        }
    }

    fn restore_residuals(&mut self, src: &mut std::vec::IntoIter<Vec<f32>>) -> Result<(), String> {
        if let Node::Ef { fb, inner } = self {
            let err = src
                .next()
                .ok_or_else(|| "too few ef residuals for pipeline".to_string())?;
            fb.restore_residual(err);
            inner.restore_residuals(src)?;
        }
        Ok(())
    }
}

/// One link's compression pipeline instance: the compiled spec plus any
/// per-link state (`ef` residuals). Built by
/// [`super::CompressorSpec::build`]; owned per (client, direction) — by
/// `ClientState` for uplinks and by `Federation` for the server broadcast.
pub struct Pipeline {
    node: Node,
    display: String,
    identity: bool,
}

impl Pipeline {
    pub(super) fn from_node(node: Node) -> Pipeline {
        let display = node.display();
        let identity = matches!(&node, Node::Plain(c) if c.name() == "identity");
        Pipeline {
            node,
            display,
            identity,
        }
    }

    pub(super) fn plain(c: Box<dyn Compressor>) -> Pipeline {
        Pipeline::from_node(Node::Plain(c))
    }

    pub(super) fn ef(inner: Pipeline) -> Pipeline {
        Pipeline::from_node(Node::Ef {
            fb: ErrorFeedback::new(),
            inner: Box::new(inner.node),
        })
    }

    pub(super) fn sched(sched: Schedule, total_rounds: usize) -> Pipeline {
        Pipeline::from_node(Node::Sched {
            sched,
            total_rounds,
        })
    }

    /// Human-readable name, e.g. `topk(0.10)+q8` or `ef(topk(0.10))`.
    pub fn name(&self) -> String {
        self.display.clone()
    }

    /// True for the identity pipeline (dense wire format): callers may
    /// skip the codec and ship `Message::dense`, which is byte-identical.
    pub fn is_identity(&self) -> bool {
        self.identity
    }

    /// True when the pipeline carries memory between calls (`ef(...)`).
    /// Stateful pipelines assume **one logical vector stream per
    /// instance** — a driver that multiplexes several streams over one
    /// link (Scaffold's x/c, Δx/Δc pairs) must reject them.
    pub fn has_state(&self) -> bool {
        self.node.has_state()
    }

    /// Encode `x` for communication round `round` into `payload` (cleared
    /// first; capacity reused), updating any per-link state. Byte-identical
    /// to [`Pipeline::compress`].
    pub fn compress_into(
        &mut self,
        x: &[f32],
        round: usize,
        rng: &mut Rng,
        payload: &mut Vec<u8>,
    ) -> CodecMeta {
        self.node.compress_into(x, round, rng, payload)
    }

    /// Encode `x` for communication round `round` into an owned payload.
    pub fn compress(&mut self, x: &[f32], round: usize, rng: &mut Rng) -> Compressed {
        let mut payload = Vec::new();
        let meta = self.compress_into(x, round, rng, &mut payload);
        meta.with_payload(payload)
    }

    /// Worst-case wire bits at round `round` for dimension `d`.
    pub fn nominal_bits(&self, d: usize, round: usize) -> u64 {
        self.node.nominal_bits(d, round)
    }

    /// Snapshot every [`ErrorFeedback`] residual in the pipeline, outermost
    /// first (DFS order). Stateless pipelines return an empty vector. The
    /// companion of [`Pipeline::restore_ef_residuals`] — together they make
    /// stateful `ef(...)` links checkpointable (see [`crate::ckpt`]).
    pub fn ef_residuals(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        self.node.collect_residuals(&mut out);
        out
    }

    /// Restore residuals captured by [`Pipeline::ef_residuals`] on a
    /// freshly-built pipeline of the same spec. Errors if the count does
    /// not match the pipeline's `ef` node count.
    pub fn restore_ef_residuals(&mut self, residuals: Vec<Vec<f32>>) -> Result<(), String> {
        let mut iter = residuals.into_iter();
        self.node.restore_residuals(&mut iter)?;
        if iter.next().is_some() {
            return Err("too many ef residuals for pipeline".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::topk::select_topk_indices;
    use super::super::{parse_spec, CompressorSpec, QuantizeR, TopK};
    use super::*;

    /// The retired seed encoder, reproduced verbatim: TopK selection, then
    /// the fused sparse-quantized layout over the survivors.
    fn seed_double_compress(
        x: &[f32],
        density: f64,
        bits: u32,
        rng: &mut Rng,
    ) -> (Vec<u8>, CodecMeta) {
        let d = x.len();
        let topk = TopK::with_density(density);
        let quant = QuantizeR::new(bits);
        let idx = select_topk_indices(x, topk.k_for(d));
        let vals: Vec<f32> = idx.iter().map(|&i| x[i]).collect();
        let mut payload = Vec::new();
        let meta = quantize::encode_sparse_quantized_into(
            d,
            &idx,
            &vals,
            quant.bits,
            quant.bucket_size,
            rng,
            &mut payload,
        );
        (payload, meta)
    }

    #[test]
    fn chained_topk_q_is_byte_identical_to_the_seed_double_compress() {
        let mut sample = Rng::seed_from_u64(12);
        for d in [64usize, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|_| sample.normal_f32(0.0, 0.4)).collect();
            for (spec, density, bits) in [
                ("topk:0.25|q4", 0.25, 4u32),
                ("topk:0.25+q:4", 0.25, 4),
                ("topk:0.5|q9", 0.5, 9),
            ] {
                let chain = parse_spec(spec).unwrap();
                let mut rng_a = Rng::seed_from_u64(7);
                let mut rng_b = Rng::seed_from_u64(7);
                let got = chain.compress(&x, &mut rng_a);
                let (want_payload, want_meta) = seed_double_compress(&x, density, bits, &mut rng_b);
                assert_eq!(got.payload, want_payload, "{spec} d={d}: wire bytes");
                assert_eq!(got.wire_bits, want_meta.wire_bits, "{spec} d={d}");
                assert_eq!(got.codec, want_meta.codec, "{spec} d={d}");
                // nominal_bits pins the seed DoubleCompress formula.
                let topk = TopK::with_density(density);
                let quant = QuantizeR::new(bits);
                assert_eq!(
                    chain.nominal_bits(d),
                    quantize::sparse_quantized_wire_bits(
                        d,
                        topk.k_for(d),
                        quant.bits,
                        quant.bucket_size
                    ),
                    "{spec} d={d}: nominal"
                );
            }
        }
    }

    #[test]
    fn generic_chain_serializes_with_the_final_stage_codec() {
        let mut rng = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..600).map(|i| ((i as f32) * 0.13).cos()).collect();
        // Quantize first, sparsify second: no fused layout exists, so the
        // wire is the final stage's sparse codec over C1-transformed values.
        let chain = parse_spec("q8|topk:0.1").unwrap();
        let enc = chain.compress(&x, &mut rng);
        let y = chain.decompress(&enc);
        let nnz = y.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= 60, "nnz={nnz}");
        assert!(enc.wire_bits <= chain.nominal_bits(x.len()));
        // Three-stage chains compose too.
        let triple = parse_spec("topk:0.5|q8|topk:0.05").unwrap();
        let enc3 = triple.compress(&x, &mut rng);
        let y3 = triple.decompress(&enc3);
        assert!(y3.iter().filter(|&&v| v != 0.0).count() <= 30);
    }

    #[test]
    fn ef_pipeline_state_persists_across_rounds() {
        let x: Vec<f32> = (0..200).map(|i| ((i as f32) * 0.31).sin()).collect();
        let spec = CompressorSpec::parse("ef(topk:0.1)").unwrap();
        let mut pipe = spec.build(10);
        let mut fresh = spec.build(10);
        let mut rng = Rng::seed_from_u64(5);
        let r0 = pipe.compress(&x, 0, &mut rng);
        // Round 1 on the stateful pipeline differs from a fresh instance:
        // the residual shifts the input.
        let mut rng_a = Rng::seed_from_u64(6);
        let mut rng_b = Rng::seed_from_u64(6);
        let r1_warm = pipe.compress(&x, 1, &mut rng_a);
        let r1_fresh = fresh.compress(&x, 1, &mut rng_b);
        assert_ne!(r1_warm.payload, r1_fresh.payload, "residual must matter");
        assert_eq!(r0.dim, x.len());
        // Determinism: replaying the same inputs and RNG seeds reproduces
        // the same byte trajectory.
        let mut replay = spec.build(10);
        let mut rng0 = Rng::seed_from_u64(5);
        let mut rng1 = Rng::seed_from_u64(6);
        assert_eq!(replay.compress(&x, 0, &mut rng0).payload, r0.payload);
        assert_eq!(replay.compress(&x, 1, &mut rng1).payload, r1_warm.payload);
    }

    #[test]
    fn plain_pipeline_wraps_the_stateless_compressor_bit_for_bit() {
        let x: Vec<f32> = (0..700).map(|i| (i as f32 - 350.0) / 41.0).collect();
        for spec in ["none", "topk:0.2", "q:6", "randk:0.3", "natural", "topk:0.1|q8"] {
            let parsed = CompressorSpec::parse(spec).unwrap();
            let mut pipe = parsed.build(7);
            let stateless = parse_spec(spec).unwrap();
            let mut rng_a = Rng::seed_from_u64(11);
            let mut rng_b = Rng::seed_from_u64(11);
            let via_pipe = pipe.compress(&x, 3, &mut rng_a);
            let direct = stateless.compress(&x, &mut rng_b);
            assert_eq!(via_pipe.payload, direct.payload, "{spec}");
            assert_eq!(via_pipe.wire_bits, direct.wire_bits, "{spec}");
            assert_eq!(via_pipe.codec, direct.codec, "{spec}");
            assert_eq!(pipe.name(), stateless.name(), "{spec}");
        }
        assert!(CompressorSpec::parse("none").unwrap().build(1).is_identity());
        assert!(!CompressorSpec::parse("q8").unwrap().build(1).is_identity());
    }
}
