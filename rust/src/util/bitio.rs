//! Bit-level serialization.
//!
//! Exact wire formats are a first-class concern in this repo: the paper's
//! headline metric is *communicated bits*, so compressors serialize through
//! [`BitWriter`]/[`BitReader`] and the transport layer counts real payload
//! sizes, not nominal estimates.
//!
//! Layout: little-endian within a `u64` accumulator, flushed to bytes LSB
//! first. Fields wider than 57 bits are split.

/// Append-only bit sink.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    /// An empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty writer with `bytes` of pre-reserved output capacity.
    pub fn with_capacity(bytes: usize) -> Self {
        Self {
            buf: Vec::with_capacity(bytes),
            acc: 0,
            nbits: 0,
        }
    }

    /// Write over a recycled buffer: clears `buf` but keeps its capacity,
    /// so steady-state encoders (`compress_into`) allocate nothing. Get the
    /// buffer back from [`BitWriter::finish`].
    pub fn over(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self {
            buf,
            acc: 0,
            nbits: 0,
        }
    }

    /// Write the low `width` bits of `value` (0 <= width <= 64).
    #[inline]
    pub fn write_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width), "value {value} wider than {width} bits");
        if width == 0 {
            return;
        }
        let mut value = value;
        let mut width = width;
        // Fill accumulator; flush full bytes.
        while width > 0 {
            let take = (64 - self.nbits).min(width);
            self.acc |= (value & mask(take)) << self.nbits;
            self.nbits += take;
            value = value.checked_shr(take).unwrap_or(0);
            width -= take;
            while self.nbits >= 8 {
                self.buf.push((self.acc & 0xFF) as u8);
                self.acc >>= 8;
                self.nbits -= 8;
            }
        }
    }

    /// Write one bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write a 32-bit little-endian unsigned integer.
    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write_bits(v as u64, 32);
    }

    /// Pad with zero bits to the next byte boundary (no-op when aligned).
    /// Aligned sections let fixed-width payloads (f32 values) be written
    /// and read via memcpy-speed paths — see `write_f32_aligned`.
    pub fn align_to_byte(&mut self) {
        let rem = (8 - (self.bit_len() % 8) as u32) % 8;
        self.write_bits(0, rem);
    }

    /// Fast path for f32 after `align_to_byte`: appends 4 LE bytes.
    #[inline]
    pub fn write_f32_aligned(&mut self, v: f32) {
        debug_assert_eq!(self.nbits, 0, "writer not byte-aligned");
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an f32 as its 32 IEEE-754 bits (works at any bit offset).
    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write_bits(v.to_bits() as u64, 32);
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.nbits as u64
    }

    /// Flush to a byte vector (pads the final partial byte with zeros).
    pub fn finish(mut self) -> Vec<u8> {
        while self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
        self.buf
    }
}

#[inline(always)]
fn mask(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Sequential bit source over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// A reader over `buf`, positioned at bit 0.
    pub fn new(buf: &'a [u8]) -> Self {
        Self {
            buf,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    /// Read `width` bits; panics past end-of-buffer (wire corruption is a
    /// programming error in this in-process transport).
    #[inline]
    pub fn read_bits(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        if width == 0 {
            return 0;
        }
        let mut out: u64 = 0;
        let mut got: u32 = 0;
        while got < width {
            if self.nbits == 0 {
                assert!(self.pos < self.buf.len(), "BitReader: out of data");
                self.acc = self.buf[self.pos] as u64;
                self.pos += 1;
                self.nbits = 8;
            }
            let take = self.nbits.min(width - got);
            out |= (self.acc & mask(take)) << got;
            self.acc >>= take;
            self.nbits -= take;
            got += take;
        }
        out
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read_bits(1) != 0
    }

    /// Read a 32-bit little-endian unsigned integer.
    #[inline]
    pub fn read_u32(&mut self) -> u32 {
        self.read_bits(32) as u32
    }

    /// Skip to the next byte boundary (mirror of `align_to_byte`).
    pub fn align_to_byte(&mut self) {
        self.nbits = 0;
        self.acc = 0;
    }

    /// Fast path for f32 after `align_to_byte`: reads 4 LE bytes.
    #[inline]
    pub fn read_f32_aligned(&mut self) -> f32 {
        debug_assert_eq!(self.nbits, 0, "reader not byte-aligned");
        assert!(self.pos + 4 <= self.buf.len(), "BitReader: out of data");
        let b = &self.buf[self.pos..self.pos + 4];
        self.pos += 4;
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    /// Read an f32 from its 32 IEEE-754 bits (works at any bit offset).
    #[inline]
    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    /// Bits remaining (counting buffered ones).
    pub fn remaining_bits(&self) -> u64 {
        (self.buf.len() - self.pos) as u64 * 8 + self.nbits as u64
    }
}

/// Minimal bit width needed to store values in [0, n) (at least 1).
pub fn bits_for(n: u64) -> u32 {
    if n <= 1 {
        1
    } else {
        64 - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_u32(0xDEADBEEF);
        w.write_bit(true);
        w.write_bits(0x3FF, 10);
        w.write_f32(-1.5);
        w.write_bits(u64::MAX, 64);
        let nbits = w.bit_len();
        assert_eq!(nbits, 3 + 32 + 1 + 10 + 32 + 64);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_u32(), 0xDEADBEEF);
        assert!(r.read_bit());
        assert_eq!(r.read_bits(10), 0x3FF);
        assert_eq!(r.read_f32(), -1.5);
        assert_eq!(r.read_bits(64), u64::MAX);
    }

    #[test]
    fn roundtrip_many_random_fields() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(101);
        for _ in 0..50 {
            let fields: Vec<(u64, u32)> = (0..200)
                .map(|_| {
                    let width = 1 + rng.below(64) as u32;
                    let value = rng.next_u64() & if width == 64 { u64::MAX } else { (1 << width) - 1 };
                    (value, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, wd) in &fields {
                w.write_bits(v, wd);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, wd) in &fields {
                assert_eq!(r.read_bits(wd), v, "width {wd}");
            }
        }
    }

    #[test]
    fn bit_len_tracks_padding() {
        let mut w = BitWriter::new();
        w.write_bits(1, 3);
        assert_eq!(w.bit_len(), 3);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 1); // padded to one byte
    }

    #[test]
    fn bits_for_boundaries() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
        assert_eq!(bits_for(1 << 20), 20);
        assert_eq!(bits_for((1 << 20) + 1), 21);
    }

    #[test]
    #[should_panic(expected = "out of data")]
    fn read_past_end_panics() {
        let bytes = vec![0u8; 1];
        let mut r = BitReader::new(&bytes);
        r.read_bits(9);
    }
}
