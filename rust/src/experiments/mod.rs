//! Experiment registry: one entry per paper table/figure, each a **named
//! sweep preset** (see [`crate::sweep`]) rather than a hand-written module.
//!
//! The eight bespoke experiment modules the reproduction started with are
//! retired: every training experiment is now a shipped TOML under
//! `experiments/` at the repository root, expanded and executed by the
//! declarative sweep engine. `fedcomloc experiment --id <id>` is a thin
//! alias for `fedcomloc sweep run --preset <name>`; EXPERIMENTS.md maps
//! every paper figure to its TOML, exact CLI invocation, output files, and
//! the summary column that reproduces the figure's y-axis.
//!
//! Absolute numbers differ from the paper (synthetic data, scaled rounds —
//! DESIGN.md §5); the *shape* — orderings, rough factors, crossovers — is
//! the reproduction target. `--scale f` multiplies rounds/dataset sizes
//! toward the paper's full configuration.
//!
//! The one non-sweep entry is Figure 11 ([`data_stats`]): a class-histogram
//! report over Dirichlet partitions, not a training run.

use crate::data::dirichlet::{partition, render_histogram};
use crate::data::{synthetic, DatasetSpec};
use crate::fed::RunConfig;
use crate::metrics::MetricsLog;
use crate::model::{LocalTrainer, ModelSpec};
use crate::sweep;
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

/// Registry spec for FedComLoc-Com with a TopK density (identity at
/// K=100%) — the sweep axis the paper-figure benches share.
pub fn fedcomloc_topk_spec(density: f64) -> String {
    if density >= 1.0 {
        "fedcomloc-com:none".to_string()
    } else {
        format!("fedcomloc-com:topk:{density}")
    }
}

/// Options shared by all experiments (and the `train` subcommand).
pub struct ExpOptions {
    /// Output directory (results/ by default).
    pub out_dir: PathBuf,
    /// Multiplier on the scaled default rounds/sizes (1.0 = testbed scale).
    pub scale: f64,
    /// Compute-plane backend key ([`crate::backend`] registry): "auto",
    /// "native", "native-simd", "native-bf16", "xla" (alias "pjrt").
    pub backend: String,
    /// Artifacts directory for the PJRT plane.
    pub artifacts_dir: PathBuf,
    /// RNG seed every run starts from (sweep `seeds` axes still win).
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            out_dir: PathBuf::from("results"),
            scale: 1.0,
            backend: "auto".into(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            seed: 42,
        }
    }
}

impl ExpOptions {
    /// Build the compute plane for a model spec (the shared
    /// [`crate::runtime::build_trainer`] policy over the [`crate::backend`]
    /// registry).
    pub fn make_trainer(&self, spec: &ModelSpec) -> Arc<dyn LocalTrainer> {
        crate::runtime::build_trainer(&self.backend, &self.artifacts_dir, spec)
    }

    /// The compute plane for a run config (its explicit model, or the
    /// dataset's default pairing). The config's own `backend` key wins over
    /// these options when set ([`crate::backend::effective_backend`]).
    pub fn trainer_for(&self, cfg: &RunConfig) -> Arc<dyn LocalTrainer> {
        let key = crate::backend::effective_backend(&cfg.backend, &self.backend);
        crate::runtime::build_trainer(key, &self.artifacts_dir, &cfg.model_spec())
    }

    /// Apply `--scale` and the seed to a run config (the literally shared
    /// [`crate::config::apply_scale`] transform the sweep engine uses).
    pub fn scale_cfg(&self, mut cfg: RunConfig) -> RunConfig {
        crate::config::apply_scale(&mut cfg, self.scale);
        cfg.seed = self.seed;
        cfg
    }

    /// Save a metrics log under `<out_dir>/<sub>/` (the `train` path; sweep
    /// runs go through the sweep sink instead).
    pub fn save(&self, sub: &str, log: &MetricsLog) {
        let dir = self.out_dir.join(sub);
        if let Err(e) = log.save(&dir) {
            log::warn!("cannot save metrics to {}: {e}", dir.display());
        }
    }

    /// The equivalent sweep-engine options.
    pub fn sweep_options(&self) -> sweep::SweepOptions {
        sweep::SweepOptions {
            out_dir: self.out_dir.clone(),
            scale: self.scale,
            seed: Some(self.seed),
            backend: self.backend.clone(),
            artifacts_dir: self.artifacts_dir.clone(),
            ..sweep::SweepOptions::default()
        }
    }
}

/// Registry entry: a paper table/figure and the sweep preset producing it.
pub struct Experiment {
    /// Stable id consumed by `experiment --id`.
    pub id: &'static str,
    /// The paper table/figure(s) this entry reproduces.
    pub paper_ref: &'static str,
    /// One-line description shown by `list-experiments`.
    pub description: &'static str,
    /// The sweep preset implementing it (`None` = a report, not a sweep).
    pub sweep: Option<&'static str>,
}

/// Every reproducible table/figure, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "table1",
            paper_ref: "Table 1 + Figure 1",
            description: "TopK sparsity ratios on FedMNIST (accuracy, loss/acc vs rounds and bits)",
            sweep: Some("sparsity"),
        },
        Experiment {
            id: "table2",
            paper_ref: "Table 2 + Figures 2, 12",
            description: "Dirichlet heterogeneity α × sparsity K grid on FedMNIST",
            sweep: Some("heterogeneity"),
        },
        Experiment {
            id: "fig3",
            paper_ref: "Figure 3",
            description: "CNN on FedCIFAR10: density sweep, tuned vs fixed stepsize",
            sweep: Some("cifar"),
        },
        Experiment {
            id: "fig5",
            paper_ref: "Figures 5, 7, 14, 15",
            description: "Quantization Q_r sweep (r ∈ {4,8,16,32}) + heterogeneity ablation",
            sweep: Some("quantization"),
        },
        Experiment {
            id: "fig8",
            paper_ref: "Figure 8",
            description: "Expected local iterations 1/p sweep with total-cost metric (τ=0.01)",
            sweep: Some("local_iters"),
        },
        Experiment {
            id: "fig9",
            paper_ref: "Figure 9",
            description: "FedComLoc vs FedAvg / sparseFedAvg / Scaffold / FedDyn",
            sweep: Some("baselines"),
        },
        Experiment {
            id: "fig10",
            paper_ref: "Figure 10",
            description: "Variant ablation: -Com vs -Local vs -Global across densities",
            sweep: Some("variants"),
        },
        Experiment {
            id: "fig11",
            paper_ref: "Figure 11",
            description: "Client class distributions under different Dirichlet α",
            sweep: None,
        },
        Experiment {
            id: "fig16",
            paper_ref: "Figure 16 (Appendix B.3)",
            description: "Double compression: TopK followed by quantization",
            sweep: Some("double"),
        },
    ]
}

/// Look up a registry entry by id.
pub fn by_id(id: &str) -> Option<Experiment> {
    registry().into_iter().find(|e| e.id == id)
}

/// Run one registry entry: resolve its sweep preset and execute it (or the
/// Figure 11 report), printing the resulting summary rows.
pub fn run(exp: &Experiment, opts: &ExpOptions) -> anyhow::Result<()> {
    let Some(preset) = exp.sweep else {
        return data_stats(opts);
    };
    let spec = sweep::preset_by_name(preset)
        .ok_or_else(|| anyhow::anyhow!("experiment '{}' names unknown sweep '{preset}'", exp.id))?
        .map_err(|e| anyhow::anyhow!(e))?;
    let outcome = sweep::run_sweep(&spec, &opts.sweep_options()).map_err(|e| anyhow::anyhow!(e))?;
    println!("\n=== {} ({}) — {} runs ===", exp.id, exp.paper_ref, outcome.units.len());
    println!("{}", crate::sweep::sink::SUMMARY_HEADER);
    for row in &outcome.rows {
        println!("{row}");
    }
    println!(
        "\nsummary: {}/summary.csv   per-round series: {}/rounds/*.jsonl",
        outcome.dir.display(),
        outcome.dir.display()
    );
    Ok(())
}

/// Dirichlet α values rendered by the Figure 11 report.
pub const DATADIST_ALPHAS: [f64; 4] = [0.1, 0.5, 1.0, 1000.0];

/// Figure 11: visualization of client class distributions vs Dirichlet α
/// (a report over the partitioner, not a training sweep).
pub fn data_stats(opts: &ExpOptions) -> anyhow::Result<()> {
    println!("\n=== Figure 11: class distribution across clients (FedCIFAR10 shapes) ===");
    let mut rng = Rng::seed_from_u64(opts.seed);
    let data = synthetic::generate(&DatasetSpec::cifar10(), 5_000, 100, &mut rng).train;
    let mut report = String::new();
    for &alpha in &DATADIST_ALPHAS {
        let mut prng = Rng::seed_from_u64(opts.seed ^ 0xA1FA);
        let p = partition(&data, 100, alpha, 1, &mut prng);
        let text = render_histogram(&p, &data, 10);
        let tv = p.heterogeneity_tv(&data);
        println!("{text}mean TV distance to global distribution: {tv:.4}\n");
        report.push_str(&text);
        report.push_str(&format!("mean TV distance: {tv:.4}\n\n"));
    }
    let dir = opts.out_dir.join("fig11");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("class_distributions.txt"), report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_unique_and_resolves_sweeps() {
        let reg = registry();
        assert_eq!(reg.len(), 9);
        let mut ids: Vec<_> = reg.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 9, "duplicate experiment ids");
        assert!(by_id("table1").is_some());
        assert!(by_id("nope").is_none());
        // Every sweep-backed entry must name a parseable shipped preset.
        for exp in &reg {
            if let Some(name) = exp.sweep {
                let spec = sweep::preset_by_name(name)
                    .unwrap_or_else(|| panic!("{}: unknown preset '{name}'", exp.id))
                    .unwrap_or_else(|e| panic!("{e}"));
                assert!(spec.num_runs() > 0, "{name}");
            }
        }
        assert!(by_id("fig11").unwrap().sweep.is_none());
    }

    #[test]
    fn scaling_applies() {
        let opts = ExpOptions {
            scale: 0.5,
            ..Default::default()
        };
        let cfg = opts.scale_cfg(RunConfig::default_mnist());
        assert_eq!(cfg.rounds, 30);
        assert_eq!(cfg.train_n, 6_000);
    }

    #[test]
    fn trainer_policy_native_for_mlp_auto() {
        let opts = ExpOptions::default();
        let t = opts.make_trainer(&ModelSpec::parse("mlp").unwrap());
        assert_eq!(t.model().name(), "mlp");
    }

    #[test]
    fn trainer_for_uses_config_model_override() {
        let opts = ExpOptions::default();
        let mut cfg = RunConfig::default_mnist();
        cfg.model = Some(ModelSpec::parse("linear:784").unwrap());
        let t = opts.trainer_for(&cfg);
        assert_eq!(t.model().name(), "linear:784");
        assert_eq!(t.dim(), 784 * 10 + 10);
    }

    #[test]
    fn sweep_options_carry_the_experiment_settings() {
        let opts = ExpOptions {
            scale: 0.5,
            seed: 7,
            backend: "native".into(),
            ..Default::default()
        };
        let so = opts.sweep_options();
        assert_eq!(so.scale, 0.5);
        assert_eq!(so.seed, Some(7));
        assert_eq!(so.backend, "native");
        assert!(!so.dry_run && !so.resume);
    }
}
