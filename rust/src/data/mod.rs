//! Dataset substrate: in-memory classification datasets, federated
//! Dirichlet partitioning, and per-client batch loading.
//!
//! The paper evaluates on FedMNIST (MLP) and FedCIFAR10 (CNN) distributed
//! over 100 clients by a Dirichlet label-skew model (§4, Appendix A/B.1).
//! This environment has no network access, so the default datasets are
//! deterministic *synthetic* equivalents with identical shapes and class
//! structure (see [`synthetic`] and DESIGN.md §5); when real MNIST IDX /
//! CIFAR-10 binary files are present under `data/`, [`idx`] loads those
//! instead ([`load_or_synthesize`]).

pub mod dirichlet;
pub mod idx;
pub mod loader;
pub mod synthetic;

use crate::util::rng::Rng;

/// Which benchmark family a dataset mimics (decides shapes and the model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 1×28×28 grayscale, 10 classes (MNIST-shaped; MLP model).
    Mnist,
    /// 3×32×32 color, 10 classes (CIFAR10-shaped; CNN model).
    Cifar10,
}

impl DatasetKind {
    pub fn feature_dim(self) -> usize {
        match self {
            DatasetKind::Mnist => 28 * 28,
            DatasetKind::Cifar10 => 3 * 32 * 32,
        }
    }

    pub fn num_classes(self) -> usize {
        10
    }

    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" | "fedmnist" => Some(DatasetKind::Mnist),
            "cifar" | "cifar10" | "fedcifar10" => Some(DatasetKind::Cifar10),
            _ => None,
        }
    }
}

/// A dense in-memory labelled dataset (row-major features).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub features: Vec<f32>,
    pub labels: Vec<u8>,
    pub feature_dim: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn example(&self, i: usize) -> (&[f32], u8) {
        let lo = i * self.feature_dim;
        (&self.features[lo..lo + self.feature_dim], self.labels[i])
    }

    /// Per-class counts (used by `data-stats` / Figure 11 reproduction).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Train/test pair.
#[derive(Debug, Clone)]
pub struct TrainTest {
    pub train: Dataset,
    pub test: Dataset,
}

/// Load real data from `data_dir` if the well-known files exist, otherwise
/// synthesize (the default in this offline environment). `train_n`/`test_n`
/// bound the sizes (real data is truncated; synthetic is generated at
/// exactly these sizes).
pub fn load_or_synthesize(
    kind: DatasetKind,
    data_dir: &std::path::Path,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> TrainTest {
    if let Some(real) = idx::try_load(kind, data_dir, train_n, test_n) {
        log::info!("loaded real {kind:?} from {}", data_dir.display());
        return real;
    }
    let mut rng = Rng::seed_from_u64(seed);
    synthetic::generate(kind, train_n, test_n, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_shapes() {
        assert_eq!(DatasetKind::Mnist.feature_dim(), 784);
        assert_eq!(DatasetKind::Cifar10.feature_dim(), 3072);
        assert_eq!(DatasetKind::parse("FedMNIST"), Some(DatasetKind::Mnist));
        assert_eq!(DatasetKind::parse("cifar10"), Some(DatasetKind::Cifar10));
        assert_eq!(DatasetKind::parse("imagenet"), None);
    }

    #[test]
    fn load_or_synthesize_falls_back() {
        let tt = load_or_synthesize(
            DatasetKind::Mnist,
            std::path::Path::new("/nonexistent"),
            200,
            50,
            1,
        );
        assert_eq!(tt.train.len(), 200);
        assert_eq!(tt.test.len(), 50);
        assert_eq!(tt.train.feature_dim, 784);
    }
}
