//! The compute-plane backend layer: who executes `forward`/`grad`/
//! `apply_step`, and how.
//!
//! FedComLoc's algorithm layer ([`crate::fed`]) only ever talks to a
//! [`crate::model::LocalTrainer`]. This module owns the *selection* of that
//! trainer: a [`Backend`] is a named compute plane in a string-keyed open
//! registry (the same pattern as `AlgorithmSpec` / `ModelSpec` /
//! `DatasetSpec` / `CompressorSpec`), chosen by the `backend` config key.
//!
//! Registered planes:
//!
//! | key           | plane                                   | numerics vs `native` |
//! |---------------|------------------------------------------|----------------------|
//! | `native`      | scalar [`kernels::ScalarKernels`]        | reference            |
//! | `native-simd` | AVX2 [`kernels::SimdKernels`]            | **bit-identical**    |
//! | `native-bf16` | bf16 activation storage over scalar      | tolerance-pinned     |
//! | `xla`         | AOT HLO via PJRT (`vendor/xla` facade)   | cross-checked        |
//!
//! plus the alias `pjrt` → `xla` (the historical `--trainer pjrt` spelling)
//! and the pseudo-key `auto`, resolved by [`resolve`] to `xla` for the CNN
//! when artifacts exist and `native` otherwise — exactly the policy
//! `runtime::build_trainer` hard-coded before this layer existed.
//!
//! A backend owns two kinds of verbs:
//! * the **model-walk verbs** (`forward_into`, `grad_into`, `apply_step`,
//!   `eval_batch_into`) — reached through the trainer it builds, which for
//!   native planes routes every layer through a
//!   [`kernels::MicroKernels`] set;
//! * the **codec verbs** ([`Backend::pack_topk_keys`],
//!   [`Backend::quantize_grid`]) — the O(d) scans in front of the TopK
//!   selection and the stochastic quantizer. These default to the wide
//!   implementations in [`simd`], which are bit-identical to the scalar
//!   loops and runtime-gated on AVX2, so *every* backend gets the fast
//!   scans; the compress layer calls the same helpers directly.
//!
//! bf16 is never selected silently: `auto` only ever resolves to `native`
//! or `xla`, and `native-bf16` must be spelled out in config (it changes
//! numerics, bounded by the tolerance goldens in
//! `tests/backend_identity.rs`).

pub mod bf16;
pub mod kernels;
pub mod simd;

pub use kernels::{Bf16Kernels, MicroKernels, ScalarKernels, SimdKernels, BF16, SCALAR, SIMD};

use crate::model::{LocalTrainer, Model};
use std::path::Path;
use std::sync::Arc;

/// A named compute plane: builds [`LocalTrainer`]s and owns the
/// codec-side scans. Registered in [`backend_registry`]; selected by the
/// `backend` config key / `--backend` flag.
pub trait Backend: Send + Sync {
    /// Registry key (`native`, `native-simd`, `native-bf16`, `xla`).
    fn key(&self) -> &'static str;

    /// One-line description for `list-backends` and docs.
    fn summary(&self) -> &'static str;

    /// Whether this plane is bit-identical to the `native` reference on
    /// every model walk (and therefore shares its reproducibility pins).
    fn bit_identical(&self) -> bool;

    /// The micro-kernel set native model walks route through. Non-native
    /// planes (xla) return the scalar set, which backs their host-side
    /// fallback paths.
    fn kernels(&self) -> &'static dyn MicroKernels;

    /// Construct the trainer for `model`. `artifacts_dir` is only
    /// consulted by artifact-backed planes (xla). Errors are surfaced to
    /// the caller, which decides the fallback policy.
    fn build(
        &self,
        model: &Model,
        artifacts_dir: &Path,
    ) -> Result<Arc<dyn LocalTrainer>, String>;

    /// TopK threshold scan: fill `keys` with the packed sort keys
    /// `(|x[i]| << 32) | !i` for every coordinate. Default: the wide scan
    /// in [`simd::pack_topk_keys`] (bit-identical to scalar, AVX2-gated at
    /// runtime).
    fn pack_topk_keys(&self, x: &[f32], keys: &mut Vec<u64>) {
        simd::pack_topk_keys(x, keys);
    }

    /// Quantization grid: `out[i] = min(|src[i]|/norm, 1)` — the
    /// normalized magnitudes the stochastic quantizer snaps onto. Default:
    /// the wide scan in [`simd::quantize_grid`].
    fn quantize_grid(&self, src: &[f32], norm: f32, out: &mut [f32]) {
        simd::quantize_grid(src, norm, out);
    }
}

/// The three native planes differ only in which kernel set they hand the
/// model walks, so one struct covers them.
struct NativeBackend {
    key: &'static str,
    summary: &'static str,
    bit_identical: bool,
    kernels: &'static dyn MicroKernels,
}

impl Backend for NativeBackend {
    fn key(&self) -> &'static str {
        self.key
    }
    fn summary(&self) -> &'static str {
        self.summary
    }
    fn bit_identical(&self) -> bool {
        self.bit_identical
    }
    fn kernels(&self) -> &'static dyn MicroKernels {
        self.kernels
    }
    fn build(
        &self,
        model: &Model,
        _artifacts_dir: &Path,
    ) -> Result<Arc<dyn LocalTrainer>, String> {
        Ok(Arc::new(crate::model::native::NativeTrainer::with_kernels(
            model.clone(),
            self.kernels,
        )))
    }
}

/// The AOT plane: compiled HLO executed through the PJRT facade. Formerly
/// a special case inside `runtime::build_trainer`; now just another
/// registry entry.
struct XlaBackend;

impl Backend for XlaBackend {
    fn key(&self) -> &'static str {
        "xla"
    }
    fn summary(&self) -> &'static str {
        "AOT-compiled HLO via PJRT (requires artifacts/; alias: pjrt)"
    }
    fn bit_identical(&self) -> bool {
        false
    }
    fn kernels(&self) -> &'static dyn MicroKernels {
        &SCALAR
    }
    fn build(
        &self,
        model: &Model,
        artifacts_dir: &Path,
    ) -> Result<Arc<dyn LocalTrainer>, String> {
        crate::runtime::PjrtTrainer::load(artifacts_dir, model)
            .map(|t| Arc::new(t) as Arc<dyn LocalTrainer>)
            .map_err(|e| e.to_string())
    }
}

static NATIVE: NativeBackend = NativeBackend {
    key: "native",
    summary: "pure-Rust scalar compute plane (the bit-identity reference)",
    bit_identical: true,
    kernels: &SCALAR,
};
static NATIVE_SIMD: NativeBackend = NativeBackend {
    key: "native-simd",
    summary: "explicit AVX2 lanes in the matmul micro-kernels; bit-identical to native",
    bit_identical: true,
    kernels: &SIMD,
};
static NATIVE_BF16: NativeBackend = NativeBackend {
    key: "native-bf16",
    summary: "bf16 activation storage over scalar arithmetic (opt-in; tolerance-pinned)",
    bit_identical: false,
    kernels: &BF16,
};
static XLA: XlaBackend = XlaBackend;

static REGISTRY: [&dyn Backend; 4] = [&NATIVE, &NATIVE_SIMD, &NATIVE_BF16, &XLA];

/// All registered compute planes, in listing order.
pub fn backend_registry() -> &'static [&'static dyn Backend] {
    &REGISTRY
}

/// Look up a backend by key, resolving the `pjrt` alias. `auto` is not a
/// backend (see [`resolve`]) and returns `None` here.
pub fn lookup(key: &str) -> Option<&'static dyn Backend> {
    let key = if key == "pjrt" { "xla" } else { key };
    REGISTRY.iter().copied().find(|b| b.key() == key)
}

/// Validate and canonicalize a user-supplied backend key: trims, resolves
/// the `pjrt` alias, accepts the pseudo-key `auto`, and rejects anything
/// not in the registry with a message listing the known keys.
pub fn canonical_backend_key(key: &str) -> Result<String, String> {
    let k = key.trim();
    let k = if k == "pjrt" { "xla" } else { k };
    if k == "auto" {
        return Ok("auto".to_string());
    }
    if lookup(k).is_some() {
        Ok(k.to_string())
    } else {
        let known: Vec<&str> = REGISTRY.iter().map(|b| b.key()).collect();
        Err(format!(
            "unknown backend `{key}` (known: auto, {}, pjrt)",
            known.join(", ")
        ))
    }
}

/// Resolve a requested backend key to a concrete registry key for `model`.
///
/// `auto` (and the empty string) keep the historical trainer policy: the
/// XLA plane for the CNN when artifacts are present — measured faster for
/// convolutions in EXPERIMENTS.md §Perf — and the scalar native plane for
/// everything else. `auto` never resolves to a plane whose numerics differ
/// silently (`native-bf16` must be requested explicitly). Unknown keys
/// warn and fall back to the `auto` policy, matching the old permissive
/// `--trainer` parsing.
pub fn resolve(requested: &str, model: &Model, artifacts_ok: bool) -> &'static str {
    let req = if requested == "pjrt" { "xla" } else { requested };
    if !req.is_empty() && req != "auto" {
        if let Some(b) = lookup(req) {
            return b.key();
        }
        log::warn!("unknown backend `{requested}`; using the auto policy");
    }
    if model.artifact_name() == "cnn" && artifacts_ok {
        "xla"
    } else {
        "native"
    }
}

/// Combine the per-run config key with the CLI/default option: an explicit
/// config `backend` wins; `auto` (the config default) defers to the
/// option, so `--backend` keeps working for runs that don't pin a plane.
pub fn effective_backend<'a>(cfg_backend: &'a str, opt_backend: &'a str) -> &'a str {
    if !cfg_backend.is_empty() && cfg_backend != "auto" {
        cfg_backend
    } else {
        opt_backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    #[test]
    fn registry_keys_are_stable_and_unique() {
        let keys: Vec<&str> = backend_registry().iter().map(|b| b.key()).collect();
        assert_eq!(keys, vec!["native", "native-simd", "native-bf16", "xla"]);
    }

    #[test]
    fn lookup_resolves_the_pjrt_alias() {
        assert_eq!(lookup("pjrt").unwrap().key(), "xla");
        assert_eq!(lookup("native-simd").unwrap().key(), "native-simd");
        assert!(lookup("auto").is_none());
        assert!(lookup("cuda").is_none());
    }

    #[test]
    fn canonicalization_accepts_known_and_rejects_unknown() {
        assert_eq!(canonical_backend_key("auto").unwrap(), "auto");
        assert_eq!(canonical_backend_key(" native-simd ").unwrap(), "native-simd");
        assert_eq!(canonical_backend_key("pjrt").unwrap(), "xla");
        let err = canonical_backend_key("gpu").unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
        assert!(err.contains("native-simd"), "{err}");
    }

    #[test]
    fn auto_policy_matches_the_historical_trainer_policy() {
        let mlp = ModelSpec::parse("mlp").unwrap().build();
        let cnn = ModelSpec::parse("cnn").unwrap().build();
        assert_eq!(resolve("auto", &mlp, true), "native");
        assert_eq!(resolve("auto", &cnn, false), "native");
        assert_eq!(resolve("auto", &cnn, true), "xla");
        assert_eq!(resolve("native", &cnn, true), "native");
        assert_eq!(resolve("native-simd", &mlp, false), "native-simd");
        assert_eq!(resolve("pjrt", &mlp, false), "xla");
        // Unknown keys keep the old permissive fallback-to-auto behaviour.
        assert_eq!(resolve("not-a-backend", &mlp, false), "native");
    }

    #[test]
    fn auto_never_resolves_to_a_numerics_changing_plane() {
        let mlp = ModelSpec::parse("mlp").unwrap().build();
        let cnn = ModelSpec::parse("cnn").unwrap().build();
        for (model, artifacts) in [(&mlp, false), (&mlp, true), (&cnn, false), (&cnn, true)] {
            let key = resolve("auto", model, artifacts);
            let b = lookup(key).unwrap();
            assert!(
                b.bit_identical() || b.key() == "xla",
                "auto resolved to silent-numerics plane {key}"
            );
            assert_ne!(key, "native-bf16");
        }
    }

    #[test]
    fn effective_backend_prefers_explicit_config() {
        assert_eq!(effective_backend("native-simd", "auto"), "native-simd");
        assert_eq!(effective_backend("auto", "native"), "native");
        assert_eq!(effective_backend("", "xla"), "xla");
    }

    #[test]
    fn native_backends_build_trainers_with_their_kernel_sets() {
        let model = ModelSpec::parse("mlp").unwrap().build();
        let dir = std::path::Path::new("/nonexistent");
        for key in ["native", "native-simd", "native-bf16"] {
            let b = lookup(key).unwrap();
            let t = b.build(&model, dir).expect("native planes always build");
            assert_eq!(t.dim(), model.layout.dim);
        }
        // The xla plane surfaces its error instead of silently falling back.
        assert!(XLA.build(&model, dir).is_err());
    }

    #[test]
    fn codec_verbs_match_the_scalar_reference() {
        let b = lookup("native-simd").unwrap();
        let x = [0.5f32, -2.0, 0.0, 3.5, -0.25];
        let mut keys = Vec::new();
        b.pack_topk_keys(&x, &mut keys);
        let reference: Vec<u64> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| ((v.abs().to_bits() as u64) << 32) | (!(i as u32)) as u64)
            .collect();
        assert_eq!(keys, reference);
        let norm = crate::tensor::norm2(&x);
        let mut grid = vec![0.0; x.len()];
        b.quantize_grid(&x, norm, &mut grid);
        for (g, &v) in grid.iter().zip(x.iter()) {
            assert_eq!(g.to_bits(), (v.abs() / norm).min(1.0).to_bits());
        }
    }
}
