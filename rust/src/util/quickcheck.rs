//! Property-testing mini-framework (proptest is not vendored offline).
//!
//! Provides seeded generators and a [`check`] driver that runs a property
//! over many random cases and, on failure, greedily shrinks the input before
//! reporting. Coordinator invariants (control-variate sums, routing,
//! compression round-trips) are verified with this in `rust/tests/`.
//!
//! ```
//! use fedcomloc::util::quickcheck::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let xs = g.vec_f32(0..=64, -10.0, 10.0);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err(format!("{xs:?}")) }
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::RangeInclusive;

/// Per-case generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Shrink pass index: 0 = full-size cases; higher = smaller cases.
    size_scale: f64,
}

impl Gen {
    fn new(seed: u64, size_scale: f64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed),
            size_scale,
        }
    }

    /// Direct access to the case's RNG stream.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer uniformly from an inclusive range, biased smaller when
    /// shrinking.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        if lo == hi {
            return lo;
        }
        let span = hi - lo;
        let scaled = ((span as f64) * self.size_scale).ceil() as usize;
        lo + self.rng.below_usize(scaled.max(1).min(span) + 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform_f32()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Vector of f32 with length drawn from `len`, values in [lo, hi),
    /// with occasional special values (0, ±extremes) mixed in.
    pub fn vec_f32(&mut self, len: RangeInclusive<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| match self.rng.below(16) {
                0 => 0.0,
                1 => lo,
                2 => hi,
                _ => self.f32_in(lo, hi),
            })
            .collect()
    }

    /// Vector of indices `< below` with length drawn from `len`.
    pub fn vec_usize(&mut self, len: RangeInclusive<usize>, below: usize) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.below_usize(below)).collect()
    }

    /// One of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

/// Run `prop` over `cases` random inputs. On failure, retries with smaller
/// generated sizes (a light-weight shrink) and panics with the smallest
/// failing case's message and the reproducing seed.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = env_seed().unwrap_or(0xFED_C0410C);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-run same seed with progressively smaller sizes and
            // report the smallest case that still fails.
            let mut smallest = msg;
            for scale in [0.5, 0.25, 0.1, 0.02] {
                let mut g = Gen::new(seed, scale);
                if let Err(m) = prop(&mut g) {
                    smallest = m;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, rerun with FEDCOMLOC_QC_SEED={base_seed}):\n  {smallest}"
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("FEDCOMLOC_QC_SEED").ok()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 100, |g| {
            let x = g.f32_in(-100.0, 100.0);
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_name() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generated_vectors_respect_bounds() {
        check("vec bounds", 100, |g| {
            let xs = g.vec_f32(0..=32, -2.0, 3.0);
            if xs.len() <= 32 && xs.iter().all(|&x| (-2.0..=3.0).contains(&x)) {
                Ok(())
            } else {
                Err(format!("{xs:?}"))
            }
        });
    }

    #[test]
    fn usize_in_respects_range() {
        check("usize_in", 200, |g| {
            let x = g.usize_in(3..=17);
            if (3..=17).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }
}
