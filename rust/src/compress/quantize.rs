//! Stochastic binary quantizer Q_r (paper Definition 3.2, after QSGD —
//! Alistarh et al., 2017).
//!
//! For x ≠ 0:  Q_r(x)_i = ‖x‖₂ · sgn(x_i) · ξ_i,  where ξ_i stochastically
//! rounds y_i = |x_i|/‖x‖₂ onto the grid {0, 1/2^r, …, 2^r/2^r}: up with
//! probability 2^r·y_i − ⌊2^r·y_i⌋, down otherwise. This makes Q_r unbiased
//! (E[Q_r(x)] = x) with minimal variance over distributions supported on the
//! grid. Q_r(0) = 0.
//!
//! **Bucketing.** Normalizing by the global ‖x‖₂ of a 10⁵-dim model makes
//! y_i ≈ 1/√d ≈ 0.003, far below the 2^-r grid for small r — quantization
//! would zero the model. Like QSGD in practice (Alistarh et al. use bucket
//! size 512; per-tensor quantization is the same idea), we quantize in
//! buckets of `bucket_size` coordinates, each with its own norm.
//!
//! Wire format per bucket: 32-bit norm + per coordinate 1 sign bit +
//! (r+1)-bit level (levels range over 0..=2^r). Exact cost:
//! ⌈d/B⌉·32 + d·(r+2) bits — we count *real* bits, so "16-bit" quantization
//! costs ≈18 bits/coordinate on our wire, slightly above the paper's
//! nominal r bits/coordinate (EXPERIMENTS.md notes this).

use super::{Codec, CodecMeta, Compressor};
use crate::util::bitio::{bits_for, BitReader, BitWriter};
use crate::util::rng::Rng;

/// Default normalization-bucket size (coordinates per bucket norm).
pub const DEFAULT_BUCKET: usize = 1024;

/// Stack-buffer span for the two-pass grid scan: pass 1 computes the
/// normalized magnitudes `min(|x|/norm, 1)` for a span through the wide
/// scan in [`crate::backend::simd::quantize_grid`] (elementwise, so
/// bit-identical to the former inline division), pass 2 runs the
/// reduction-order-sensitive part — sign bits, RNG draws, bit writes — in
/// the exact original per-coordinate order. A fixed-size stack array keeps
/// the hot path allocation-free (`tests/alloc_steady_state.rs`).
const GRID_SPAN: usize = 256;

/// The unbiased stochastic quantizer Q_r (Definition 3.2).
#[derive(Debug, Clone, Copy)]
pub struct QuantizeR {
    /// Number of quantization bits r (levels = 2^r), 1..=32.
    pub bits: u32,
    /// Coordinates per normalization bucket (see module docs).
    pub bucket_size: usize,
}

impl QuantizeR {
    /// Q_r at the default bucket size.
    pub fn new(bits: u32) -> Self {
        Self::with_bucket(bits, DEFAULT_BUCKET)
    }

    /// Q_r with an explicit normalization-bucket size.
    pub fn with_bucket(bits: u32, bucket_size: usize) -> Self {
        assert!((1..=32).contains(&bits), "bits in 1..=32");
        assert!(bucket_size > 0);
        Self { bits, bucket_size }
    }

    #[inline]
    fn levels(&self) -> u64 {
        1u64 << self.bits
    }

    /// Stochastically quantize one normalized magnitude y = |x_i|/‖x‖ ∈ [0,1]
    /// to an integer level in 0..=2^r.
    #[inline]
    fn quantize_level(&self, y: f32, rng: &mut Rng) -> u64 {
        let s = self.levels() as f64;
        let scaled = (y as f64 * s).clamp(0.0, s);
        let lo = scaled.floor();
        let frac = scaled - lo;
        let level = if rng.uniform() < frac { lo + 1.0 } else { lo };
        level as u64
    }
}

impl Compressor for QuantizeR {
    fn name(&self) -> String {
        format!("q{}", self.bits)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta {
        let d = x.len();
        let level_bits = self.bits + 1;
        let mut ybuf = [0.0f32; GRID_SPAN];
        let mut w = BitWriter::over(std::mem::take(payload));
        for bucket in x.chunks(self.bucket_size) {
            // Non-finite norms (diverged models) encode as 0 so encoder and
            // decoder agree on the bucket being skipped.
            let raw = crate::tensor::norm2(bucket);
            let norm = if raw.is_finite() { raw } else { 0.0 };
            w.write_f32(norm);
            if norm > 0.0 {
                for span in bucket.chunks(GRID_SPAN) {
                    let y = &mut ybuf[..span.len()];
                    crate::backend::simd::quantize_grid(span, norm, y);
                    for (&v, &yv) in span.iter().zip(y.iter()) {
                        w.write_bit(v.is_sign_negative());
                        w.write_bits(self.quantize_level(yv, rng), level_bits);
                    }
                }
            }
        }
        let wire_bits = w.bit_len();
        *payload = w.finish();
        CodecMeta {
            wire_bits,
            dim: d,
            codec: Codec::Quantized {
                bits: self.bits,
                bucket: self.bucket_size as u32,
            },
        }
    }

    fn decompress(&self, c: &super::Compressed) -> Vec<f32> {
        // The bucket size travels in the codec tag, so decoding never
        // consults this instance's configuration.
        super::decode_payload(c.codec, c.dim, &c.payload)
    }

    fn nominal_bits(&self, d: usize) -> u64 {
        32 * d.div_ceil(self.bucket_size) as u64 + d as u64 * (self.bits as u64 + 2)
    }

    fn quantizer_params(&self) -> Option<(u32, usize)> {
        Some((self.bits, self.bucket_size))
    }

    fn apply(&self, x: &mut [f32], rng: &mut Rng) {
        // In-place semantic twin of encode→decode, mirroring both loops
        // exactly — same per-bucket norm handling, same per-coordinate RNG
        // draw order, same `norm · level / 2^r` float arithmetic — so the
        // result is bit-identical to the codec round-trip (pinned below)
        // without serializing. This is the path generic chains take for
        // their leading stages.
        let s = self.levels() as f32;
        let mut ybuf = [0.0f32; GRID_SPAN];
        for bucket in x.chunks_mut(self.bucket_size) {
            let raw = crate::tensor::norm2(bucket);
            let norm = if raw.is_finite() { raw } else { 0.0 };
            if norm > 0.0 {
                for span in bucket.chunks_mut(GRID_SPAN) {
                    let y = &mut ybuf[..span.len()];
                    crate::backend::simd::quantize_grid(span, norm, y);
                    for (v, &yv) in span.iter_mut().zip(y.iter()) {
                        let neg = v.is_sign_negative();
                        let level = self.quantize_level(yv, rng) as f32;
                        let mag = norm * level / s;
                        *v = if neg { -mag } else { mag };
                    }
                }
            } else {
                bucket.fill(0.0);
            }
        }
    }
}

/// Decoder for [`Codec::Quantized`] payloads into a caller buffer (fully
/// overwritten; see [`super::decode_payload_into`]).
pub(super) fn decode_quantized_into(
    dim: usize,
    payload: &[u8],
    bits: u32,
    bucket: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), dim);
    let mut r = BitReader::new(payload);
    let s = (1u64 << bits) as f32;
    let level_bits = bits + 1;
    let mut pos = 0usize;
    while pos < dim {
        let take = (dim - pos).min(bucket);
        let norm = r.read_f32();
        if norm <= 0.0 {
            out[pos..pos + take].fill(0.0);
        } else {
            for slot in out[pos..pos + take].iter_mut() {
                let neg = r.read_bit();
                let level = r.read_bits(level_bits) as f32;
                let mag = norm * level / s;
                *slot = if neg { -mag } else { mag };
            }
        }
        pos += take;
    }
}

/// Encoder for the fused sparsify-then-quantize codec (the wire format of
/// a sparsifier→quantizer [`super::Chain`], Appendix B.3 double
/// compression): 32-bit K, then per survivor-bucket (`bucket` survivors) a
/// 32-bit norm followed by (index, sign, level) triples. Bucketing over
/// the *survivor sequence* matters just as for the dense quantizer: a
/// single global norm at r=4 destroys the small survivors and destabilizes
/// training (observed as divergence in the Figure 16 runs).
pub(super) fn encode_sparse_quantized_into(
    d: usize,
    idx: &[usize],
    vals: &[f32],
    bits: u32,
    bucket: usize,
    rng: &mut Rng,
    payload: &mut Vec<u8>,
) -> CodecMeta {
    assert_eq!(idx.len(), vals.len());
    let q = QuantizeR::with_bucket(bits, bucket);
    let idx_bits = bits_for(d as u64);
    let level_bits = bits + 1;
    let mut ybuf = [0.0f32; GRID_SPAN];
    let mut w = BitWriter::over(std::mem::take(payload));
    w.write_u32(idx.len() as u32);
    for (ichunk, vchunk) in idx.chunks(bucket).zip(vals.chunks(bucket)) {
        let raw = crate::tensor::norm2(vchunk);
        let norm = if raw.is_finite() { raw } else { 0.0 };
        w.write_f32(norm);
        for (ispan, vspan) in ichunk.chunks(GRID_SPAN).zip(vchunk.chunks(GRID_SPAN)) {
            let y = &mut ybuf[..vspan.len()];
            if norm > 0.0 {
                crate::backend::simd::quantize_grid(vspan, norm, y);
            }
            for (j, (&i, &v)) in ispan.iter().zip(vspan).enumerate() {
                w.write_bits(i as u64, idx_bits);
                if norm > 0.0 {
                    w.write_bit(v.is_sign_negative());
                    w.write_bits(q.quantize_level(y[j], rng), level_bits);
                }
            }
        }
    }
    let wire_bits = w.bit_len();
    *payload = w.finish();
    CodecMeta {
        wire_bits,
        dim: d,
        codec: Codec::SparseQuantized {
            bits,
            bucket: bucket as u32,
        },
    }
}

/// Decoder for [`Codec::SparseQuantized`] payloads into a caller buffer
/// (fully overwritten; see [`super::decode_payload_into`]).
pub(super) fn decode_sparse_quantized_into(
    dim: usize,
    payload: &[u8],
    bits: u32,
    bucket: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), dim);
    out.fill(0.0);
    let mut r = BitReader::new(payload);
    let k = r.read_u32() as usize;
    let idx_bits = bits_for(dim as u64);
    let s = (1u64 << bits) as f32;
    let level_bits = bits + 1;
    let mut remaining = k;
    while remaining > 0 {
        let take = remaining.min(bucket);
        let norm = r.read_f32();
        for _ in 0..take {
            let i = r.read_bits(idx_bits) as usize;
            if norm > 0.0 {
                let neg = r.read_bit();
                let level = r.read_bits(level_bits) as f32;
                let mag = norm * level / s;
                out[i] = if neg { -mag } else { mag };
            }
        }
        remaining -= take;
    }
}

/// Exact bit length of the sparse-quantized layout for `k` survivors when
/// every survivor bucket has a nonzero norm (the maximal case the encoder
/// can emit): 32-bit K header, a 32-bit norm per ⌈k/bucket⌉ survivor
/// bucket, and per survivor an index, a sign bit, and a (bits+1)-bit level.
/// Shared with the fused chain's `nominal_bits` so formula and encoder
/// cannot drift.
pub(super) fn sparse_quantized_wire_bits(d: usize, k: usize, bits: u32, bucket: usize) -> u64 {
    let buckets = k.div_ceil(bucket) as u64;
    32 + 32 * buckets + k as u64 * (bits_for(d as u64) as u64 + 1 + (bits as u64 + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{l2_distance, norm2};

    #[test]
    fn zero_vector_maps_to_zero() {
        let mut rng = Rng::seed_from_u64(0);
        let x = vec![0.0f32; 17];
        let q = QuantizeR::new(4);
        let c = q.compress(&x, &mut rng);
        assert_eq!(q.decompress(&c), x);
        // Wire cost for the zero vector is just the bucket-norm header.
        assert_eq!(c.wire_bits, 32);
    }

    #[test]
    fn unbiasedness() {
        // E[Q_r(x)] = x: average many independent quantizations.
        let mut rng = Rng::seed_from_u64(1);
        let x = vec![0.3f32, -0.5, 0.01, 0.8, -0.02];
        let q = QuantizeR::new(2);
        let trials = 20_000;
        let mut acc = vec![0.0f64; x.len()];
        for _ in 0..trials {
            let c = q.compress(&x, &mut rng);
            for (a, v) in acc.iter_mut().zip(q.decompress(&c)) {
                *a += v as f64;
            }
        }
        for (a, &xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!(
                (mean - xi as f64).abs() < 0.01,
                "mean={mean} expected={xi}"
            );
        }
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Rng::seed_from_u64(2);
        let x: Vec<f32> = (0..256).map(|i| ((i as f32) * 0.37).sin()).collect();
        let q = QuantizeR::new(16);
        let c = q.compress(&x, &mut rng);
        let y = q.decompress(&c);
        let rel = l2_distance(&x, &y) / norm2(&x);
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn bucketed_quantization_is_finer_than_global() {
        // With per-bucket norms, a vector with one huge bucket does not
        // destroy the resolution of the others.
        let mut rng = Rng::seed_from_u64(11);
        let mut x = vec![0.01f32; 2048];
        for v in x.iter_mut().take(1024) {
            *v = 100.0;
        }
        // Bucketed: the small bucket keeps its own norm (~0.32), so its
        // values stochastically round to 0 or one grid cell (~0.02) — many
        // survive as nonzero and the bucket mean is preserved.
        let q_bucketed = QuantizeR::with_bucket(4, 1024);
        let y = q_bucketed.decompress(&q_bucketed.compress(&x, &mut rng));
        let nnz_bucketed = y[1024..].iter().filter(|&&v| v != 0.0).count();
        let mean_bucketed: f32 = y[1024..].iter().sum::<f32>() / 1024.0;
        assert!(nnz_bucketed > 100, "bucketed nnz {nnz_bucketed}");
        assert!((mean_bucketed - 0.01).abs() < 0.005, "mean {mean_bucketed}");
        // Global norm (~3200): grid cell ~200 ⇒ the small half is wiped out.
        let q_global = QuantizeR::with_bucket(4, 4096);
        let z = q_global.decompress(&q_global.compress(&x, &mut rng));
        let nnz_global = z[1024..].iter().filter(|&&v| v != 0.0).count();
        assert!(
            nnz_global < nnz_bucketed / 10,
            "global nnz {nnz_global} vs bucketed {nnz_bucketed}"
        );
    }

    #[test]
    fn low_bits_error_bounded_by_grid() {
        let mut rng = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 11.0).collect();
        let norm = norm2(&x);
        let q = QuantizeR::new(4);
        let c = q.compress(&x, &mut rng);
        let y = q.decompress(&c);
        // Per-coordinate error at most one grid cell: norm / 2^r.
        for (xi, yi) in x.iter().zip(&y) {
            assert!((xi - yi).abs() <= norm / 16.0 + 1e-6, "{xi} vs {yi}");
        }
    }

    #[test]
    fn signs_preserved() {
        let mut rng = Rng::seed_from_u64(4);
        let x = vec![1.0f32, -1.0, 0.5, -0.5];
        let q = QuantizeR::new(8);
        let c = q.compress(&x, &mut rng);
        for (xi, yi) in x.iter().zip(q.decompress(&c)) {
            assert!(xi * yi >= 0.0, "sign flip: {xi} -> {yi}");
        }
    }

    #[test]
    fn wire_bits_formula() {
        let mut rng = Rng::seed_from_u64(5);
        let d: usize = 1001;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for bits in [1u32, 4, 8, 16, 32] {
            let q = QuantizeR::new(bits);
            let c = q.compress(&x, &mut rng);
            let buckets = d.div_ceil(q.bucket_size) as u64;
            assert_eq!(c.wire_bits, 32 * buckets + d as u64 * (bits as u64 + 2));
            assert!(c.wire_bits <= q.nominal_bits(d));
        }
    }

    #[test]
    fn compression_beats_dense_below_30_bits() {
        let mut rng = Rng::seed_from_u64(6);
        let d = 4096;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let c16 = QuantizeR::new(16).compress(&x, &mut rng);
        let c4 = QuantizeR::new(4).compress(&x, &mut rng);
        assert!(c16.wire_bits < super::super::dense_bits(d));
        assert!(c4.wire_bits < c16.wire_bits / 2);
    }

    #[test]
    fn apply_is_bit_identical_to_codec_roundtrip() {
        let mut sample = Rng::seed_from_u64(13);
        for d in [1usize, 63, 1000, 2500] {
            let x: Vec<f32> = (0..d).map(|_| sample.normal_f32(0.0, 0.7)).collect();
            for q in [QuantizeR::new(4), QuantizeR::with_bucket(7, 100)] {
                let mut rng_a = Rng::seed_from_u64(5);
                let mut rng_b = Rng::seed_from_u64(5);
                let via_wire = q.decompress(&q.compress(&x, &mut rng_a));
                let mut via_apply = x.clone();
                q.apply(&mut via_apply, &mut rng_b);
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&via_wire), bits(&via_apply), "q{} d={d}", q.bits);
                // And the RNG streams stay in lockstep afterwards.
                assert_eq!(rng_a.next_u64(), rng_b.next_u64());
            }
        }
    }

    #[test]
    fn sparse_quantized_roundtrip() {
        let mut rng = Rng::seed_from_u64(7);
        let d = 500;
        let idx = vec![3usize, 77, 178, 400, 499];
        let vals = vec![1.0f32, -2.0, 0.5, -0.25, 3.0];
        let mut payload = Vec::new();
        let meta = encode_sparse_quantized_into(d, &idx, &vals, 8, DEFAULT_BUCKET, &mut rng, &mut payload);
        let c = meta.with_payload(payload);
        let y = super::decode_payload(c.codec, c.dim, &c.payload);
        assert_eq!(y.len(), d);
        let norm = norm2(&vals);
        for (j, &i) in idx.iter().enumerate() {
            assert!((y[i] - vals[j]).abs() <= norm / 256.0 + 1e-6);
        }
        assert_eq!(y.iter().filter(|&&v| v != 0.0).count(), idx.len());
    }
}
