//! Minimal offline logging facade.
//!
//! The testbed vendors no external crates; this is an API-compatible
//! implementation of the subset of the `log` crate the repository uses:
//! the five level macros, the [`Log`] trait, [`set_logger`]/[`set_max_level`],
//! and the [`Level`]/[`LevelFilter`] enums with the standard ordering
//! (`Error < Warn < Info < Debug < Trace`).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Global maximum verbosity, `Off` disabling everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a record, available to [`Log::enabled`].
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: level plus preformatted arguments.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A log sink. Implementations are installed once via [`set_logger`].
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling applied before the logger's own filter.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: route one record through the installed logger.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, ::core::module_path!(), ::core::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_log_crate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
        assert_eq!(format!("{}", Level::Info), "INFO");
    }

    #[test]
    fn dispatch_without_logger_is_silent() {
        set_max_level(LevelFilter::Trace);
        info!("no logger installed: must not panic ({})", 42);
    }
}
