//! Figure 8: expected number of local iterations (1/p) sweep.
//!
//! p ∈ {0.05, 0.1, 0.2, 0.3, 0.5} with K = 30% TopK (paper §4.5); reports
//! accuracy/loss against communication rounds AND against the total-cost
//! metric (communication round = 1, local iteration = τ = 0.01).

use super::ExpOptions;
use crate::fed::{run as fed_run, RunConfig};

pub const PS: [f64; 5] = [0.05, 0.1, 0.2, 0.3, 0.5];
pub const DENSITY: f64 = 0.30;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let trainer = opts.trainer_for(&RunConfig::default_mnist());
    println!("\n=== Figure 8: local-iteration budget (K=30%, τ=0.01) ===");
    println!(
        "{:<8}{:>10}{:>12}{:>14}{:>14}{:>12}",
        "p", "E[iters]", "best_acc", "local_iters", "total_cost", "final_loss"
    );
    for &p in &PS {
        let cfg = RunConfig {
            p,
            ..opts.scale_cfg(RunConfig::default_mnist())
        };
        let spec = super::algo(&format!("fedcomloc-com:topk:{DENSITY}"))?;
        log::info!("fig8: p={p}");
        let log = fed_run(&cfg, trainer.clone(), &spec);
        let acc = log.best_accuracy().unwrap_or(0.0);
        let total_iters: usize = log.records.iter().map(|r| r.local_steps).sum();
        let cost = log.records.last().map(|r| r.total_cost).unwrap_or(0.0);
        let loss = log.final_train_loss().unwrap_or(f64::NAN);
        opts.save("fig8", &log);
        println!(
            "{p:<8}{:>10.1}{acc:>12.4}{total_iters:>14}{cost:>14.2}{loss:>12.4}",
            1.0 / p
        );
    }
    println!("(paper finding: smaller p — more local training — accelerates and can improve final accuracy)");
    Ok(())
}
