//! FedAvg (McMahan et al., 2016/2017) and sparseFedAvg (paper §4.7).
//!
//! Round shape: sample S_r; broadcast x; each client runs E local SGD steps
//! (no control variates — h is ignored by passing zeros); clients upload
//! their model (TopK-compressed for sparseFedAvg, exactly mirroring
//! FedComLoc-Com's wire format so the Fig. 9 bits-axis comparison is
//! apples-to-apples); server averages.

use super::transport::send_through;
use super::{Federation, RoundLogger, RunConfig};
use crate::compress::Compressor;
use crate::metrics::MetricsLog;

pub fn run(cfg: &RunConfig, fed: &mut Federation, compressor: &dyn Compressor) -> MetricsLog {
    let algo = if compressor.name() == "identity" {
        "fedavg".to_string()
    } else {
        format!("sparsefedavg[{}]", compressor.name())
    };
    let name = format!("{algo}-{}-a{}", fed.model.name(), cfg.dirichlet_alpha);
    let log = MetricsLog::new(&name)
        .with_meta("algorithm", algo)
        .with_meta("gamma", cfg.gamma)
        .with_meta("local_steps", cfg.local_steps)
        .with_meta("alpha", cfg.dirichlet_alpha);
    let mut logger = RoundLogger::new(cfg, log);
    let dim = fed.x.len();
    let zeros = vec![0.0f32; dim];

    for round in 0..cfg.rounds {
        logger.begin_round();
        let sampled = fed.sample_clients(cfg.clients_per_round);
        let mut usage = super::transport::WireUsage::default();
        for _ in &sampled {
            usage.add_downlink(crate::compress::dense_bits(dim));
        }

        let x = fed.x.clone();
        let trainer = &fed.trainer;
        let clients = &fed.clients;
        let gamma = cfg.gamma;
        let local_steps = cfg.local_steps;
        let zeros_ref = &zeros;
        let results: Vec<(Vec<f32>, u64, f64)> = fed.pool.map(&sampled, |_, &ci| {
            let mut state = clients[ci].lock().unwrap();
            let mut xi = x.clone();
            let mut loss_sum = 0.0f64;
            for _ in 0..local_steps {
                let batch = state.loader.next_batch();
                let (next, loss) = trainer.train_step(&xi, zeros_ref, &batch, gamma);
                xi = next;
                loss_sum += loss as f64;
            }
            let (upload, bits) = send_through(compressor, &xi, &mut state.rng);
            (upload, bits, loss_sum)
        });

        let rows: Vec<&[f32]> = results.iter().map(|(v, _, _)| v.as_slice()).collect();
        crate::tensor::mean_into(&rows, &mut fed.x);
        for (_, bits, _) in &results {
            usage.add_uplink(*bits);
        }
        let train_loss = results.iter().map(|(_, _, l)| l).sum::<f64>()
            / (results.len() * cfg.local_steps).max(1) as f64;

        let eval = if (round + 1) % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(fed.evaluate())
        } else {
            None
        };
        logger.end_round(
            round,
            cfg.local_steps,
            train_loss,
            usage.uplink_bits,
            usage.downlink_bits,
            eval,
        );
    }
    logger.finish()
}
