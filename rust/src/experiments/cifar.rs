//! Figure 3: CNN on FedCIFAR10 — density sweep with tuned vs fixed stepsize.
//!
//! Left columns of the paper's figure tune γ per density from the §4.3 grid;
//! the right columns fix γ = 0.01 (the maximum stepsize that converges for
//! every configuration). The tuned sweep here uses a reduced grid to stay
//! inside the testbed budget; `--scale`/presets widen it.

use super::{fedcomloc_topk_spec, ExpOptions};
use crate::fed::{run as fed_run, AlgorithmSpec, RunConfig};

pub const DENSITIES: [f64; 4] = [1.0, 0.10, 0.30, 0.50];
pub const TUNE_GRID: [f32; 3] = [0.01, 0.05, 0.1];
pub const FIXED_GAMMA: f32 = 0.01;

fn spec_for(density: f64) -> AlgorithmSpec {
    AlgorithmSpec::parse(&fedcomloc_topk_spec(density)).expect("static spec")
}

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let trainer = opts.trainer_for(&RunConfig::default_cifar());
    println!("\n=== Figure 3: CNN on FedCIFAR10 ===");

    println!("\n-- tuned stepsize (grid {TUNE_GRID:?}) --");
    let mut tuned_rows = Vec::new();
    for &density in &DENSITIES {
        let mut best: Option<(f32, f64, u64)> = None;
        for &gamma in &TUNE_GRID {
            let cfg = RunConfig {
                gamma,
                ..opts.scale_cfg(RunConfig::default_cifar())
            };
            log::info!("fig3 tuned: density {density} gamma {gamma}");
            let log = fed_run(&cfg, trainer.clone(), &spec_for(density));
            let acc = log.best_accuracy().unwrap_or(0.0);
            opts.save("fig3", &log);
            if best.is_none() || acc > best.unwrap().1 {
                best = Some((gamma, acc, log.total_uplink_bits()));
            }
        }
        let (gamma, acc, bits) = best.unwrap();
        println!(
            "  K={:>4.0}%  best γ={gamma}  acc={acc:.4}  uplink_bits={bits}",
            density * 100.0
        );
        tuned_rows.push((density, acc));
    }

    println!("\n-- fixed stepsize γ={FIXED_GAMMA} --");
    for &density in &DENSITIES {
        let cfg = RunConfig {
            gamma: FIXED_GAMMA,
            ..opts.scale_cfg(RunConfig::default_cifar())
        };
        log::info!("fig3 fixed: density {density}");
        let log = fed_run(&cfg, trainer.clone(), &spec_for(density));
        let acc = log.best_accuracy().unwrap_or(0.0);
        let loss = log.final_train_loss().unwrap_or(f64::NAN);
        opts.save("fig3-fixed", &log);
        println!(
            "  K={:>4.0}%  acc={acc:.4}  final_loss={loss:.4}",
            density * 100.0
        );
    }
    Ok(())
}
