//! Little-endian byte serialization for checkpoint snapshots: a growable
//! [`ByteWriter`], a bounds-checked [`ByteReader`], a table-driven CRC-32,
//! and helpers for the repo's [`Rng`] state tuple.
//!
//! This sits in `util` (not under `ckpt`) so that `fed/`-layer state hooks
//! ([`crate::fed::FedAlgorithm::save_state`], transport `save_state`) can
//! produce byte sections without depending on the checkpoint subsystem.
//! Everything is fixed-width little-endian so snapshots are bit-identical
//! across hosts, mirroring the wire [`crate::fed::Message`] framing
//! discipline.

use crate::util::rng::Rng;

/// Growable little-endian byte sink for snapshot sections.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// An empty writer.
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    /// Consume the writer and return the accumulated bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`, little-endian.
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`, little-endian.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` as its IEEE-754 bit pattern, little-endian.
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 bit pattern, little-endian.
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed (u32) UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed (u64 element count) `f32` slice.
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f32(x);
        }
    }

    /// Append a length-prefixed (u64 element count) `usize` slice (as u64s).
    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x as u64);
        }
    }

    /// Append a length-prefixed (u64 byte count) raw byte slice.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Append an [`Rng`] state: the four xoshiro words plus the cached
    /// Box–Muller normal (flag byte + f64 bit pattern).
    pub fn put_rng(&mut self, rng: &Rng) {
        let (s, cached) = rng.state();
        for w in s {
            self.put_u64(w);
        }
        match cached {
            Some(v) => {
                self.put_u8(1);
                self.put_f64(v);
            }
            None => self.put_u8(0),
        }
    }
}

/// Bounds-checked little-endian reader over a snapshot section. Every
/// `take_*` validates the remaining length before reading, so truncated or
/// corrupted sections surface as descriptive `Err`s, never panics or
/// oversized allocations.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Context prefix for error messages (the section being decoded).
    what: &'a str,
}

impl<'a> ByteReader<'a> {
    /// A reader over `buf`; `what` names the section in error messages.
    pub fn new(buf: &'a [u8], what: &'a str) -> ByteReader<'a> {
        ByteReader { buf, pos: 0, what }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless every byte has been consumed — catches trailing garbage
    /// and schema drift.
    pub fn finish(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!(
                "{}: {} trailing bytes after decode",
                self.what,
                self.remaining()
            ));
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "{}: truncated (need {n} bytes at offset {}, have {})",
                self.what,
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn take_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn take_u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    /// Read a little-endian `u32`.
    pub fn take_u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn take_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a little-endian `f32` bit pattern.
    pub fn take_f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `f64` bit pattern.
    pub fn take_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a declared element/byte count and validate it against the bytes
    /// actually remaining (each element at least `elem_bytes` wide), so a
    /// corrupted length cannot trigger a huge allocation.
    fn take_count(&mut self, elem_bytes: usize) -> Result<usize, String> {
        let n = self.take_u64()?;
        let need = (n as usize).saturating_mul(elem_bytes);
        if n > usize::MAX as u64 || need > self.remaining() {
            return Err(format!(
                "{}: declared count {n} exceeds remaining {} bytes",
                self.what,
                self.remaining()
            ));
        }
        Ok(n as usize)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn take_str(&mut self) -> Result<String, String> {
        let n = self.take_u32()? as usize;
        if n > self.remaining() {
            return Err(format!(
                "{}: declared string length {n} exceeds remaining {} bytes",
                self.what,
                self.remaining()
            ));
        }
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| format!("{}: non-UTF-8 string", self.what))
    }

    /// Read a length-prefixed `f32` vector.
    pub fn take_f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.take_count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.take_f32()?);
        }
        Ok(v)
    }

    /// Read a length-prefixed `usize` vector.
    pub fn take_usizes(&mut self) -> Result<Vec<usize>, String> {
        let n = self.take_count(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.take_u64()? as usize);
        }
        Ok(v)
    }

    /// Read a length-prefixed raw byte vector.
    pub fn take_bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.take_count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    /// Read an [`Rng`] state written by [`ByteWriter::put_rng`].
    pub fn take_rng(&mut self) -> Result<Rng, String> {
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = self.take_u64()?;
        }
        let cached = match self.take_u8()? {
            0 => None,
            1 => Some(self.take_f64()?),
            t => return Err(format!("{}: bad rng cache flag {t}", self.what)),
        };
        Ok(Rng::from_state(s, cached))
    }
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB8_8320) over `bytes` —
/// the per-section integrity guard of the snapshot format.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = crc32_table();
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut rng = Rng::seed_from_u64(7);
        let _ = rng.normal(); // leave a cached normal in the state
        let mut w = ByteWriter::new();
        w.put_u8(0xAB);
        w.put_u16(0x1234);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_f32(-1.5);
        w.put_f64(std::f64::consts::PI);
        w.put_str("hello ✓");
        w.put_f32s(&[1.0, -2.0, 3.5]);
        w.put_usizes(&[0, 7, 42]);
        w.put_bytes(&[9, 8, 7]);
        w.put_rng(&rng);
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes, "test");
        assert_eq!(r.take_u8().unwrap(), 0xAB);
        assert_eq!(r.take_u16().unwrap(), 0x1234);
        assert_eq!(r.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.take_f32().unwrap(), -1.5);
        assert_eq!(r.take_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.take_str().unwrap(), "hello ✓");
        assert_eq!(r.take_f32s().unwrap(), vec![1.0, -2.0, 3.5]);
        assert_eq!(r.take_usizes().unwrap(), vec![0, 7, 42]);
        assert_eq!(r.take_bytes().unwrap(), vec![9, 8, 7]);
        let mut restored = r.take_rng().unwrap();
        r.finish().unwrap();
        // The restored stream continues identically.
        for _ in 0..10 {
            assert_eq!(restored.next_u64(), rng.next_u64());
        }
    }

    #[test]
    fn truncation_and_bad_lengths_error_cleanly() {
        let mut w = ByteWriter::new();
        w.put_f32s(&[1.0; 16]);
        let bytes = w.into_bytes();
        // Truncate mid-payload: clean error, no panic.
        for cut in [0, 4, 9, bytes.len() - 1] {
            let mut r = ByteReader::new(&bytes[..cut], "trunc");
            assert!(r.take_f32s().is_err(), "cut={cut}");
        }
        // Corrupt the declared count upward: rejected against remaining len.
        let mut evil = bytes.clone();
        evil[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut r = ByteReader::new(&evil, "evil");
        let err = r.take_f32s().unwrap_err();
        assert!(err.contains("exceeds remaining"), "{err}");
    }

    #[test]
    fn finish_flags_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u32(5);
        w.put_u8(0);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes, "tail");
        r.take_u32().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }
}
