"""L1 Pallas kernel: fused Scaffnew control-variate SGD step.

Computes x̂ = x − γ·(g − h) — Algorithm 1 line 7, the per-iteration hot-spot
of FedComLoc local training (d ≈ 10⁵–10⁶ elements per step). Fusing the
three-operand update into one pass avoids materializing (g − h) in HBM; on
TPU each grid step streams one VMEM block of each operand through the VPU.
"""

import jax.numpy as jnp

from . import common


def _kernel(x_ref, g_ref, h_ref, gamma_ref, o_ref):
    gamma = gamma_ref[0, 0]
    o_ref[...] = x_ref[...] - gamma * (g_ref[...] - h_ref[...])


def sgd_cv(x, g, h, gamma):
    """x̂ = x − γ·(g − h) over flat f32 vectors (γ traced scalar)."""
    assert x.shape == g.shape == h.shape and x.ndim == 1
    return common.elementwise_call(
        _kernel,
        jnp.float32,
        x.astype(jnp.float32),
        g.astype(jnp.float32),
        h.astype(jnp.float32),
        scalars=(gamma,),
    )
