//! Fixed-size worker pool over std threads + channels.
//!
//! tokio is not in the offline vendor set; the coordinator's parallelism
//! needs are simple and fork-join shaped (run the sampled clients' local
//! epochs concurrently each round), so a small dedicated pool is the right
//! tool anyway: no async runtime on the hot path, no per-round thread spawn
//! cost, deterministic shutdown.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("fed-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("worker rx poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            size,
        }
    }

    /// Pool sized to available parallelism (capped).
    pub fn with_default_size(cap: usize) -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n.min(cap.max(1)))
    }

    /// Number of worker threads (and therefore the number of distinct
    /// worker slots [`ThreadPool::map_worker`] can hand out).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Fork-join map: run `f(i, &items[i])` for every item on the pool and
    /// collect results in input order. This is the coordinator's per-round
    /// primitive ("run all sampled clients' local updates").
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send + 'static,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.map_worker(items, |_, i, item| f(i, item))
    }

    /// [`ThreadPool::map`] with a *worker slot*: `f(w, i, &items[i])` where
    /// `w < self.size()` identifies the executing worker and is held by
    /// exactly one thread at a time for the whole map call. Callers key
    /// per-worker mutable state (e.g. one `model::Workspace` each) off `w`
    /// without any contention.
    pub fn map_worker<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send + 'static,
        F: Fn(usize, usize, &T) -> R + Sync,
    {
        let n = items.len();
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        if n == 0 {
            return Vec::new();
        }
        // Scoped execution: borrow items/f from the caller without 'static.
        let next = std::sync::atomic::AtomicUsize::new(0);
        let out_slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
        std::thread::scope(|scope| {
            let (next, out_slots, f) = (&next, &out_slots, &f);
            let nworkers = self.size.min(n);
            for w in 0..nworkers {
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(w, i, &items[i]);
                    **out_slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        out.into_iter().map(|r| r.expect("job not run")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let items: Vec<usize> = (0..57).collect();
        let out = pool.map(&items, |_, &x| x * x);
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(&Vec::<usize>::new(), |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn map_runs_concurrently() {
        // With 4 workers, 8 sleeps of 30ms should take well under 8*30ms.
        let pool = ThreadPool::new(4);
        let items = vec![(); 8];
        let t0 = std::time::Instant::now();
        pool.map(&items, |_, _| std::thread::sleep(std::time::Duration::from_millis(30)));
        assert!(t0.elapsed() < std::time::Duration::from_millis(200));
    }

    #[test]
    fn map_worker_slots_are_exclusive_and_bounded() {
        let pool = ThreadPool::new(3);
        let items: Vec<usize> = (0..100).collect();
        let in_use: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        let out = pool.map_worker(&items, |w, i, &x| {
            assert!(w < 3, "worker slot out of range: {w}");
            // A slot must never be held by two threads at once.
            assert_eq!(in_use[w].fetch_add(1, Ordering::SeqCst), 0, "slot {w} shared");
            std::thread::sleep(std::time::Duration::from_micros(50));
            in_use[w].fetch_sub(1, Ordering::SeqCst);
            (i, x * 2)
        });
        for (i, &(ii, doubled)) in out.iter().enumerate() {
            assert_eq!(ii, i);
            assert_eq!(doubled, i * 2);
        }
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
