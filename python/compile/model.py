"""L2 program builders: the jitted functions that become AOT artifacts.

Every FedComLoc/baseline algorithm in the Rust coordinator is driven by four
programs per model family (paper Algorithm 1 + §4 baselines):

  train_step(params, h, x, y, γ)            -> (params', loss)
      ĝ = ∇f(params) on the minibatch; params' = params − γ(ĝ − h) via the
      fused L1 sgd_cv kernel. h = 0 recovers plain SGD (FedAvg local step).

  train_step_local(params, h, x, y, γ, ρ)   -> (params', loss)
      FedComLoc-Local: gradient evaluated at TopK_ρ(params) (in-graph L1
      topk kernel), update applied to the un-masked params (Alg. 1 l.6–7).

  grad(params, x, y)                        -> (g, loss)
      Raw minibatch gradient — Scaffold/FedDyn/FedAvg aggregate these with
      algorithm-specific server logic in Rust.

  evaluate(params, x, y)                    -> (per-example loss, correct)
      Vector outputs so the Rust side can mask padded eval rows exactly.

Plus one standalone compression program:

  quantize(x, u, r)                         -> Q_r(x)
      The L1 quantizer; used by the runtime cross-check test that pins the
      Rust wire codec and the Pallas kernel to the same semantics.
"""

import jax
import jax.numpy as jnp

from .kernels import quantize as quantize_kernel
from .kernels import sgd_cv, topk
from .models import cnn, mlp

MODELS = {"mlp": mlp, "cnn": cnn}

# Static batch geometry per model family (the AOT executables have fixed
# shapes; the Rust loader pads/chunks to these — see data/loader.rs).
BATCH = {"mlp": 64, "cnn": 32}
EVAL_BATCH = {"mlp": 256, "cnn": 128}
INPUT_SHAPE = {"mlp": (784,), "cnn": (3, 32, 32)}


def build_train_step(name):
    model = MODELS[name]

    def train_step(params, h, x, y, gamma):
        loss, g = jax.value_and_grad(model.loss_fn)(params, x, y)
        new_params = sgd_cv.sgd_cv(params, g, h, gamma)
        return new_params, loss

    return train_step


def build_train_step_local(name):
    model = MODELS[name]

    def train_step_local(params, h, x, y, gamma, density):
        masked = topk.topk(params, density)
        loss, g = jax.value_and_grad(model.loss_fn)(masked, x, y)
        new_params = sgd_cv.sgd_cv(params, g, h, gamma)
        return new_params, loss

    return train_step_local


def build_grad(name):
    model = MODELS[name]

    def grad(params, x, y):
        loss, g = jax.value_and_grad(model.loss_fn)(params, x, y)
        return g, loss

    return grad


def build_evaluate(name):
    model = MODELS[name]

    def evaluate(params, x, y):
        return model.per_example_metrics(params, x, y)

    return evaluate


def build_quantize():
    def quantize(x, u, r):
        return quantize_kernel.quantize(x, u, r)

    return quantize


def example_args(name, program):
    """ShapeDtypeStructs for jax.jit(...).lower(...) of a given program."""
    model = MODELS[name]
    d = model.DIM
    b = BATCH[name]
    e = EVAL_BATCH[name]
    xs = INPUT_SHAPE[name]
    f32 = jnp.float32
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    if program == "train_step":
        return (S((d,), f32), S((d,), f32), S((b, *xs), f32), S((b,), i32), S((), f32))
    if program == "train_step_local":
        return (
            S((d,), f32),
            S((d,), f32),
            S((b, *xs), f32),
            S((b,), i32),
            S((), f32),
            S((), f32),
        )
    if program == "grad":
        return (S((d,), f32), S((b, *xs), f32), S((b,), i32))
    if program == "evaluate":
        return (S((d,), f32), S((e, *xs), f32), S((e,), i32))
    raise ValueError(f"unknown program {program!r}")


PROGRAMS = {
    "train_step": build_train_step,
    "train_step_local": build_train_step_local,
    "grad": build_grad,
    "evaluate": build_evaluate,
}
