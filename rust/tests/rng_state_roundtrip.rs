//! Property pin for checkpointable RNG streams (`Rng::state` /
//! `Rng::from_state`): every stream the federation actually constructs,
//! frozen at an **arbitrary** point in its draw history, continues
//! bit-identically after a save/restore round-trip — including mid-pair
//! Box–Muller freezes, where the cached second normal must ride in the
//! snapshot or every later `normal()` draw shifts by one.

use fedcomloc::util::quickcheck::{check, Gen};
use fedcomloc::util::rng::Rng;

/// The salts the runtime derives its per-purpose streams from (data
/// loaders, per-client compression streams, model init, the algorithms'
/// server/coin streams). The exact values don't matter to the property —
/// they pin that real constructions, not just toy seeds, are covered.
const STREAM_SALTS: &[u64] = &[
    0xC11E27,      // client loader base
    0xC0_FFEE,     // per-client rng base
    0x1217,        // model init
    0x5EED_C019,   // scaffnew communication coin
    0x5E2E_5EED,   // scaffnew server stream
    0x0D01_1AF5,   // fedavg server sampling
    0x5CAF_F01D,   // scaffold server stream
    0xFEDD_D114,   // feddyn server stream
];

/// Burn a random prefix of mixed draw kinds, exercising every sampler the
/// codebase calls (and, through odd `normal` counts, the cached-normal
/// slot).
fn burn(rng: &mut Rng, g: &mut Gen) {
    let steps = g.usize_in(0..=40);
    for _ in 0..steps {
        match g.usize_in(0..=7) {
            0 => {
                rng.next_u64();
            }
            1 => {
                rng.uniform();
            }
            2 => {
                rng.normal();
            }
            3 => {
                rng.below(1 + g.usize_in(0..=100) as u64);
            }
            4 => {
                rng.gamma(0.1 + f64::from(g.f32_in(0.0, 3.0)));
            }
            5 => {
                rng.dirichlet(0.5, 1 + g.usize_in(0..=8));
            }
            6 => {
                let mut xs: Vec<usize> = (0..g.usize_in(0..=16)).collect();
                rng.shuffle(&mut xs);
            }
            _ => {
                rng.bernoulli(0.3);
            }
        }
    }
}

/// Drain a deterministic draw transcript for comparison.
fn transcript(rng: &mut Rng) -> Vec<u64> {
    let mut out = Vec::with_capacity(24);
    for _ in 0..8 {
        out.push(rng.next_u64());
        out.push(rng.normal().to_bits());
        out.push(rng.uniform().to_bits());
    }
    out
}

#[test]
fn every_stream_restores_to_an_exact_continuation() {
    check("rng state roundtrip", 200, |g| {
        let salt = *g.choose(STREAM_SALTS);
        let instance = g.usize_in(0..=32) as u64;
        let mut rng = Rng::seed_from_u64(salt.wrapping_add(instance));
        burn(&mut rng, g);

        let (s, cached) = rng.state();
        let mut restored = Rng::from_state(s, cached);
        let expect = transcript(&mut rng);
        let got = transcript(&mut restored);
        if got != expect {
            return Err(format!(
                "stream salt {salt:#x}+{instance} diverged after restore: \
                 {got:?} != {expect:?} (cached normal: {})",
                cached.is_some()
            ));
        }
        Ok(())
    });
}

#[test]
fn cached_normal_is_part_of_the_state() {
    // Freeze exactly mid Box–Muller pair: the restored stream's next
    // normal must be the cached second half, not a fresh pair.
    let mut rng = Rng::seed_from_u64(7);
    let _first_half = rng.normal();
    let (s, cached) = rng.state();
    assert!(cached.is_some(), "odd normal count must leave a cached half");
    let mut restored = Rng::from_state(s, cached);
    assert_eq!(restored.normal().to_bits(), rng.normal().to_bits());
    // Dropping the cached half detectably changes the continuation.
    let mut wrong = Rng::from_state(s, None);
    assert_ne!(wrong.normal().to_bits(), Rng::from_state(s, cached).normal().to_bits());
}
