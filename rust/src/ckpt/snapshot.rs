//! The checkpoint container: a versioned, self-describing, CRC-guarded
//! binary snapshot file.
//!
//! Layout (all integers little-endian, mirroring the wire
//! [`crate::fed::message::Message`] framing discipline):
//!
//! ```text
//! "FCKP"                      4-byte magic
//! schema                      u16  (see [`crate::ckpt::SCHEMA_VERSION`])
//! round                       u64  completed rounds when captured
//! algo_spec                   u32 len + UTF-8 (registry spec string)
//! n_sections                  u32
//! per section:
//!   name                      u32 len + UTF-8
//!   payload                   u64 len + bytes
//!   crc32(payload)            u32  (IEEE, [`crate::util::bytes::crc32`])
//! ```
//!
//! Sections are named and length-framed, so a reader skips sections it
//! does not understand and a writer may append new ones without a schema
//! bump; every payload is CRC-guarded, so torn or bit-rotted state is
//! detected at load, not at some confusing point mid-resume. Files are
//! written atomically: serialize to `<name>.tmp`, flush + fsync, then
//! rename over the final name — a crash mid-write leaves the previous
//! checkpoint untouched.

use super::SCHEMA_VERSION;
use crate::util::bytes::{crc32, ByteReader, ByteWriter};
use std::io::Write;
use std::path::{Path, PathBuf};

const MAGIC: [u8; 4] = *b"FCKP";

/// One checkpoint: header metadata plus named state sections.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Completed rounds when this snapshot was captured; resume restarts
    /// the drive loop at exactly this round index.
    pub round: u64,
    /// The algorithm registry spec string the run was launched with;
    /// resume refuses a different algorithm.
    pub algo_spec: String,
    /// Named state sections, in capture order.
    pub sections: Vec<(String, Vec<u8>)>,
}

impl Snapshot {
    /// An empty snapshot for `round` completed rounds of `algo_spec`.
    pub fn new(round: u64, algo_spec: &str) -> Snapshot {
        Snapshot {
            round,
            algo_spec: algo_spec.to_string(),
            sections: Vec::new(),
        }
    }

    /// Append a named state section.
    pub fn push_section(&mut self, name: &str, payload: Vec<u8>) {
        self.sections.push((name.to_string(), payload));
    }

    /// Look up a section's payload by name.
    pub fn section(&self, name: &str) -> Result<&[u8], String> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| format!("checkpoint is missing section '{name}'"))
    }

    /// Serialize the full container (header + CRC-framed sections).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        for b in MAGIC {
            w.put_u8(b);
        }
        w.put_u16(SCHEMA_VERSION);
        w.put_u64(self.round);
        w.put_str(&self.algo_spec);
        w.put_u32(self.sections.len() as u32);
        for (name, payload) in &self.sections {
            w.put_str(name);
            w.put_bytes(payload);
            w.put_u32(crc32(payload));
        }
        w.into_bytes()
    }

    /// Parse and validate a serialized container: magic, schema version,
    /// and every section's CRC.
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot, String> {
        let mut r = ByteReader::new(bytes, "checkpoint");
        let mut magic = [0u8; 4];
        for b in &mut magic {
            *b = r.take_u8()?;
        }
        if magic != MAGIC {
            return Err(format!("not a checkpoint file (bad magic {magic:02x?})"));
        }
        let schema = r.take_u16()?;
        if schema != SCHEMA_VERSION {
            return Err(format!(
                "unsupported checkpoint schema v{schema} (this build reads v{SCHEMA_VERSION})"
            ));
        }
        let round = r.take_u64()?;
        let algo_spec = r.take_str()?;
        let n = r.take_u32()? as usize;
        let mut sections = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.take_str()?;
            let payload = r.take_bytes()?;
            let want = r.take_u32()?;
            let got = crc32(&payload);
            if got != want {
                return Err(format!(
                    "checkpoint section '{name}' is corrupt: crc {got:08x} != recorded {want:08x}"
                ));
            }
            sections.push((name, payload));
        }
        r.finish()?;
        Ok(Snapshot {
            round,
            algo_spec,
            sections,
        })
    }

    /// Write the snapshot to `<dir>/ckpt-<round>.fckp` atomically
    /// (tmp + flush + fsync + rename) and return the final path.
    pub fn save_atomic(&self, dir: &Path) -> Result<PathBuf, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create checkpoint dir {}: {e}", dir.display()))?;
        let path = dir.join(file_name(self.round));
        let tmp = dir.join(format!("{}.tmp", file_name(self.round)));
        let bytes = self.to_bytes();
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| format!("cannot create {}: {e}", tmp.display()))?;
            f.write_all(&bytes)
                .and_then(|_| f.flush())
                .and_then(|_| f.sync_all())
                .map_err(|e| format!("cannot write {}: {e}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("cannot rename {} -> {}: {e}", tmp.display(), path.display()))?;
        // Make the rename itself durable (best-effort: directory handles
        // are not syncable on every platform/filesystem).
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(path)
    }

    /// Read and validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Snapshot, String> {
        let bytes = std::fs::read(path)
            .map_err(|e| format!("cannot read checkpoint {}: {e}", path.display()))?;
        Snapshot::from_bytes(&bytes)
            .map_err(|e| format!("invalid checkpoint {}: {e}", path.display()))
    }

    /// Human-readable description: schema, round, algorithm, and the name
    /// and size of every state section (`fedcomloc ckpt inspect`).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("schema:      v{SCHEMA_VERSION}\n"));
        out.push_str(&format!("rounds done: {}\n", self.round));
        out.push_str(&format!("algorithm:   {}\n", self.algo_spec));
        out.push_str(&format!("sections:    {}\n", self.sections.len()));
        for (name, payload) in &self.sections {
            out.push_str(&format!(
                "  {:<12} {:>10} bytes  crc32 {:08x}\n",
                name,
                payload.len(),
                crc32(payload)
            ));
        }
        out
    }
}

/// Canonical checkpoint file name for `round` completed rounds.
pub fn file_name(round: u64) -> String {
    format!("ckpt-{round:06}.fckp")
}

/// The newest *valid* checkpoint in `dir`: `(completed_rounds, path)` with
/// the highest round number that parses and passes every section CRC, or
/// `None` when the directory holds none (or does not exist). Only files
/// matching the `ckpt-<round>.fckp` pattern are considered, so foreign
/// files and leftover `.tmp` spills are ignored. A truncated or bit-rotted
/// candidate (e.g. a crash landed mid-write on a filesystem without atomic
/// rename durability) is skipped with a warning and the previous valid
/// snapshot is returned instead of hard-failing resume.
pub fn latest_checkpoint(dir: &Path) -> Option<(u64, PathBuf)> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut found: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| parse_round(&e.file_name().to_string_lossy()).map(|r| (r, e.path())))
        .collect();
    found.sort_by_key(|(r, _)| std::cmp::Reverse(*r));
    for (round, path) in found {
        match Snapshot::load(&path) {
            Ok(_) => return Some((round, path)),
            Err(e) => {
                log::warn!("skipping corrupt checkpoint {}: {e}", path.display());
            }
        }
    }
    None
}

/// CRC-check every section of every `ckpt-<round>.fckp` snapshot in `dir`
/// (`fedcomloc ckpt verify`). Returns a per-file report on success, or the
/// report (with per-file errors) when any snapshot fails validation or the
/// directory holds no checkpoints.
pub fn verify_dir(dir: &Path) -> Result<String, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read checkpoint dir {}: {e}", dir.display()))?;
    let mut found: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| parse_round(&e.file_name().to_string_lossy()).map(|r| (r, e.path())))
        .collect();
    if found.is_empty() {
        return Err(format!("no checkpoints in {}", dir.display()));
    }
    found.sort_by_key(|(r, _)| *r);
    let mut report = String::new();
    let mut bad = 0usize;
    for (_, path) in &found {
        let name = path.file_name().unwrap_or_default().to_string_lossy().into_owned();
        match Snapshot::load(path) {
            Ok(s) => {
                report.push_str(&format!(
                    "{name}  ok  round {}, {} sections, algorithm {}\n",
                    s.round,
                    s.sections.len(),
                    s.algo_spec
                ));
            }
            Err(e) => {
                bad += 1;
                report.push_str(&format!("{name}  CORRUPT  {e}\n"));
            }
        }
    }
    report.push_str(&format!("{} checkpoints, {} corrupt\n", found.len(), bad));
    if bad > 0 {
        Err(report)
    } else {
        Ok(report)
    }
}

/// Delete all but the newest `keep_last` checkpoints in `dir`
/// (`keep_last == 0` keeps everything). Returns the number removed.
pub fn prune(dir: &Path, keep_last: usize) -> usize {
    if keep_last == 0 {
        return 0;
    }
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    let mut found: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| parse_round(&e.file_name().to_string_lossy()).map(|r| (r, e.path())))
        .collect();
    found.sort_by_key(|(r, _)| std::cmp::Reverse(*r));
    let mut removed = 0;
    for (_, path) in found.into_iter().skip(keep_last) {
        if std::fs::remove_file(&path).is_ok() {
            removed += 1;
        }
    }
    removed
}

fn parse_round(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt-")?.strip_suffix(".fckp")?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fedcomloc_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample() -> Snapshot {
        let mut s = Snapshot::new(7, "fedcomloc-com:topk:0.1");
        s.push_section("model", vec![1, 2, 3, 4, 5]);
        s.push_section("fed_rng", vec![0xAA; 41]);
        s.push_section("empty", Vec::new());
        s
    }

    #[test]
    fn container_roundtrips() {
        let s = sample();
        let back = Snapshot::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.section("model").unwrap(), &[1, 2, 3, 4, 5]);
        assert!(back.section("nope").unwrap_err().contains("nope"));
    }

    #[test]
    fn corruption_is_detected() {
        let s = sample();
        let good = s.to_bytes();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Snapshot::from_bytes(&bad).unwrap_err().contains("magic"));
        // Wrong schema.
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(Snapshot::from_bytes(&bad).unwrap_err().contains("schema"));
        // Flip a payload byte: the section's CRC must catch it.
        let mut bad = good.clone();
        let payload_pos = good
            .windows(5)
            .position(|w| w == [1, 2, 3, 4, 5])
            .expect("payload present");
        bad[payload_pos] ^= 0xFF;
        let err = Snapshot::from_bytes(&bad).unwrap_err();
        assert!(err.contains("corrupt") && err.contains("model"), "{err}");
        // Truncation anywhere is an error, never a panic.
        for cut in 0..good.len() {
            assert!(Snapshot::from_bytes(&good[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn atomic_save_load_latest_and_prune() {
        let dir = tmpdir("atomic");
        for round in [2u64, 4, 6, 8] {
            let mut s = sample();
            s.round = round;
            let path = s.save_atomic(&dir).unwrap();
            assert_eq!(path.file_name().unwrap().to_string_lossy(), file_name(round));
            assert_eq!(Snapshot::load(&path).unwrap().round, round);
        }
        // A leftover tmp spill and a foreign file are ignored.
        std::fs::write(dir.join("ckpt-000099.fckp.tmp"), b"junk").unwrap();
        std::fs::write(dir.join("notes.txt"), b"junk").unwrap();
        let (round, path) = latest_checkpoint(&dir).unwrap();
        assert_eq!(round, 8);
        assert_eq!(Snapshot::load(&path).unwrap().round, 8);
        assert_eq!(prune(&dir, 2), 2);
        assert_eq!(latest_checkpoint(&dir).unwrap().0, 8);
        assert!(!dir.join(file_name(2)).exists());
        assert!(!dir.join(file_name(4)).exists());
        assert!(dir.join(file_name(6)).exists());
        // keep_last = 0 keeps everything.
        assert_eq!(prune(&dir, 0), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_skips_corrupt_and_falls_back_to_previous_valid() {
        let dir = tmpdir("fallback");
        for round in [3u64, 5] {
            let mut s = sample();
            s.round = round;
            s.save_atomic(&dir).unwrap();
        }
        // A crash mid-write (no atomic-rename durability) left the newest
        // file truncated: resume must fall back to round 5, not hard-fail.
        let good = {
            let mut s = sample();
            s.round = 9;
            s.to_bytes()
        };
        std::fs::write(dir.join(file_name(9)), &good[..good.len() / 2]).unwrap();
        let (round, path) = latest_checkpoint(&dir).unwrap();
        assert_eq!(round, 5);
        assert_eq!(Snapshot::load(&path).unwrap().round, 5);
        // With every candidate corrupt, there is no checkpoint to resume.
        std::fs::write(dir.join(file_name(5)), b"junk").unwrap();
        std::fs::write(dir.join(file_name(3)), b"junk").unwrap();
        assert!(latest_checkpoint(&dir).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_dir_reports_every_snapshot() {
        let dir = tmpdir("verify");
        assert!(verify_dir(&dir).unwrap_err().contains("no checkpoints"));
        for round in [1u64, 2] {
            let mut s = sample();
            s.round = round;
            s.save_atomic(&dir).unwrap();
        }
        let report = verify_dir(&dir).unwrap();
        assert!(report.contains(&file_name(1)) && report.contains(&file_name(2)), "{report}");
        assert!(report.contains("2 checkpoints, 0 corrupt"), "{report}");
        // A bit-rotted payload fails the section CRC and the whole verify.
        let path = dir.join(file_name(2));
        let mut bytes = std::fs::read(&path).unwrap();
        let pos = bytes
            .windows(5)
            .position(|w| w == [1, 2, 3, 4, 5])
            .expect("payload present");
        bytes[pos] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let report = verify_dir(&dir).unwrap_err();
        assert!(report.contains("CORRUPT") && report.contains("1 corrupt"), "{report}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn describe_names_sections() {
        let text = sample().describe();
        assert!(text.contains("fedcomloc-com:topk:0.1"));
        assert!(text.contains("model"));
        assert!(text.contains("rounds done: 7"));
    }
}
