//! Neural-net primitive ops (forward + backward) for the native trainer.
//!
//! These back the pure-Rust [`super::native::NativeTrainer`], the PJRT-free
//! twin of the AOT-compiled JAX programs. Numerics are cross-checked against
//! the HLO artifacts in `rust/tests/runtime_artifacts.rs`.
//!
//! # The matmul micro-kernel
//!
//! All three matmul orientations (`matmul_acc`, `matmul_at_b`,
//! `matmul_a_bt`) share one register-blocked scheme: 4 output rows at a
//! time, 16 columns per accumulator tile (two 8-lane f32 vectors once the
//! autovectorizer lowers the fixed-size-array inner loops), with the whole
//! K reduction held in registers so the C tile is touched exactly once per
//! call. Inner loops run over `[f32; 16]` / `[f32; 8]` array references
//! obtained via `try_into`, which eliminates bounds checks and gives LLVM
//! exact trip counts to unroll.
//!
//! Per-element accumulation order is ascending `k`, matching the previous
//! scalar kernels, except for the dot-product orientation (`matmul_a_bt`)
//! which lane-splits the reduction 8 ways and combines with a fixed
//! deterministic tree — results are deterministic for a given build, which
//! is the invariant every bit-identity test in this repo relies on.
//!
//! Bias and ReLU are fused into the matmul epilogues
//! ([`matmul_bias_act`], [`matmul_a_bt_bias_act`]) for the Dense/Conv
//! forward paths: the epilogue applies `+bias` then `max(0, ·)` per element
//! in the same order the former separate `add_bias`/`relu_inplace` passes
//! did, so fusion changes no values — it only removes two extra sweeps
//! over the activation buffer.
//!
//! Every op writes into caller-provided buffers and fully overwrites (or
//! explicitly accumulates into) its output, so the buffers can live in a
//! reused [`super::workspace::Workspace`] with no cross-call state leakage.

/// Columns per register accumulator tile (two 8-lane f32 vectors).
const NR: usize = 16;
/// Lanes for the lane-split dot-product reduction.
const DL: usize = 8;

/// C[m×n] = A[m×k] @ B[k×n]  (row-major, overwrite).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    matmul_acc(a, b, c, m, k, n);
}

/// C += A @ B — register-blocked 4×16 micro-kernel (see module docs).
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut i = 0;
    while i + 4 <= m {
        let (a0, a1, a2, a3) = (
            &a[i * k..(i + 1) * k],
            &a[(i + 1) * k..(i + 2) * k],
            &a[(i + 2) * k..(i + 3) * k],
            &a[(i + 3) * k..(i + 4) * k],
        );
        let mut rows = c[i * n..(i + 4) * n].chunks_exact_mut(n);
        let (c0, c1, c2, c3) = (
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
        );
        acc_rows4(a0, a1, a2, a3, b, c0, c1, c2, c3, k, n);
        i += 4;
    }
    while i < m {
        acc_row1(&a[i * k..(i + 1) * k], b, &mut c[i * n..(i + 1) * n], k, n);
        i += 1;
    }
}

/// 4-row × 16-col accumulator tiles over the full K reduction.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn acc_rows4(
    a0: &[f32],
    a1: &[f32],
    a2: &[f32],
    a3: &[f32],
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    k: usize,
    n: usize,
) {
    let mut j = 0;
    while j + NR <= n {
        let mut t0 = [0f32; NR];
        let mut t1 = [0f32; NR];
        let mut t2 = [0f32; NR];
        let mut t3 = [0f32; NR];
        t0.copy_from_slice(&c0[j..j + NR]);
        t1.copy_from_slice(&c1[j..j + NR]);
        t2.copy_from_slice(&c2[j..j + NR]);
        t3.copy_from_slice(&c3[j..j + NR]);
        for kk in 0..k {
            let bw: &[f32; NR] = b[kk * n + j..kk * n + j + NR].try_into().unwrap();
            let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for l in 0..NR {
                t0[l] += x0 * bw[l];
                t1[l] += x1 * bw[l];
                t2[l] += x2 * bw[l];
                t3[l] += x3 * bw[l];
            }
        }
        c0[j..j + NR].copy_from_slice(&t0);
        c1[j..j + NR].copy_from_slice(&t1);
        c2[j..j + NR].copy_from_slice(&t2);
        c3[j..j + NR].copy_from_slice(&t3);
        j += NR;
    }
    if j < n {
        let w = n - j;
        let mut t = [[0f32; NR]; 4];
        t[0][..w].copy_from_slice(&c0[j..]);
        t[1][..w].copy_from_slice(&c1[j..]);
        t[2][..w].copy_from_slice(&c2[j..]);
        t[3][..w].copy_from_slice(&c3[j..]);
        for kk in 0..k {
            let bw = &b[kk * n + j..kk * n + n];
            let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for l in 0..w {
                t[0][l] += x0 * bw[l];
                t[1][l] += x1 * bw[l];
                t[2][l] += x2 * bw[l];
                t[3][l] += x3 * bw[l];
            }
        }
        c0[j..].copy_from_slice(&t[0][..w]);
        c1[j..].copy_from_slice(&t[1][..w]);
        c2[j..].copy_from_slice(&t[2][..w]);
        c3[j..].copy_from_slice(&t[3][..w]);
    }
}

/// Single-row remainder of [`matmul_acc`] (1×16 tiles).
#[inline(always)]
fn acc_row1(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize) {
    let mut j = 0;
    while j + NR <= n {
        let mut t = [0f32; NR];
        t.copy_from_slice(&c[j..j + NR]);
        for (kk, &x) in a.iter().enumerate().take(k) {
            let bw: &[f32; NR] = b[kk * n + j..kk * n + j + NR].try_into().unwrap();
            for l in 0..NR {
                t[l] += x * bw[l];
            }
        }
        c[j..j + NR].copy_from_slice(&t);
        j += NR;
    }
    if j < n {
        let w = n - j;
        let mut t = [0f32; NR];
        t[..w].copy_from_slice(&c[j..]);
        for (kk, &x) in a.iter().enumerate().take(k) {
            let bw = &b[kk * n + j..kk * n + n];
            for l in 0..w {
                t[l] += x * bw[l];
            }
        }
        c[j..].copy_from_slice(&t[..w]);
    }
}

/// C[m×n] = A[m×k] @ B[k×n] + bias[n] (row-broadcast), optionally followed
/// by ReLU — the fused Dense-layer forward. Overwrites C. The epilogue
/// applies `+bias` then `max(0, ·)` per element, identical to running
/// [`matmul`], `add_bias`, `relu_inplace` in sequence.
pub fn matmul_bias_act(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    debug_assert_eq!(bias.len(), n);
    matmul(a, b, c, m, k, n);
    for row in c.chunks_exact_mut(n) {
        if relu {
            for (v, &bv) in row.iter_mut().zip(bias) {
                let s = *v + bv;
                *v = if s < 0.0 { 0.0 } else { s };
            }
        } else {
            for (v, &bv) in row.iter_mut().zip(bias) {
                *v += bv;
            }
        }
    }
}

/// C[m×n] = A[k×m]ᵀ @ B[k×n]  (used for weight gradients: dW = Xᵀ @ dY).
/// Fully overwrites C. Register-blocked like [`matmul_acc`] with strided
/// (column) A loads.
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut i = 0;
    while i + 4 <= m {
        let mut rows = c[i * n..(i + 4) * n].chunks_exact_mut(n);
        let (c0, c1, c2, c3) = (
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
        );
        at_b_rows4(a, i, m, b, c0, c1, c2, c3, k, n);
        i += 4;
    }
    while i < m {
        at_b_row1(a, i, m, b, &mut c[i * n..(i + 1) * n], k, n);
        i += 1;
    }
}

/// 4 strided-A rows × 16-col tiles for the Aᵀ orientation.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn at_b_rows4(
    a: &[f32],
    i: usize,
    m: usize,
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    k: usize,
    n: usize,
) {
    let mut j = 0;
    while j + NR <= n {
        let mut t0 = [0f32; NR];
        let mut t1 = [0f32; NR];
        let mut t2 = [0f32; NR];
        let mut t3 = [0f32; NR];
        for kk in 0..k {
            let base = kk * m + i;
            let (x0, x1, x2, x3) = (a[base], a[base + 1], a[base + 2], a[base + 3]);
            let bw: &[f32; NR] = b[kk * n + j..kk * n + j + NR].try_into().unwrap();
            for l in 0..NR {
                t0[l] += x0 * bw[l];
                t1[l] += x1 * bw[l];
                t2[l] += x2 * bw[l];
                t3[l] += x3 * bw[l];
            }
        }
        c0[j..j + NR].copy_from_slice(&t0);
        c1[j..j + NR].copy_from_slice(&t1);
        c2[j..j + NR].copy_from_slice(&t2);
        c3[j..j + NR].copy_from_slice(&t3);
        j += NR;
    }
    if j < n {
        let w = n - j;
        let mut t = [[0f32; NR]; 4];
        for kk in 0..k {
            let base = kk * m + i;
            let (x0, x1, x2, x3) = (a[base], a[base + 1], a[base + 2], a[base + 3]);
            let bw = &b[kk * n + j..kk * n + n];
            for l in 0..w {
                t[0][l] += x0 * bw[l];
                t[1][l] += x1 * bw[l];
                t[2][l] += x2 * bw[l];
                t[3][l] += x3 * bw[l];
            }
        }
        c0[j..].copy_from_slice(&t[0][..w]);
        c1[j..].copy_from_slice(&t[1][..w]);
        c2[j..].copy_from_slice(&t[2][..w]);
        c3[j..].copy_from_slice(&t[3][..w]);
    }
}

/// Single-row remainder of [`matmul_at_b`].
#[inline(always)]
fn at_b_row1(a: &[f32], i: usize, m: usize, b: &[f32], c: &mut [f32], k: usize, n: usize) {
    let mut j = 0;
    while j + NR <= n {
        let mut t = [0f32; NR];
        for kk in 0..k {
            let x = a[kk * m + i];
            let bw: &[f32; NR] = b[kk * n + j..kk * n + j + NR].try_into().unwrap();
            for l in 0..NR {
                t[l] += x * bw[l];
            }
        }
        c[j..j + NR].copy_from_slice(&t);
        j += NR;
    }
    if j < n {
        let w = n - j;
        let mut t = [0f32; NR];
        for kk in 0..k {
            let x = a[kk * m + i];
            let bw = &b[kk * n + j..kk * n + n];
            for l in 0..w {
                t[l] += x * bw[l];
            }
        }
        c[j..].copy_from_slice(&t[..w]);
    }
}

/// Lane-split dot product: 8 parallel accumulators combined with a fixed
/// deterministic tree, scalar tail appended last.
#[inline(always)]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; DL];
    let n8 = a.len() / DL * DL;
    let mut p = 0;
    while p < n8 {
        let av: &[f32; DL] = a[p..p + DL].try_into().unwrap();
        let bv: &[f32; DL] = b[p..p + DL].try_into().unwrap();
        for l in 0..DL {
            acc[l] += av[l] * bv[l];
        }
        p += DL;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
    for q in n8..a.len() {
        s += a[q] * b[q];
    }
    s
}

/// Four simultaneous lane-split dot products against a shared right-hand
/// row (streams `br` once for four A rows).
#[inline(always)]
#[allow(clippy::type_complexity)]
fn dot_lanes4(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], br: &[f32]) -> (f32, f32, f32, f32) {
    let k = br.len();
    let mut acc = [[0f32; DL]; 4];
    let n8 = k / DL * DL;
    let mut p = 0;
    while p < n8 {
        let bv: &[f32; DL] = br[p..p + DL].try_into().unwrap();
        let v0: &[f32; DL] = a0[p..p + DL].try_into().unwrap();
        let v1: &[f32; DL] = a1[p..p + DL].try_into().unwrap();
        let v2: &[f32; DL] = a2[p..p + DL].try_into().unwrap();
        let v3: &[f32; DL] = a3[p..p + DL].try_into().unwrap();
        for l in 0..DL {
            acc[0][l] += v0[l] * bv[l];
            acc[1][l] += v1[l] * bv[l];
            acc[2][l] += v2[l] * bv[l];
            acc[3][l] += v3[l] * bv[l];
        }
        p += DL;
    }
    let hsum = |t: &[f32; DL]| ((t[0] + t[4]) + (t[2] + t[6])) + ((t[1] + t[5]) + (t[3] + t[7]));
    let (mut s0, mut s1, mut s2, mut s3) = (hsum(&acc[0]), hsum(&acc[1]), hsum(&acc[2]), hsum(&acc[3]));
    for q in n8..k {
        let bv = br[q];
        s0 += a0[q] * bv;
        s1 += a1[q] * bv;
        s2 += a2[q] * bv;
        s3 += a3[q] * bv;
    }
    (s0, s1, s2, s3)
}

/// C[m×n] = A[m×k] @ B[n×k]ᵀ  (used for input gradients: dX = dY @ Wᵀ).
/// Fully overwrites C.
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let mut i = 0;
    while i + 4 <= m {
        let (a0, a1, a2, a3) = (
            &a[i * k..(i + 1) * k],
            &a[(i + 1) * k..(i + 2) * k],
            &a[(i + 2) * k..(i + 3) * k],
            &a[(i + 3) * k..(i + 4) * k],
        );
        let mut rows = c[i * n..(i + 4) * n].chunks_exact_mut(n);
        let (c0, c1, c2, c3) = (
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
            rows.next().unwrap(),
        );
        for j in 0..n {
            let br = &b[j * k..(j + 1) * k];
            let (s0, s1, s2, s3) = dot_lanes4(a0, a1, a2, a3, br);
            c0[j] = s0;
            c1[j] = s1;
            c2[j] = s2;
            c3[j] = s3;
        }
        i += 4;
    }
    while i < m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (j, cv) in c_row.iter_mut().enumerate() {
            *cv = dot_lanes(a_row, &b[j * k..(j + 1) * k]);
        }
        i += 1;
    }
}

/// C[m×n] = A[m×k] @ B[n×k]ᵀ + bias[m] (column-broadcast, i.e. one bias per
/// *output row*), optionally followed by ReLU — the fused Conv-layer
/// forward, where rows are output channels. Fully overwrites C.
#[allow(clippy::too_many_arguments)]
pub fn matmul_a_bt_bias_act(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    debug_assert_eq!(bias.len(), m);
    matmul_a_bt(a, b, c, m, k, n);
    for (row, &bv) in c.chunks_exact_mut(n).zip(bias) {
        if relu {
            for v in row.iter_mut() {
                let s = *v + bv;
                *v = if s < 0.0 { 0.0 } else { s };
            }
        } else {
            for v in row.iter_mut() {
                *v += bv;
            }
        }
    }
}

/// y = relu(x) in place; returns nothing (mask recoverable from y > 0).
#[inline]
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// dx = dy ⊙ 1[y > 0] in place on dy (y is the *post*-ReLU activation).
#[inline]
pub fn relu_backward_inplace(dy: &mut [f32], y: &[f32]) {
    debug_assert_eq!(dy.len(), y.len());
    for (d, &a) in dy.iter_mut().zip(y) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Add bias row-wise: X[m×n] += b[n].
#[inline]
pub fn add_bias(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for row in x.chunks_exact_mut(n) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// db[n] = Σ_rows dY[m×n].
#[inline]
pub fn bias_grad(dy: &[f32], db: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    db.fill(0.0);
    for row in dy.chunks_exact(n) {
        for (g, &v) in db.iter_mut().zip(row) {
            *g += v;
        }
    }
}

/// Softmax cross-entropy over logits[m×n] with integer labels.
/// Returns (mean loss, dlogits[m×n] already scaled by 1/m).
pub fn softmax_cross_entropy(logits: &[f32], labels: &[i32], n: usize) -> (f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; labels.len() * n];
    let loss = softmax_cross_entropy_into(logits, labels, n, &mut dlogits);
    (loss, dlogits)
}

/// [`softmax_cross_entropy`] writing the gradient into a caller buffer
/// (fully overwritten). Returns the mean loss.
pub fn softmax_cross_entropy_into(
    logits: &[f32],
    labels: &[i32],
    n: usize,
    dlogits: &mut [f32],
) -> f32 {
    let m = labels.len();
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(dlogits.len(), m * n);
    let mut loss_acc = 0.0f64;
    for (row, &label) in labels.iter().enumerate() {
        let lo = row * n;
        let z = &logits[lo..lo + n];
        let zmax = z.iter().cloned().fold(f32::MIN, f32::max);
        let mut denom = 0.0f64;
        for &v in z {
            denom += ((v - zmax) as f64).exp();
        }
        let log_denom = denom.ln() as f32 + zmax;
        let label = label as usize;
        debug_assert!(label < n);
        loss_acc += (log_denom - z[label]) as f64;
        let dl = &mut dlogits[lo..lo + n];
        for (j, dv) in dl.iter_mut().enumerate() {
            let p = (((z[j] - zmax) as f64).exp() / denom) as f32;
            *dv = (p - if j == label { 1.0 } else { 0.0 }) / m as f32;
        }
    }
    (loss_acc / m as f64) as f32
}

/// Count of argmax(logits_row) == label.
pub fn count_correct(logits: &[f32], labels: &[i32], n: usize, valid: usize) -> usize {
    labels
        .iter()
        .take(valid)
        .enumerate()
        .filter(|&(row, &label)| {
            let z = &logits[row * n..(row + 1) * n];
            let mut best = 0usize;
            for j in 1..n {
                if z[j] > z[best] {
                    best = j;
                }
            }
            best == label as usize
        })
        .count()
}

/// Sum of per-row CE losses for the first `valid` rows (no gradient).
pub fn cross_entropy_sum(logits: &[f32], labels: &[i32], n: usize, valid: usize) -> f64 {
    let mut acc = 0.0f64;
    for (row, &label) in labels.iter().take(valid).enumerate() {
        let z = &logits[row * n..(row + 1) * n];
        let zmax = z.iter().cloned().fold(f32::MIN, f32::max);
        let denom: f64 = z.iter().map(|&v| ((v - zmax) as f64).exp()).sum();
        acc += denom.ln() + zmax as f64 - z[label as usize] as f64;
    }
    acc
}

// ---------------------------------------------------------------------------
// Convolution via im2col (NCHW activations, OIHW weights, valid padding,
// stride 1 — the FedLab CIFAR CNN uses exactly this shape).
// ---------------------------------------------------------------------------

/// Geometry of one conv layer.
#[derive(Debug, Clone, Copy)]
pub struct ConvShape {
    /// Input channels.
    pub in_ch: usize,
    /// Output channels.
    pub out_ch: usize,
    /// Input plane height.
    pub in_h: usize,
    /// Input plane width.
    pub in_w: usize,
    /// Square kernel side.
    pub k: usize,
}

impl ConvShape {
    /// Output plane height (valid padding, stride 1).
    pub fn out_h(&self) -> usize {
        self.in_h - self.k + 1
    }
    /// Output plane width (valid padding, stride 1).
    pub fn out_w(&self) -> usize {
        self.in_w - self.k + 1
    }
    /// Rows of the im2col matrix (output positions).
    pub fn col_rows(&self) -> usize {
        self.out_h() * self.out_w()
    }
    /// Columns of the im2col matrix (receptive-field size).
    pub fn col_cols(&self) -> usize {
        self.in_ch * self.k * self.k
    }
}

/// im2col for one image: col[(oh·ow) × (in_ch·k·k)].
pub fn im2col(x: &[f32], s: &ConvShape, col: &mut [f32]) {
    let (oh, ow, k) = (s.out_h(), s.out_w(), s.k);
    debug_assert_eq!(x.len(), s.in_ch * s.in_h * s.in_w);
    debug_assert_eq!(col.len(), s.col_rows() * s.col_cols());
    let cc = s.col_cols();
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cc;
            let mut c = row;
            for ch in 0..s.in_ch {
                let plane = ch * s.in_h * s.in_w;
                for ky in 0..k {
                    let src = plane + (oy + ky) * s.in_w + ox;
                    col[c..c + k].copy_from_slice(&x[src..src + k]);
                    c += k;
                }
            }
        }
    }
}

/// col2im accumulate (transpose of im2col) for input gradients.
pub fn col2im_acc(col: &[f32], s: &ConvShape, dx: &mut [f32]) {
    let (oh, ow, k) = (s.out_h(), s.out_w(), s.k);
    debug_assert_eq!(dx.len(), s.in_ch * s.in_h * s.in_w);
    let cc = s.col_cols();
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cc;
            let mut c = row;
            for ch in 0..s.in_ch {
                let plane = ch * s.in_h * s.in_w;
                for ky in 0..k {
                    let dst = plane + (oy + ky) * s.in_w + ox;
                    for kx in 0..k {
                        dx[dst + kx] += col[c + kx];
                    }
                    c += k;
                }
            }
        }
    }
}

/// Forward conv for a batch with the bias (+ optional ReLU) fused into the
/// matmul epilogue.
/// x:[b, in_ch, h, w], w:[out_ch, in_ch·k·k] (OIHW flattened), bias:[out_ch]
/// → y:[b, out_ch, oh, ow]. `col_buf` is scratch of size col_rows·col_cols.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    s: &ConvShape,
    batch: usize,
    y: &mut [f32],
    col_buf: &mut [f32],
    relu: bool,
) {
    conv2d_forward_with(
        &crate::backend::kernels::SCALAR,
        x,
        w,
        bias,
        s,
        batch,
        y,
        col_buf,
        relu,
    );
}

/// [`conv2d_forward`] with the matmul routed through a backend
/// [`MicroKernels`](crate::backend::kernels::MicroKernels) set; im2col
/// stays canonical (pure data movement).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_with(
    kernels: &dyn crate::backend::kernels::MicroKernels,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    s: &ConvShape,
    batch: usize,
    y: &mut [f32],
    col_buf: &mut [f32],
    relu: bool,
) {
    let (oh, ow) = (s.out_h(), s.out_w());
    let ysz = s.out_ch * oh * ow;
    let xsz = s.in_ch * s.in_h * s.in_w;
    debug_assert_eq!(x.len(), batch * xsz);
    debug_assert_eq!(y.len(), batch * ysz);
    debug_assert_eq!(w.len(), s.out_ch * s.col_cols());
    for b in 0..batch {
        im2col(&x[b * xsz..(b + 1) * xsz], s, col_buf);
        // y_b[out_ch × (oh·ow)] = W[out_ch × cc] @ colᵀ[(cc) × (oh·ow)],
        // bias per output channel and ReLU applied in the epilogue.
        let yb = &mut y[b * ysz..(b + 1) * ysz];
        kernels.matmul_a_bt_bias_act(w, col_buf, bias, yb, s.out_ch, s.col_cols(), s.col_rows(), relu);
    }
}

/// Backward conv: given dy, produce dW, db, and (optionally) dx.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    s: &ConvShape,
    batch: usize,
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
    col_buf: &mut [f32],
    dcol_buf: &mut [f32],
) {
    conv2d_backward_with(
        &crate::backend::kernels::SCALAR,
        x,
        w,
        dy,
        s,
        batch,
        dw,
        db,
        dx,
        col_buf,
        dcol_buf,
    );
}

/// [`conv2d_backward`] with the two matmuls routed through a backend
/// [`MicroKernels`](crate::backend::kernels::MicroKernels) set; im2col /
/// col2im and the bias reduction stay canonical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_with(
    kernels: &dyn crate::backend::kernels::MicroKernels,
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    s: &ConvShape,
    batch: usize,
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
    col_buf: &mut [f32],
    dcol_buf: &mut [f32],
) {
    let (oh, ow) = (s.out_h(), s.out_w());
    let ysz = s.out_ch * oh * ow;
    let xsz = s.in_ch * s.in_h * s.in_w;
    let cc = s.col_cols();
    let cr = s.col_rows();
    dw.fill(0.0);
    db.fill(0.0);
    let mut dx = dx;
    if let Some(dx) = dx.as_deref_mut() {
        dx.fill(0.0);
    }
    for b in 0..batch {
        let dyb = &dy[b * ysz..(b + 1) * ysz]; // [out_ch × cr]
        im2col(&x[b * xsz..(b + 1) * xsz], s, col_buf); // [cr × cc]
        // dW[oc × cc] += dyb[oc × cr] @ col[cr × cc]
        kernels.matmul_acc(dyb, col_buf, dw, s.out_ch, cr, cc);
        for oc in 0..s.out_ch {
            db[oc] += dyb[oc * cr..(oc + 1) * cr].iter().sum::<f32>();
        }
        if let Some(dx) = dx.as_deref_mut() {
            // dcol[cr × cc] = dybᵀ[cr × oc] @ W[oc × cc]
            kernels.matmul_at_b(dyb, w, dcol_buf, cr, s.out_ch, cc);
            col2im_acc(dcol_buf, s, &mut dx[b * xsz..(b + 1) * xsz]);
        }
    }
}

/// 2×2 max-pool forward (stride 2) on [b, ch, h, w] with argmax bookkeeping.
pub fn maxpool2_forward(
    x: &[f32],
    batch_ch: usize, // batch · channels (pooling is per-plane)
    h: usize,
    w: usize,
    y: &mut [f32],
    argmax: &mut [u32],
) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(x.len(), batch_ch * h * w);
    debug_assert_eq!(y.len(), batch_ch * oh * ow);
    debug_assert_eq!(argmax.len(), y.len());
    for p in 0..batch_ch {
        let xp = &x[p * h * w..(p + 1) * h * w];
        let yo = p * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let base = (2 * oy) * w + 2 * ox;
                let cands = [base, base + 1, base + w, base + w + 1];
                let mut best = cands[0];
                for &c in &cands[1..] {
                    if xp[c] > xp[best] {
                        best = c;
                    }
                }
                y[yo + oy * ow + ox] = xp[best];
                argmax[yo + oy * ow + ox] = (p * h * w + best) as u32;
            }
        }
    }
}

/// Max-pool backward: scatter dy into dx at the recorded argmax positions.
pub fn maxpool2_backward(dy: &[f32], argmax: &[u32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), argmax.len());
    dx.fill(0.0);
    for (&g, &pos) in dy.iter().zip(argmax) {
        dx[pos as usize] += g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(1);
        let (m, k, n) = (7, 13, 5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        // aᵀ stored: build A' = aᵀ [k×m], use matmul_at_b
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul_at_b(&at, &b, &mut c2, m, k, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
        // bᵀ stored: B' = bᵀ [n×k], use matmul_a_bt
        let mut bt = vec![0.0; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        matmul_a_bt(&a, &bt, &mut c3, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    /// Naive triple loop in f64 as the oracle for the blocked kernels.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += (a[i * k + kk] as f64) * (b[kk * n + j] as f64);
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn blocked_kernels_match_naive_across_shapes() {
        // Exercise every row/column remainder path of the 4×16 tiling:
        // m ∈ {1..5}, n around the NR=16 boundary, odd k.
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(33);
        for &m in &[1usize, 2, 3, 4, 5, 9] {
            for &n in &[1usize, 15, 16, 17, 31, 33] {
                for &k in &[1usize, 7, 8, 9, 40] {
                    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let want = naive(&a, &b, m, k, n);
                    let mut c = vec![0.0; m * n];
                    matmul(&a, &b, &mut c, m, k, n);
                    for (idx, (x, y)) in c.iter().zip(&want).enumerate() {
                        assert!(
                            (x - y).abs() < 1e-3,
                            "matmul m={m} n={n} k={k} idx={idx}: {x} vs {y}"
                        );
                    }
                    // Accumulate path: C preloaded with ones must add on top.
                    let mut c_acc = vec![1.0f32; m * n];
                    matmul_acc(&a, &b, &mut c_acc, m, k, n);
                    for (x, y) in c_acc.iter().zip(&want) {
                        assert!((x - (y + 1.0)).abs() < 1e-3, "acc m={m} n={n} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_bias_act_matches_separate_passes() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(34);
        let (m, k, n) = (6, 11, 19);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut want = vec![0.0; m * n];
        matmul(&a, &b, &mut want, m, k, n);
        add_bias(&mut want, &bias, m, n);
        let mut want_relu = want.clone();
        relu_inplace(&mut want_relu);
        let mut fused = vec![f32::NAN; m * n]; // stale garbage must be overwritten
        matmul_bias_act(&a, &b, &bias, &mut fused, m, k, n, false);
        assert_eq!(fused, want, "fused no-relu must be bit-identical");
        let mut fused_relu = vec![f32::NAN; m * n];
        matmul_bias_act(&a, &b, &bias, &mut fused_relu, m, k, n, true);
        assert_eq!(fused_relu, want_relu, "fused relu must be bit-identical");

        // Conv orientation: per-row bias.
        let bt: Vec<f32> = {
            let mut bt = vec![0.0; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            bt
        };
        let row_bias: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut want_rows = vec![0.0; m * n];
        matmul_a_bt(&a, &bt, &mut want_rows, m, k, n);
        for (row, &bv) in want_rows.chunks_exact_mut(n).zip(&row_bias) {
            for v in row.iter_mut() {
                *v += bv;
            }
            relu_inplace(row);
        }
        let mut fused_rows = vec![f32::NAN; m * n];
        matmul_a_bt_bias_act(&a, &bt, &row_bias, &mut fused_rows, m, k, n, true);
        assert_eq!(fused_rows, want_rows);
    }

    #[test]
    fn softmax_ce_gradient_numerically() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(2);
        let (m, n) = (4, 6);
        let logits: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let labels: Vec<i32> = (0..m).map(|_| rng.below(n as u64) as i32).collect();
        let (loss, grad) = softmax_cross_entropy(&logits, &labels, n);
        assert!(loss > 0.0);
        let eps = 1e-3f32;
        for idx in 0..m * n {
            let mut lp = logits.clone();
            lp[idx] += eps;
            let (loss_p, _) = softmax_cross_entropy(&lp, &labels, n);
            let mut lm = logits.clone();
            lm[idx] -= eps;
            let (loss_m, _) = softmax_cross_entropy(&lm, &labels, n);
            let num = (loss_p - loss_m) / (2.0 * eps);
            assert!(
                (num - grad[idx]).abs() < 1e-2,
                "idx {idx}: numeric {num} analytic {}",
                grad[idx]
            );
        }
    }

    #[test]
    fn softmax_into_overwrites_stale_buffer() {
        let logits = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let labels = vec![0, 2];
        let (loss, grad) = softmax_cross_entropy(&logits, &labels, 3);
        let mut stale = vec![f32::NAN; 6];
        let loss2 = softmax_cross_entropy_into(&logits, &labels, 3, &mut stale);
        assert_eq!(loss.to_bits(), loss2.to_bits());
        assert_eq!(grad, stale);
    }

    #[test]
    fn softmax_rows_sum_to_zero_grad() {
        let logits = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let labels = vec![0, 2];
        let (_, grad) = softmax_cross_entropy(&logits, &labels, 3);
        for row in grad.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn relu_and_backward() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut dy = vec![5.0, 5.0, 5.0];
        relu_backward_inplace(&mut dy, &x);
        assert_eq!(dy, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> — the two must be adjoint maps.
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(3);
        let s = ConvShape {
            in_ch: 2,
            out_ch: 1,
            in_h: 6,
            in_w: 5,
            k: 3,
        };
        let x: Vec<f32> = (0..s.in_ch * s.in_h * s.in_w)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let mut col = vec![0.0; s.col_rows() * s.col_cols()];
        im2col(&x, &s, &mut col);
        let c: Vec<f32> = (0..col.len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let lhs: f64 = col.iter().zip(&c).map(|(&a, &b)| (a * b) as f64).sum();
        let mut back = vec![0.0; x.len()];
        col2im_acc(&c, &s, &mut back);
        let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn conv_forward_known_value() {
        // 1 channel 3x3 input, 2x2 kernel of ones, no bias:
        // each output = sum of 2x2 patch.
        let s = ConvShape {
            in_ch: 1,
            out_ch: 1,
            in_h: 3,
            in_w: 3,
            k: 2,
        };
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let w = [1.0, 1.0, 1.0, 1.0];
        let bias = [0.5];
        let mut y = vec![0.0; 4];
        let mut col = vec![0.0; s.col_rows() * s.col_cols()];
        conv2d_forward(&x, &w, &bias, &s, 1, &mut y, &mut col, false);
        assert_eq!(y, vec![12.5, 16.5, 24.5, 28.5]);
    }

    #[test]
    fn conv_backward_matches_numeric() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(4);
        let s = ConvShape {
            in_ch: 2,
            out_ch: 3,
            in_h: 5,
            in_w: 5,
            k: 3,
        };
        let batch = 2;
        let xsz = s.in_ch * s.in_h * s.in_w;
        let ysz = s.out_ch * s.out_h() * s.out_w();
        let x: Vec<f32> = (0..batch * xsz).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let w: Vec<f32> = (0..s.out_ch * s.col_cols())
            .map(|_| rng.normal_f32(0.0, 0.5))
            .collect();
        let bias: Vec<f32> = (0..s.out_ch).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        // Loss = sum(y * t) for random t -> dy = t.
        let t: Vec<f32> = (0..batch * ysz).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut col = vec![0.0; s.col_rows() * s.col_cols()];
        let mut dcol = vec![0.0; col.len()];
        let fwd_loss = |w: &[f32], bias: &[f32], x: &[f32]| -> f64 {
            let mut y = vec![0.0; batch * ysz];
            let mut colb = vec![0.0; s.col_rows() * s.col_cols()];
            conv2d_forward(x, w, bias, &s, batch, &mut y, &mut colb, false);
            y.iter().zip(&t).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let mut dw = vec![0.0; w.len()];
        let mut db = vec![0.0; bias.len()];
        let mut dx = vec![0.0; x.len()];
        conv2d_backward(&x, &w, &t, &s, batch, &mut dw, &mut db, Some(&mut dx), &mut col, &mut dcol);
        let eps = 1e-2f32;
        // Spot-check a handful of coordinates of each gradient.
        for &i in &[0usize, 7, w.len() / 2, w.len() - 1] {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (fwd_loss(&wp, &bias, &x) - fwd_loss(&wm, &bias, &x)) / (2.0 * eps as f64);
            assert!(
                (num - dw[i] as f64).abs() < 0.05 * (num.abs().max(1.0)),
                "dw[{i}]: numeric {num} analytic {}",
                dw[i]
            );
        }
        for &i in &[0usize, x.len() / 3, x.len() - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (fwd_loss(&w, &bias, &xp) - fwd_loss(&w, &bias, &xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 0.05 * (num.abs().max(1.0)),
                "dx[{i}]: numeric {num} analytic {}",
                dx[i]
            );
        }
        for i in 0..bias.len() {
            let mut bp = bias.clone();
            bp[i] += eps;
            let mut bm = bias.clone();
            bm[i] -= eps;
            let num = (fwd_loss(&w, &bp, &x) - fwd_loss(&w, &bm, &x)) / (2.0 * eps as f64);
            assert!((num - db[i] as f64).abs() < 0.05 * num.abs().max(1.0));
        }
    }

    #[test]
    fn maxpool_forward_backward() {
        // One 4x4 plane.
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0,   5.0, 6.0,
            3.0, 4.0,   8.0, 7.0,
            0.0, 0.5,   1.0, 1.5,
            0.2, 0.1,   2.0, 1.0,
        ];
        let mut y = vec![0.0; 4];
        let mut arg = vec![0u32; 4];
        maxpool2_forward(&x, 1, 4, 4, &mut y, &mut arg);
        assert_eq!(y, vec![4.0, 8.0, 0.5, 2.0]);
        let dy = vec![1.0, 2.0, 3.0, 4.0];
        let mut dx = vec![0.0; 16];
        maxpool2_backward(&dy, &arg, &mut dx);
        assert_eq!(dx[5], 1.0); // position of 4.0
        assert_eq!(dx[6], 2.0); // position of 8.0
        assert_eq!(dx[9], 3.0); // position of 0.5
        assert_eq!(dx[14], 4.0); // position of 2.0
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn count_correct_and_ce_sum() {
        let logits = vec![1.0, 5.0, 0.0, 9.0, 0.0, 0.0];
        let labels = vec![1, 0];
        assert_eq!(count_correct(&logits, &labels, 3, 2), 2);
        assert_eq!(count_correct(&logits, &labels, 3, 1), 1);
        let ce = cross_entropy_sum(&logits, &labels, 3, 2);
        assert!(ce > 0.0 && ce < 0.1); // confident correct predictions
    }
}
