//! Identity "compressor": dense f32 wire format (the K=100% baseline).

use super::{Codec, CodecMeta, Compressed, Compressor};
use crate::util::rng::Rng;

/// The identity operator: dense 32·d-bit payloads, no information loss.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta {
        payload.clear();
        payload.reserve(x.len() * 4);
        for v in x {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        CodecMeta {
            wire_bits: 32 * x.len() as u64,
            dim: x.len(),
            codec: Codec::Dense,
        }
    }

    fn decompress(&self, c: &Compressed) -> Vec<f32> {
        assert_eq!(c.codec, Codec::Dense);
        super::decode_payload(c.codec, c.dim, &c.payload)
    }

    fn apply(&self, _x: &mut [f32], _rng: &mut Rng) {}

    fn nominal_bits(&self, d: usize) -> u64 {
        32 * d as u64
    }
}

/// Dense payload decoder into a caller buffer: raw little-endian f32s (see
/// [`super::decode_payload_into`]).
pub(super) fn decode_dense_into(dim: usize, payload: &[u8], out: &mut [f32]) {
    assert_eq!(payload.len(), dim * 4, "dense payload length mismatch");
    debug_assert_eq!(out.len(), dim);
    for (slot, b) in out.iter_mut().zip(payload.chunks_exact(4)) {
        *slot = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::seed_from_u64(0);
        let x = vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let c = Identity.compress(&x, &mut rng);
        assert_eq!(c.wire_bits, 32 * 5);
        assert_eq!(Identity.decompress(&c), x);
    }

    #[test]
    fn apply_is_noop() {
        let mut rng = Rng::seed_from_u64(0);
        let mut x = vec![1.0, 2.0];
        Identity.apply(&mut x, &mut rng);
        assert_eq!(x, vec![1.0, 2.0]);
    }
}
