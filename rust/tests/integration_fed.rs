//! Integration tests: the federated algorithms end-to-end on the native
//! compute plane (synthetic FedMNIST, scaled-down configs).

use fedcomloc::compress::{parse_spec, Identity, TopK};
use fedcomloc::data::DatasetKind;
use fedcomloc::fed::{run, AlgorithmSpec, RunConfig, Variant};
use fedcomloc::model::native::NativeTrainer;
use fedcomloc::model::ModelKind;
use std::sync::Arc;

fn quick_cfg() -> RunConfig {
    RunConfig {
        train_n: 2_000,
        test_n: 500,
        n_clients: 20,
        clients_per_round: 5,
        rounds: 25,
        eval_every: 5,
        gamma: 0.05,
        ..RunConfig::default_mnist()
    }
}

fn native() -> Arc<NativeTrainer> {
    Arc::new(NativeTrainer::new(ModelKind::Mlp))
}

#[test]
fn fedcomloc_com_learns_and_counts_bits() {
    let cfg = quick_cfg();
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(TopK::with_density(0.3)),
    };
    let log = run(&cfg, native(), &spec);
    assert_eq!(log.records.len(), 25);
    let acc = log.best_accuracy().unwrap();
    assert!(acc > 0.45, "accuracy {acc}");
    // Compressed uplink must be well below dense uplink.
    let dense_bits = 32 * ModelKind::Mlp.dim() as u64 * cfg.clients_per_round as u64;
    let r0 = &log.records[0];
    assert!(r0.uplink_bits < dense_bits / 2, "uplink {}", r0.uplink_bits);
    assert_eq!(r0.downlink_bits, dense_bits);
    // Cumulative counters are monotone.
    for w in log.records.windows(2) {
        assert!(w[1].cum_uplink_bits > w[0].cum_uplink_bits);
        assert!(w[1].total_cost > w[0].total_cost);
    }
}

#[test]
fn fedcomloc_uncompressed_beats_chance_quickly() {
    let cfg = quick_cfg();
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(Identity),
    };
    let log = run(&cfg, native(), &spec);
    assert!(log.best_accuracy().unwrap() > 0.5);
    // Identity uplink counts full dense bits.
    let dense_bits = 32 * ModelKind::Mlp.dim() as u64 * cfg.clients_per_round as u64;
    assert_eq!(log.records[0].uplink_bits, dense_bits);
}

#[test]
fn variants_all_run_and_learn() {
    for variant in [Variant::Com, Variant::Local, Variant::Global] {
        let cfg = quick_cfg();
        let spec = AlgorithmSpec::FedComLoc {
            variant,
            compressor: Box::new(TopK::with_density(0.5)),
        };
        let log = run(&cfg, native(), &spec);
        let acc = log.best_accuracy().unwrap();
        assert!(acc > 0.35, "variant {variant:?} acc {acc}");
        if variant == Variant::Global {
            // Downlink compressed after the first aggregation.
            let later = &log.records[3];
            let dense =
                32 * ModelKind::Mlp.dim() as u64 * cfg.clients_per_round as u64;
            assert!(later.downlink_bits < dense, "downlink {}", later.downlink_bits);
        }
    }
}

#[test]
fn quantized_fedcomloc_learns() {
    let cfg = quick_cfg();
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: parse_spec("q:8").unwrap(),
    };
    let log = run(&cfg, native(), &spec);
    assert!(log.best_accuracy().unwrap() > 0.45);
    // 8-bit quantization: ~10 bits/coord on our wire vs 32 dense.
    let dense_bits = 32 * ModelKind::Mlp.dim() as u64 * cfg.clients_per_round as u64;
    assert!(log.records[0].uplink_bits < dense_bits / 3 + 64_000);
}

#[test]
fn baselines_run_and_learn() {
    let cfg = quick_cfg();
    for spec in [
        AlgorithmSpec::FedAvg {
            compressor: Box::new(Identity),
        },
        AlgorithmSpec::FedAvg {
            compressor: Box::new(TopK::with_density(0.3)),
        },
        AlgorithmSpec::Scaffold,
        AlgorithmSpec::FedDyn { alpha: 0.01 },
    ] {
        let name = spec.name();
        let log = run(&cfg, native(), &spec);
        let acc = log.best_accuracy().unwrap();
        assert!(acc > 0.3, "{name} acc {acc}");
        assert_eq!(log.records.len(), cfg.rounds);
    }
}

#[test]
fn scaffold_uplink_is_double() {
    let cfg = quick_cfg();
    let log = run(&cfg, native(), &AlgorithmSpec::Scaffold);
    let dense_bits = 32 * ModelKind::Mlp.dim() as u64 * cfg.clients_per_round as u64;
    assert_eq!(log.records[0].uplink_bits, 2 * dense_bits);
    assert_eq!(log.records[0].downlink_bits, 2 * dense_bits);
}

#[test]
fn control_variate_sum_stays_zero_for_com() {
    // Σ h_i = 0 is Algorithm 1's invariant under -Com (exact averaging).
    use fedcomloc::fed::Federation;
    let cfg = quick_cfg();
    let mut fed = Federation::new(&cfg, native());
    let comp = TopK::with_density(0.3);
    let log = fedcomloc::fed::scaffnew::run(&cfg, &mut fed, Variant::Com, &comp);
    assert!(log.best_accuracy().is_some());
    let h_sum = fed.control_variate_sum();
    let norm = fedcomloc::tensor::norm2(&h_sum);
    // f32 accumulation over 25 rounds: tolerance scales with dim.
    assert!(norm < 0.05, "sum of control variates drifted: {norm}");
}

#[test]
fn deterministic_given_seed() {
    let cfg = quick_cfg();
    let mk = || AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(TopK::with_density(0.3)),
    };
    let a = run(&cfg, native(), &mk());
    let b = run(&cfg, native(), &mk());
    let accs_a: Vec<_> = a.records.iter().map(|r| r.test_accuracy).collect();
    let accs_b: Vec<_> = b.records.iter().map(|r| r.test_accuracy).collect();
    assert_eq!(accs_a, accs_b);
    assert_eq!(
        a.records.last().unwrap().cum_uplink_bits,
        b.records.last().unwrap().cum_uplink_bits
    );
}

#[test]
fn smaller_p_means_fewer_comm_rounds_per_iteration() {
    // With p = 0.5 vs p = 0.05 the same number of communication rounds
    // consumes ~10x fewer local iterations.
    let mut cfg = quick_cfg();
    cfg.rounds = 20;
    cfg.p = 0.5;
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(Identity),
    };
    let log_hi = run(&cfg, native(), &spec);
    cfg.p = 0.05;
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(Identity),
    };
    let log_lo = run(&cfg, native(), &spec);
    let iters_hi: usize = log_hi.records.iter().map(|r| r.local_steps).sum();
    let iters_lo: usize = log_lo.records.iter().map(|r| r.local_steps).sum();
    assert!(
        iters_lo > 4 * iters_hi,
        "p=0.05 iters {iters_lo} vs p=0.5 iters {iters_hi}"
    );
    // And total cost reflects the τ-weighted tradeoff.
    let cost_hi = log_hi.records.last().unwrap().total_cost;
    let cost_lo = log_lo.records.last().unwrap().total_cost;
    assert!(cost_lo > cost_hi);
}

#[test]
fn dataset_kind_cifar_runs_with_native_cnn() {
    // Tiny CNN smoke (native conv is slow; keep rounds minimal).
    let cfg = RunConfig {
        dataset: DatasetKind::Cifar10,
        train_n: 320,
        test_n: 64,
        n_clients: 4,
        clients_per_round: 2,
        rounds: 2,
        p: 0.5,
        batch_size: 16,
        eval_batch: 32,
        eval_every: 2,
        ..RunConfig::default_cifar()
    };
    let trainer = Arc::new(NativeTrainer::new(ModelKind::Cnn));
    let spec = AlgorithmSpec::FedComLoc {
        variant: Variant::Com,
        compressor: Box::new(TopK::with_density(0.3)),
    };
    let log = run(&cfg, trainer, &spec);
    assert_eq!(log.records.len(), 2);
    assert!(log.best_accuracy().is_some());
}
