//! Figure 9: FedComLoc variants vs FedAvg / sparseFedAvg / Scaffold / FedDyn.
//!
//! Left panel: compressed methods (sparseFedAvg at γ=0.1 vs FedComLoc at the
//! lower γ=0.05, as in §4.7). Right panel: uncompressed FedAvg vs Scaffold
//! vs FedDyn vs FedComLoc at a shared γ.

use super::ExpOptions;
use crate::compress::{Identity, TopK};
use crate::fed::{run as fed_run, AlgorithmSpec, RunConfig, Variant};
use crate::model::ModelKind;

pub const DENSITY: f64 = 0.30;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let trainer = opts.make_trainer(ModelKind::Mlp);

    println!("\n=== Figure 9 (left): compressed methods ===");
    // sparseFedAvg at γ=0.1; FedComLoc variants at γ=0.05 (paper §4.7).
    let runs: Vec<(&str, f32, AlgorithmSpec)> = vec![
        (
            "sparseFedAvg",
            0.1,
            AlgorithmSpec::FedAvg {
                compressor: Box::new(TopK::with_density(DENSITY)),
            },
        ),
        (
            "FedComLoc-Com",
            0.05,
            AlgorithmSpec::FedComLoc {
                variant: Variant::Com,
                compressor: Box::new(TopK::with_density(DENSITY)),
            },
        ),
        (
            "FedComLoc-Local",
            0.05,
            AlgorithmSpec::FedComLoc {
                variant: Variant::Local,
                compressor: Box::new(TopK::with_density(DENSITY)),
            },
        ),
        (
            "FedComLoc-Global",
            0.05,
            AlgorithmSpec::FedComLoc {
                variant: Variant::Global,
                compressor: Box::new(TopK::with_density(DENSITY)),
            },
        ),
    ];
    report(opts, &trainer, runs, "fig9-left")?;

    println!("\n=== Figure 9 (right): uncompressed methods, shared γ ===");
    let gamma = 0.05; // paper uses a uniform small rate for this panel
    let runs: Vec<(&str, f32, AlgorithmSpec)> = vec![
        (
            "FedAvg",
            gamma,
            AlgorithmSpec::FedAvg {
                compressor: Box::new(Identity),
            },
        ),
        ("Scaffold", gamma, AlgorithmSpec::Scaffold),
        ("FedDyn", gamma, AlgorithmSpec::FedDyn { alpha: 0.01 }),
        (
            "FedComLoc",
            gamma,
            AlgorithmSpec::FedComLoc {
                variant: Variant::Com,
                compressor: Box::new(Identity),
            },
        ),
    ];
    report(opts, &trainer, runs, "fig9-right")?;
    Ok(())
}

fn report(
    opts: &ExpOptions,
    trainer: &std::sync::Arc<dyn crate::model::LocalTrainer>,
    runs: Vec<(&str, f32, AlgorithmSpec)>,
    tag: &str,
) -> anyhow::Result<()> {
    println!(
        "{:<18}{:>8}{:>12}{:>12}{:>16}{:>16}",
        "method", "γ", "best_acc", "final_loss", "uplink_bits", "rounds_to_60%"
    );
    for (name, gamma, spec) in runs {
        let cfg = RunConfig {
            gamma,
            ..opts.scale_cfg(RunConfig::default_mnist())
        };
        log::info!("{tag}: {name}");
        let log = fed_run(&cfg, trainer.clone(), &spec);
        let acc = log.best_accuracy().unwrap_or(0.0);
        let loss = log.final_train_loss().unwrap_or(f64::NAN);
        let bits = log.total_uplink_bits();
        let to60 = log
            .rounds_to_accuracy(0.60)
            .map(|(r, _)| r.to_string())
            .unwrap_or_else(|| "-".into());
        opts.save(tag, &log);
        println!("{name:<18}{gamma:>8}{acc:>12.4}{loss:>12.4}{bits:>16}{to60:>16}");
    }
    Ok(())
}
