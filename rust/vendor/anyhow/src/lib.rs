//! Minimal offline error facade.
//!
//! API-compatible with the subset of `anyhow` this repository uses: the
//! [`Error`] type-erased error, [`Result`] alias, and the [`anyhow!`]/
//! [`bail!`] macros. Like the real crate, [`Error`] deliberately does *not*
//! implement `std::error::Error`, which is what lets the blanket
//! `From<E: Error>` conversion (powering `?`) coexist with coherence.

use std::fmt;

/// A type-erased error: a rendered message (all call sites in this
/// repository either format a message or convert a typed error once at the
/// boundary, so no downcasting machinery is needed).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulted to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let what = "thing";
        let b = anyhow!("missing {} ({what})", 3);
        assert_eq!(b.to_string(), "missing 3 (thing)");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            let _ = std::str::from_utf8(&[0xFF])?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn bail_returns() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(inner(true).unwrap_err().to_string(), "flag was true");
    }
}
