//! Acceptance pins for the checkpoint subsystem (`fedcomloc::ckpt`):
//!
//! * **bit-identical resume** — a run killed after round k and restarted
//!   from its latest snapshot produces byte-identical per-round metrics
//!   (the sweep sink's canonical JSONL lines) to an uninterrupted run,
//!   across all four algorithm families, a stateful `ef(...)` uplink
//!   pipeline, and a `semisync:K` scenario with pending stragglers;
//! * the checkpointing observer itself never perturbs training — an
//!   observed run equals the plain `run_with_transport` drive byte for
//!   byte;
//! * retention keeps only the last `keep_last` snapshots and the final
//!   round is always captured;
//! * `ServeState` loaded from the final snapshot reproduces the recorded
//!   final-round test accuracy **exactly** (same trainer plane, same
//!   fold order), and answers `eval`/`predict`/`info` requests.

use fedcomloc::ckpt::{latest_checkpoint, Checkpointer, ServeState};
use fedcomloc::data::DatasetSpec;
use fedcomloc::fed::transport::parse_transport;
use fedcomloc::fed::{
    run_with_transport, run_with_transport_observed, AlgorithmSpec, RunConfig,
};
use fedcomloc::metrics::MetricsLog;
use fedcomloc::sweep::sink;
use std::path::{Path, PathBuf};

/// Fresh scratch dir under the system temp root (removed on re-entry so
/// reruns never resume from a previous test process's snapshots).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedcomloc-ckptres-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Fast convex workload (softmax on flat synthetic Gaussians, d = 132),
/// driven through the discrete-event `semisync:2` scenario so snapshots
/// must carry pending straggler deliveries across the kill point.
fn tiny_cfg(compress_up: &str) -> RunConfig {
    RunConfig {
        dataset: DatasetSpec::parse("synthetic:32-c4").unwrap(),
        train_n: 400,
        test_n: 100,
        n_clients: 6,
        clients_per_round: 4,
        rounds: 6,
        eval_every: 2,
        batch_size: 16,
        eval_batch: 32,
        threads: 1,
        compress_up: compress_up.to_string(),
        scenario: "semisync:2".to_string(),
        ..RunConfig::default_mnist()
    }
}

fn run_observed(cfg: &RunConfig, spec: &AlgorithmSpec, ckpt: &mut Checkpointer) -> MetricsLog {
    let trainer =
        fedcomloc::runtime::build_trainer("native", Path::new("artifacts"), &cfg.model_spec());
    let mut transport = parse_transport("inproc", cfg.seed).unwrap();
    run_with_transport_observed(cfg, trainer, spec, transport.as_mut(), ckpt)
        .unwrap_or_else(|e| panic!("observed run failed: {e}"))
}

/// The deterministic per-round serialization the sweep sink writes to
/// `rounds/<run_id>.jsonl` (wall-clock excluded) — byte equality here is
/// the acceptance bar for "bit-identical resume".
fn lines(log: &MetricsLog) -> Vec<String> {
    log.records.iter().map(|r| sink::round_line("case", r)).collect()
}

/// Kill after 3 completed rounds, resume from the surviving snapshot, and
/// require the stitched run to match an uninterrupted one byte for byte.
fn assert_resume_bit_identical(algo: &str, compress_up: &str, tag: &str) {
    let cfg = tiny_cfg(compress_up);
    let spec = AlgorithmSpec::parse(algo).unwrap_or_else(|e| panic!("{algo}: {e}"));
    let root = tmp_dir(tag);

    // Uninterrupted reference, checkpointing every round.
    let dir_a = root.join("a");
    let mut ckpt_a = Checkpointer::new(&dir_a, spec.key());
    let log_a = run_observed(&cfg, &spec, &mut ckpt_a);
    assert_eq!(ckpt_a.resumed_from(), None, "{tag}: fresh dir must not resume");
    assert_eq!(log_a.records.len(), cfg.rounds);

    // Simulated crash: the observer stops the drive after round 3's
    // snapshot lands, mid-run and without finalization.
    let dir_b = root.join("b");
    let mut crash = Checkpointer::new(&dir_b, spec.key()).crash_after(3);
    let partial = run_observed(&cfg, &spec, &mut crash);
    assert_eq!(partial.records.len(), 3, "{tag}: crash must stop the drive mid-run");
    assert_eq!(lines(&partial), lines(&log_a)[..3].to_vec(), "{tag}: pre-crash rounds");

    // Fresh process, same checkpoint dir: restart and run to completion.
    let mut resume = Checkpointer::new(&dir_b, spec.key());
    let log_b = run_observed(&cfg, &spec, &mut resume);
    assert_eq!(resume.resumed_from(), Some(3), "{tag}: must resume at round 3");
    assert_eq!(log_b.records.len(), cfg.rounds);

    let (a, b) = (lines(&log_a), lines(&log_b));
    for (la, lb) in a.iter().zip(&b) {
        assert_eq!(la, lb, "{tag}: a resumed round diverged from the uninterrupted run");
    }
    assert_eq!(
        log_a.best_accuracy().map(f64::to_bits),
        log_b.best_accuracy().map(f64::to_bits),
        "{tag}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn fedcomloc_with_ef_pipeline_resumes_bit_identically() {
    assert_resume_bit_identical("fedcomloc-com", "ef(topk:0.25)", "fedcomloc");
}

#[test]
fn fedavg_with_ef_pipeline_resumes_bit_identically() {
    assert_resume_bit_identical("fedavg", "ef(topk:0.25)", "fedavg");
}

#[test]
fn scaffold_resumes_bit_identically() {
    // Scaffold ships two vectors per direction and rejects stateful
    // pipelines; its control variates still ride in the snapshot.
    assert_resume_bit_identical("scaffold", "none", "scaffold");
}

#[test]
fn feddyn_resumes_bit_identically() {
    assert_resume_bit_identical("feddyn:0.01", "ef(topk:0.25)", "feddyn");
}

/// Million-client population, 4-client cohorts, stateful `ef(...)` uplink:
/// crash-and-resume must stay byte-identical, and snapshots must serialize
/// only the *touched* clients — the file size is cohort-bounded, not
/// population-proportional.
#[test]
fn million_client_run_resumes_bit_identically_with_sparse_snapshots() {
    let mut cfg = tiny_cfg("ef(topk:0.25)");
    cfg.n_clients = 1_000_000;
    cfg.clients_per_round = 4;
    let spec = AlgorithmSpec::parse("fedcomloc-com").unwrap();
    let root = tmp_dir("million");

    // Uninterrupted reference, checkpointing every round.
    let dir_a = root.join("a");
    let mut ckpt_a = Checkpointer::new(&dir_a, spec.key());
    let log_a = run_observed(&cfg, &spec, &mut ckpt_a);
    assert_eq!(log_a.records.len(), cfg.rounds);

    // Crash after round 3, then restart from the surviving snapshot: the
    // restore path materializes exactly the checkpointed residents (with
    // their `ef` residuals) out of the 10^6-client population.
    let dir_b = root.join("b");
    let mut crash = Checkpointer::new(&dir_b, spec.key()).crash_after(3);
    let partial = run_observed(&cfg, &spec, &mut crash);
    assert_eq!(partial.records.len(), 3, "crash must stop the drive mid-run");
    let mut resume = Checkpointer::new(&dir_b, spec.key());
    let log_b = run_observed(&cfg, &spec, &mut resume);
    assert_eq!(resume.resumed_from(), Some(3), "must resume at round 3");
    assert_eq!(lines(&log_a), lines(&log_b), "resumed run diverged at 1M clients");

    // Same workload at the seed's 6-client population: the only state
    // difference is how many clients the cohorts touched, so the 1M-client
    // snapshot may be at most a small constant factor larger — never the
    // ~10^5x a population-proportional clients section would cost.
    let small_cfg = tiny_cfg("ef(topk:0.25)");
    let dir_s = root.join("s");
    let mut ckpt_s = Checkpointer::new(&dir_s, spec.key());
    let _ = run_observed(&small_cfg, &spec, &mut ckpt_s);
    let (_, path_big) = latest_checkpoint(&dir_a).unwrap();
    let (_, path_small) = latest_checkpoint(&dir_s).unwrap();
    let big = std::fs::metadata(&path_big).unwrap().len();
    let small = std::fs::metadata(&path_small).unwrap().len();
    assert!(
        big <= 8 * small,
        "1M-client snapshot is {big} B vs {small} B at 6 clients: \
         the clients section scales with population, not touched clients"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn observer_never_perturbs_training() {
    let cfg = tiny_cfg("ef(topk:0.25)");
    let spec = AlgorithmSpec::parse("fedcomloc-com").unwrap();
    let trainer =
        fedcomloc::runtime::build_trainer("native", Path::new("artifacts"), &cfg.model_spec());
    let mut plain_transport = parse_transport("inproc", cfg.seed).unwrap();
    let plain = run_with_transport(&cfg, trainer, &spec, plain_transport.as_mut());

    let root = tmp_dir("noperturb");
    let mut ckpt = Checkpointer::new(&root, spec.key());
    let observed = run_observed(&cfg, &spec, &mut ckpt);
    assert_eq!(lines(&plain), lines(&observed), "snapshotting must be invisible to the math");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn retention_prunes_to_keep_last_and_always_captures_the_final_round() {
    let cfg = tiny_cfg("none");
    let spec = AlgorithmSpec::parse("fedavg").unwrap();
    let root = tmp_dir("retention");
    let mut ckpt = Checkpointer::new(&root, spec.key()).every(1).keep_last(2);
    let _ = run_observed(&cfg, &spec, &mut ckpt);
    let mut kept: Vec<String> = std::fs::read_dir(&root)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    kept.sort();
    assert_eq!(kept, vec!["ckpt-000005.fckp", "ckpt-000006.fckp"]);
    let (round, _) = latest_checkpoint(&root).unwrap();
    assert_eq!(round, cfg.rounds as u64);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn serve_reproduces_the_recorded_final_accuracy_exactly() {
    let cfg = tiny_cfg("ef(topk:0.25)");
    let spec = AlgorithmSpec::parse("fedcomloc-com").unwrap();
    let root = tmp_dir("serve");
    let mut ckpt = Checkpointer::new(&root, spec.key());
    let log = run_observed(&cfg, &spec, &mut ckpt);

    let (round, path) = latest_checkpoint(&root).unwrap();
    assert_eq!(round, cfg.rounds as u64);
    let mut serve = ServeState::load(&path, "native", Path::new("artifacts")).unwrap();
    assert_eq!(serve.round(), cfg.rounds as u64);
    assert_eq!(serve.algo_spec(), spec.key());

    // The snapshot's record trail carries the final evaluated accuracy...
    let trained = log.records.last().unwrap().test_accuracy.unwrap();
    let recorded = serve.recorded_accuracy().unwrap();
    assert_eq!(recorded.to_bits(), trained.to_bits(), "snapshot records drifted");

    // ...and re-evaluating the restored parameters over the re-derived
    // test split lands on the same number bit for bit: train → snapshot →
    // serve is lossless end to end.
    let eval = serve.eval();
    assert_eq!(eval.examples, cfg.test_n);
    assert_eq!(eval.accuracy.to_bits(), trained.to_bits(), "served accuracy drifted");

    // The line protocol agrees with the typed API and stays total on use.
    let reply = serve.handle_line(r#"{"cmd":"eval"}"#);
    assert!(reply.contains("\"accuracy\""), "eval reply: {reply}");
    assert!(reply.contains("\"matches_recorded\":true"), "eval reply: {reply}");
    let row = vec![0.0f32; 32];
    let (label, probs) = serve.predict(&row).unwrap();
    assert!(label < 4, "label {label}");
    // exp(−loss) probes recover the softmax outputs, which sum to 1 up to
    // the f32 forward pass's rounding.
    assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-3, "probs {probs:?}");
    let _ = std::fs::remove_dir_all(&root);
}
