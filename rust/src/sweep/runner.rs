//! The sweep executor: expand a [`SweepSpec`], run every [`RunUnit`] in
//! parallel on the shared [`crate::util::threadpool::ThreadPool`] (one run
//! per worker), and stream results through the [`super::sink`].
//!
//! # Determinism
//!
//! Each run derives every RNG stream (partitioning, client loaders,
//! compression stochasticity, transport dropout) from its own `cfg.seed`,
//! and the sink excludes wall-clock time, so a sweep's `summary.csv` and
//! `rounds/*.jsonl` are **byte-identical** for any `--threads` value and
//! any completion order (pinned by `tests/sweep_engine.rs`).
//!
//! # Resume
//!
//! `resume: true` reads the existing `summary.csv` and skips every run
//! whose row is already present **with a matching configuration prefix**
//! (schema, run id, algo, dataset, model, transport, effective backend, and
//! every scalar setting — see [`sink::summary_key`]) **and** whose
//! per-round JSONL file is still on disk; a row left over from an edited
//! sweep file or different CLI options mismatches and is re-executed, so
//! stale results are never silently reused, and JSONL files from runs no
//! longer in the expansion are deleted. Rows are appended in
//! completion order while running, so a killed sweep loses at most the
//! in-flight runs; on completion the file is rewritten in canonical
//! expansion order.

use super::sink;
use super::spec::{RunUnit, SweepSpec};
use crate::fed::transport::parse_transport;
use crate::fed::{run_with_transport, run_with_transport_observed, AlgorithmSpec};
use crate::model::LocalTrainer;
use crate::util::threadpool::ThreadPool;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One compute plane per distinct `backend|model` pair, shared by every
/// run in the sweep (a PJRT engine load is expensive; units overwhelmingly
/// share one model). The key includes the *effective* backend so a sweep
/// mixing a `backends` axis never hands a `native` unit a SIMD plane (or
/// vice versa). Building happens under the lock so a cold engine is loaded
/// exactly once even when many workers race on the same key.
type TrainerCache = Mutex<BTreeMap<String, Arc<dyn LocalTrainer>>>;

/// Execution options for [`run_sweep`] (the CLI's `sweep run` flags).
pub struct SweepOptions {
    /// Root output directory; results land in `<out_dir>/<sweep-name>/`.
    pub out_dir: PathBuf,
    /// Sweep-level worker count (runs in flight at once; 0 = auto). Each
    /// run's *inner* client pool is forced to 1 thread while the sweep
    /// itself is parallel, unless the run config pins `threads` explicitly.
    pub threads: usize,
    /// Print the expanded matrix and exit without running anything.
    pub dry_run: bool,
    /// Skip runs whose summary row already exists with a matching
    /// configuration prefix (see module docs).
    pub resume: bool,
    /// Multiplier on rounds/dataset sizes (the experiment `--scale`).
    pub scale: f64,
    /// Base-seed override (an explicit `seeds` axis still wins).
    pub seed: Option<u64>,
    /// Compute-plane backend key ([`crate::backend`] registry): `auto`,
    /// `native`, `native-simd`, `native-bf16`, `xla` (alias `pjrt`). A
    /// unit whose config pins its own `backend` key (e.g. via a sweep
    /// `backends` axis) wins over this option
    /// ([`crate::backend::effective_backend`]).
    pub backend: String,
    /// AOT artifacts directory for the PJRT plane.
    pub artifacts_dir: PathBuf,
    /// When set, every run checkpoints into
    /// `<checkpoint_dir>/<run_id>/` via a [`crate::ckpt::Checkpointer`]
    /// and auto-resumes from the latest snapshot there — a killed sweep
    /// restarted with `--resume` re-enters each unfinished run at its
    /// last checkpointed round instead of from scratch.
    pub checkpoint_dir: Option<PathBuf>,
    /// Snapshot cadence in rounds for [`SweepOptions::checkpoint_dir`]
    /// (0 = every round).
    pub checkpoint_every: usize,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            out_dir: PathBuf::from("results"),
            threads: 0,
            dry_run: false,
            resume: false,
            scale: 1.0,
            seed: None,
            backend: "auto".to_string(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            checkpoint_dir: None,
            checkpoint_every: 0,
        }
    }
}

/// What [`run_sweep`] hands back.
pub struct SweepOutcome {
    /// The sweep's name (output subdirectory under `out_dir`).
    pub name: String,
    /// `<out_dir>/<name>` (unset for dry runs).
    pub dir: PathBuf,
    /// The expanded matrix, in canonical order.
    pub units: Vec<RunUnit>,
    /// Runs executed this invocation.
    pub executed: usize,
    /// Runs skipped because `--resume` found their summary row.
    pub skipped: usize,
    /// Canonical summary rows (empty for dry runs).
    pub rows: Vec<String>,
}

/// Render the expanded matrix as the `--dry-run` table.
pub fn format_matrix(units: &[RunUnit]) -> String {
    let mut out = format!(
        "{:<40}{:<34}{:<18}{:<22}{:<10}{:<18}{:<26}{:<26}{:>7}{:>7}{:>7}{:>8}{:>8}{:>7}\n",
        "run_id", "algo", "dataset", "model", "transport", "scenario", "up", "down", "rounds", "local", "p", "alpha", "gamma", "seed"
    );
    for u in units {
        out.push_str(&format!(
            "{:<40}{:<34}{:<18}{:<22}{:<10}{:<18}{:<26}{:<26}{:>7}{:>7}{:>7}{:>8}{:>8}{:>7}\n",
            u.id,
            u.algo,
            u.cfg.dataset.key(),
            u.model_key(),
            u.transport,
            u.cfg.scenario,
            u.cfg.compress_up,
            u.cfg.compress_down,
            u.cfg.rounds,
            u.cfg.local_steps,
            u.cfg.p,
            u.cfg.dirichlet_alpha,
            u.cfg.gamma,
            u.cfg.seed,
        ));
    }
    out
}

fn run_unit(
    sweep_name: &str,
    sweep_dir: &Path,
    unit: &RunUnit,
    opts: &SweepOptions,
    sweep_workers: usize,
    trainers: &TrainerCache,
) -> Result<String, String> {
    let mut cfg = unit.cfg.clone();
    if cfg.threads == 0 && sweep_workers > 1 {
        // The sweep already saturates the cores one-run-per-worker; a
        // per-run auto-sized client pool would oversubscribe. Results are
        // invariant to this (see module docs). With one inner thread, each
        // run's Federation owns exactly one compute `model::Workspace`
        // that stays warm for the run's whole lifetime — the sweep-level
        // instantiation of the one-workspace-per-worker rule
        // (ARCHITECTURE.md "Compute core & workspaces").
        cfg.threads = 1;
    }
    let model = cfg.model_spec();
    let backend = crate::backend::effective_backend(&cfg.backend, &opts.backend);
    let trainer = {
        let mut cache = trainers.lock().unwrap();
        let cache_key = format!("{backend}|{}", model.key());
        match cache.get(&cache_key) {
            Some(t) => Arc::clone(t),
            None => {
                let t = crate::runtime::build_trainer(backend, &opts.artifacts_dir, &model);
                cache.insert(cache_key, Arc::clone(&t));
                t
            }
        }
    };
    let algo = AlgorithmSpec::parse(&unit.algo)?;
    let mut transport = parse_transport(&unit.transport, cfg.seed)?;
    let t0 = std::time::Instant::now();
    let log = match &opts.checkpoint_dir {
        Some(root) => {
            let mut ckpt = crate::ckpt::Checkpointer::new(&root.join(&unit.id), algo.key())
                .every(opts.checkpoint_every.max(1));
            run_with_transport_observed(&cfg, trainer, &algo, transport.as_mut(), &mut ckpt)
                .map_err(|e| format!("{}: {e}", unit.id))?
        }
        None => run_with_transport(&cfg, trainer, &algo, transport.as_mut()),
    };
    log::info!(
        "[sweep {sweep_name}] {} done in {:.2?}: best_acc={:?}",
        unit.id,
        t0.elapsed(),
        log.best_accuracy()
    );
    sink::write_rounds_jsonl(sweep_dir, &unit.id, &log)
        .map_err(|e| format!("{}: writing rounds jsonl: {e}", unit.id))?;
    Ok(sink::summary_row(sweep_name, backend, unit, &log))
}

/// Expand and execute a sweep (see module docs). Returns an error if the
/// spec fails validation, output files cannot be written, or any run fails;
/// completed runs keep their appended summary rows either way.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepOutcome, String> {
    let units = spec.expand(opts.scale, opts.seed)?;
    if opts.dry_run {
        return Ok(SweepOutcome {
            name: spec.name.clone(),
            dir: PathBuf::new(),
            units,
            executed: 0,
            skipped: 0,
            rows: Vec::new(),
        });
    }
    let dir = opts.out_dir.join(&spec.name);
    if !opts.resume {
        // A fresh run replaces the whole result set: clear any per-round
        // files from a previous (possibly differently-shaped) expansion so
        // the documented `rounds/*.jsonl` glob never mixes in dead runs.
        let _ = std::fs::remove_dir_all(dir.join("rounds"));
    }
    std::fs::create_dir_all(dir.join("rounds"))
        .map_err(|e| format!("cannot create {}: {e}", dir.display()))?;
    let spath = sink::summary_path(&dir);
    // A resumed row counts only if its full configuration prefix (algo,
    // dataset, model, transport, rounds, …, seed) matches the freshly
    // expanded unit — an edited sweep file or different CLI options must
    // re-execute the run, never silently reuse a stale result.
    let existing: BTreeMap<String, String> = if opts.resume {
        let rows = sink::read_summary_rows(&spath);
        units
            .iter()
            .filter_map(|u| {
                // Resumable = summary row with a matching config prefix AND
                // the per-round file still on disk (both outputs must be
                // complete for the run to count as done).
                let row = rows.get(&u.id)?;
                let backend = crate::backend::effective_backend(&u.cfg.backend, &opts.backend);
                let key = sink::summary_key(&spec.name, backend, u);
                (row.starts_with(&format!("{key},"))
                    && sink::rounds_path(&dir, &u.id).is_file())
                .then(|| (u.id.clone(), row.clone()))
            })
            .collect()
    } else {
        BTreeMap::new()
    };
    // Reconcile rounds/: drop JSONL files whose run id is not in the
    // current expansion, so the documented `rounds/*.jsonl` glob never
    // mixes in runs from a previous, differently-shaped sweep file.
    if opts.resume {
        let current: std::collections::BTreeSet<&str> =
            units.iter().map(|u| u.id.as_str()).collect();
        if let Ok(entries) = std::fs::read_dir(dir.join("rounds")) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let stale = name
                    .to_str()
                    .and_then(|n| n.strip_suffix(".jsonl"))
                    .is_some_and(|stem| !current.contains(stem));
                if stale {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
    }
    let todo: Vec<RunUnit> = units
        .iter()
        .filter(|u| !existing.contains_key(&u.id))
        .cloned()
        .collect();
    let skipped = units.len() - todo.len();

    // Fresh header (non-resume truncates any stale file); progress rows are
    // appended in completion order and canonicalized at the end.
    if !opts.resume || !spath.is_file() {
        sink::write_summary(&spath, &[]).map_err(|e| format!("cannot write summary: {e}"))?;
    }
    let progress = Mutex::new(
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&spath)
            .map_err(|e| format!("cannot open summary for append: {e}"))?,
    );

    // Known trade-off: `ThreadPool::map` runs on scoped threads (the
    // pool's persistent workers serve `execute`), so the pool here mainly
    // provides the shared sizing policy and the fork-join primitive — its
    // parked workers cost a few stacks for the sweep's duration, the same
    // profile as the per-run Federation pools.
    let pool = if opts.threads == 0 {
        ThreadPool::with_default_size(todo.len().max(1))
    } else {
        ThreadPool::new(opts.threads.clamp(1, todo.len().max(1)))
    };
    let workers = pool.size();
    log::info!(
        "[sweep {}] {} runs ({} resumed), {} workers -> {}",
        spec.name,
        todo.len(),
        skipped,
        workers,
        dir.display()
    );

    let trainers: TrainerCache = Mutex::new(BTreeMap::new());
    let results: Vec<Result<String, String>> = pool.map(&todo, |_, unit| {
        let row = run_unit(&spec.name, &dir, unit, opts, workers, &trainers)?;
        if let Ok(mut f) = progress.lock() {
            // Flush + fsync each progress row: a crash right after a run
            // completes must not lose its row to OS buffering (the row is
            // what --resume matches to skip re-executing the run).
            let _ = writeln!(f, "{row}");
            let _ = f.flush();
            let _ = f.sync_data();
        }
        Ok(row)
    });

    let mut by_id: BTreeMap<String, String> = existing;
    let mut failures = Vec::new();
    for (unit, result) in todo.iter().zip(results) {
        match result {
            Ok(row) => {
                by_id.insert(unit.id.clone(), row);
            }
            Err(e) => failures.push(e),
        }
    }
    if !failures.is_empty() {
        return Err(format!(
            "{} of {} runs failed; first error: {}",
            failures.len(),
            todo.len(),
            failures[0]
        ));
    }
    let rows: Vec<String> = units
        .iter()
        .map(|u| by_id.get(&u.id).cloned().expect("every run accounted for"))
        .collect();
    sink::write_summary(&spath, &rows).map_err(|e| format!("cannot write summary: {e}"))?;
    Ok(SweepOutcome {
        name: spec.name.clone(),
        dir,
        executed: todo.len(),
        skipped,
        units,
        rows,
    })
}
