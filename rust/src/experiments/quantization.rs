//! Figures 5/15 (quantization sweep) and 7/14 (quantization × heterogeneity).
//!
//! Q_r with r ∈ {4, 8, 16, 32} via FedComLoc-Com on FedMNIST (Fig 5) and
//! FedCIFAR10 (Fig 15); then r ∈ {8, 16} across Dirichlet α (Figs 7/14).

use super::ExpOptions;
use crate::fed::{run as fed_run, AlgorithmSpec, RunConfig};

pub const BITS: [u32; 4] = [4, 8, 16, 32];
pub const HET_BITS: [u32; 2] = [8, 16];
pub const HET_ALPHAS: [f64; 4] = [0.1, 0.3, 0.7, 0.9];

fn spec_for(bits: u32) -> AlgorithmSpec {
    AlgorithmSpec::parse(&format!("fedcomloc-com:q:{bits}")).expect("static spec")
}

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    // ---- Figure 5: FedMNIST sweep ----
    let trainer = opts.trainer_for(&RunConfig::default_mnist());
    println!("\n=== Figure 5: quantization Q_r on FedMNIST ===");
    let mut base_acc = None;
    for &bits in &BITS {
        let cfg = opts.scale_cfg(RunConfig::default_mnist());
        log::info!("fig5: r={bits}");
        let log = fed_run(&cfg, trainer.clone(), &spec_for(bits));
        let acc = log.best_accuracy().unwrap_or(0.0);
        let bits_total = log.total_uplink_bits();
        opts.save("fig5", &log);
        if bits == 32 {
            base_acc = Some(acc);
        }
        println!("  r={bits:>2}  acc={acc:.4}  uplink_bits={bits_total}");
    }
    if let Some(b) = base_acc {
        println!("  (decrease vs r=32 shown in EXPERIMENTS.md; baseline {b:.4})");
    }

    // ---- Figures 7/14: heterogeneity ablation ----
    println!("\n=== Figures 7/14: Q_r × Dirichlet α (FedMNIST) ===");
    for &bits in &HET_BITS {
        for &alpha in &HET_ALPHAS {
            let cfg = RunConfig {
                dirichlet_alpha: alpha,
                ..opts.scale_cfg(RunConfig::default_mnist())
            };
            log::info!("fig7: r={bits} alpha={alpha}");
            let log = fed_run(&cfg, trainer.clone(), &spec_for(bits));
            let acc = log.best_accuracy().unwrap_or(0.0);
            opts.save("fig7", &log);
            println!("  r={bits:>2} α={alpha}  acc={acc:.4}");
        }
    }

    // ---- Figure 15: FedCIFAR10 sweep ----
    println!("\n=== Figure 15: quantization Q_r on FedCIFAR10 ===");
    let trainer = opts.trainer_for(&RunConfig::default_cifar());
    for &bits in &BITS {
        let cfg = opts.scale_cfg(RunConfig::default_cifar());
        log::info!("fig15: r={bits}");
        let log = fed_run(&cfg, trainer.clone(), &spec_for(bits));
        let acc = log.best_accuracy().unwrap_or(0.0);
        opts.save("fig15", &log);
        println!("  r={bits:>2}  acc={acc:.4}  uplink_bits={}", log.total_uplink_bits());
    }
    Ok(())
}
