//! Scaffold (Karimireddy et al., 2020) — the paper's strongest
//! non-accelerated baseline (§4.7, Figure 9).
//!
//! Client i keeps a control variate c_i (stored in `ClientState::h`);
//! the server keeps the global variate c. Local step:
//!     x ← x − γ·(∇f_i(x) − c_i + c)
//! After E steps (option II of the paper):
//!     c_i⁺ = c_i − c + (x_server − x_i)/(E·γ)
//!     uplink Δx = x_i − x_server and Δc = c_i⁺ − c_i
//!     server: x += mean(Δx);  c += (|S|/n)·mean(Δc)
//! Communication is uncompressed both ways, and the uplink carries TWO
//! d-vectors (Δx, Δc) — Scaffold's well-known 2× communication overhead,
//! which the bits-axis plots make visible.

use super::{Federation, RoundLogger, RunConfig};
use crate::metrics::MetricsLog;
use crate::tensor;

pub fn run(cfg: &RunConfig, fed: &mut Federation) -> MetricsLog {
    let name = format!("scaffold-{}-a{}", fed.model.name(), cfg.dirichlet_alpha);
    let log = MetricsLog::new(&name)
        .with_meta("algorithm", "scaffold")
        .with_meta("gamma", cfg.gamma)
        .with_meta("local_steps", cfg.local_steps)
        .with_meta("alpha", cfg.dirichlet_alpha);
    let mut logger = RoundLogger::new(cfg, log);
    let dim = fed.x.len();
    let mut c_global = vec![0.0f32; dim];
    let inv_e_gamma = 1.0 / (cfg.local_steps as f32 * cfg.gamma);

    for round in 0..cfg.rounds {
        logger.begin_round();
        let sampled = fed.sample_clients(cfg.clients_per_round);
        let mut usage = super::transport::WireUsage::default();
        for _ in &sampled {
            // Downlink: x and c (2 dense vectors).
            usage.add_downlink(2 * crate::compress::dense_bits(dim));
        }

        let x = fed.x.clone();
        let c_ref = &c_global;
        let trainer = &fed.trainer;
        let clients = &fed.clients;
        let gamma = cfg.gamma;
        let local_steps = cfg.local_steps;
        // Returns (Δx, Δc, loss_sum); client updates its own c_i in place.
        let results: Vec<(Vec<f32>, Vec<f32>, f64)> = fed.pool.map(&sampled, |_, &ci| {
            let mut state = clients[ci].lock().unwrap();
            let mut xi = x.clone();
            let mut loss_sum = 0.0f64;
            // Effective control-variate correction: −c_i + c ⇒ pass
            // h = c_i − c to the Scaffnew-form step x − γ(g − h).
            let mut h_eff = vec![0.0f32; xi.len()];
            tensor::sub(&state.h, c_ref, &mut h_eff);
            for _ in 0..local_steps {
                let batch = state.loader.next_batch();
                let (next, loss) = trainer.train_step(&xi, &h_eff, &batch, gamma);
                xi = next;
                loss_sum += loss as f64;
            }
            // Option II variate refresh.
            let mut c_new = vec![0.0f32; xi.len()];
            for j in 0..xi.len() {
                c_new[j] = state.h[j] - c_ref[j] + (x[j] - xi[j]) * inv_e_gamma;
            }
            let mut dx = vec![0.0f32; xi.len()];
            tensor::sub(&xi, &x, &mut dx);
            let mut dc = vec![0.0f32; xi.len()];
            tensor::sub(&c_new, &state.h, &mut dc);
            state.h = c_new;
            (dx, dc, loss_sum)
        });

        // Server updates.
        let m = results.len().max(1) as f32;
        let scale_c = m / cfg.n_clients as f32 / m; // (|S|/n)·(1/|S|)
        for (dx, dc, _) in &results {
            tensor::axpy(1.0 / m, dx, &mut fed.x);
            tensor::axpy(scale_c, dc, &mut c_global);
        }
        for _ in &results {
            usage.add_uplink(2 * crate::compress::dense_bits(dim));
        }
        let train_loss = results.iter().map(|(_, _, l)| l).sum::<f64>()
            / (results.len() * cfg.local_steps).max(1) as f64;

        let eval = if (round + 1) % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(fed.evaluate())
        } else {
            None
        };
        logger.end_round(
            round,
            cfg.local_steps,
            train_loss,
            usage.uplink_bits,
            usage.downlink_bits,
            eval,
        );
    }
    logger.finish()
}
