//! Model substrate: the composable layer API and the trainer abstraction.
//!
//! Architectures are selected through the string-keyed, open
//! [`spec::ModelSpec`] registry (mirroring `fed::AlgorithmSpec`) and built
//! as [`layers::Model`] values: typed [`layers::Layer`] descriptors over a
//! single flat f32 parameter vector with one shared
//! [`layers::ParamLayout`], so the coordinator, compressors and transport
//! treat model state uniformly. The paper's two nets (Appendix A.1) are
//! the registry defaults:
//!
//! * **`mlp`** for FedMNIST — 784 → 128 → 64 → 10, ReLU (d = 109,386);
//! * **`cnn`** for FedCIFAR10 — conv5×5(3→32) → pool → conv5×5(32→64) →
//!   pool → fc 1600→384 → fc 384→192 → fc 192→10, ReLU (d = 744,330), the
//!   FedLab reference architecture;
//!
//! and parameterized specs (`mlp:784x512x256x10`, `cnn:c8-f32@3x16`,
//! `linear:<d>`, `softmax:<d>x<c>`) are first-class — see `spec.rs`.
//!
//! Two interchangeable [`LocalTrainer`] implementations execute the local
//! objective: [`native::NativeTrainer`] (pure Rust, generic over the layer
//! sequence via `ops.rs`) and `runtime::PjrtTrainer` (AOT-compiled HLO from
//! the JAX/Pallas layers, available for the artifact-backed seed layouts).
//! The parameter memory layout is identical across both — it is pinned down
//! in `python/compile/models/` and cross-checked by integration tests.

pub mod layers;
pub mod native;
pub mod ops;
pub mod spec;
pub mod workspace;

pub use layers::{Layer, Model, ParamLayout, ParamSlice};
pub use spec::{build_model, model_registry, ModelFamily, ModelSpec};
pub use workspace::Workspace;

use crate::data::loader::{Batch, EvalBatches};
use crate::util::rng::Rng;

/// He-normal weight init, zero biases — shared by both trainers so every
/// algorithm starts from the identical x₀ given the same seed.
pub fn init_params(model: &Model, rng: &mut Rng) -> Vec<f32> {
    model.init(rng)
}

/// Evaluation result over a test set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    /// Mean cross-entropy over the evaluated examples.
    pub mean_loss: f64,
    /// Top-1 accuracy over the evaluated examples.
    pub accuracy: f64,
    /// Number of examples evaluated.
    pub examples: usize,
}

/// Executes the local objective: gradients, fused Scaffnew steps, and
/// evaluation. Implementations must be deterministic given their inputs.
///
/// Every operation comes in two forms: the original allocating signature
/// and a workspace-backed `_into` twin that reuses a caller
/// [`Workspace`] (one per pool worker — see `model::workspace`). The
/// allocating forms are thin wrappers, so the two are bit-identical by
/// construction; the federated drivers run the `_into` fast path.
pub trait LocalTrainer: Send + Sync {
    /// The architecture this trainer computes over.
    fn model(&self) -> &Model;

    /// Total parameter count d of [`LocalTrainer::model`].
    fn dim(&self) -> usize {
        self.model().dim()
    }

    /// Minibatch gradient of the local empirical loss at `params`.
    /// Returns (∇f(params), loss).
    fn grad(&self, params: &[f32], batch: &Batch) -> (Vec<f32>, f32);

    /// Workspace-backed [`LocalTrainer::grad`]: ∇f lands in
    /// `ws.grad[..dim]`, the loss is returned. The default copies through
    /// the allocating path (right for trainers that cannot avoid the
    /// allocation, e.g. PJRT's device transfers); the native trainer
    /// overrides it with the zero-allocation compute core.
    fn grad_into(&self, params: &[f32], batch: &Batch, ws: &mut Workspace) -> f32 {
        let (g, loss) = self.grad(params, batch);
        ws.ensure(self.model(), batch.y.len());
        ws.grad[..g.len()].copy_from_slice(&g);
        loss
    }

    /// Fused Scaffnew local step (Algorithm 1 line 7):
    /// x̂ = params − γ·(∇f(params) − h). Returns (x̂, loss).
    fn train_step(&self, params: &[f32], h: &[f32], batch: &Batch, gamma: f32) -> (Vec<f32>, f32) {
        let (g, loss) = self.grad(params, batch);
        let mut out = vec![0.0f32; params.len()];
        crate::tensor::sgd_control_variate_step(params, &g, h, gamma, &mut out);
        (out, loss)
    }

    /// Workspace-backed [`LocalTrainer::train_step`]: x̂ lands in
    /// `ws.step[..dim]`, the loss is returned. Zero-allocation once the
    /// workspace is warm (pinned by `rust/tests/alloc_steady_state.rs`).
    fn train_step_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        ws: &mut Workspace,
    ) -> f32 {
        let loss = self.grad_into(params, batch, ws);
        let (g, out) = ws.grad_and_step(params.len());
        crate::tensor::sgd_control_variate_step(params, g, h, gamma, out);
        loss
    }

    /// FedComLoc-Local step (Algorithm 1 line 6½): the gradient is evaluated
    /// at the TopK-masked parameters, g = ∇f(TopK_{density}(params)), while
    /// the update is applied to the *unmasked* params.
    fn train_step_masked(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
    ) -> (Vec<f32>, f32) {
        let mut masked = params.to_vec();
        let k = ((density * params.len() as f64).ceil() as usize).clamp(1, params.len());
        crate::compress::topk::apply_topk(&mut masked, k);
        let (g, loss) = self.grad(&masked, batch);
        let mut out = vec![0.0f32; params.len()];
        crate::tensor::sgd_control_variate_step(params, &g, h, gamma, &mut out);
        (out, loss)
    }

    /// Workspace-backed [`LocalTrainer::train_step_masked`]: x̂ lands in
    /// `ws.step[..dim]`, the loss is returned. The masked parameter copy
    /// and the TopK selection scratch both live in the workspace.
    fn train_step_masked_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
        ws: &mut Workspace,
    ) -> f32 {
        let d = params.len();
        let k = ((density * d as f64).ceil() as usize).clamp(1, d);
        // Move the masked buffer (and TopK scratch) out of the workspace so
        // the gradient call below can borrow the workspace mutably; moving
        // a Vec is a pointer swap, not an allocation.
        let mut masked = std::mem::take(&mut ws.masked);
        if masked.len() < d {
            masked.resize(d, 0.0);
        }
        masked[..d].copy_from_slice(params);
        let mut keys = std::mem::take(&mut ws.topk_keys);
        let mut idx = std::mem::take(&mut ws.topk_idx);
        crate::compress::topk::apply_topk_with(&mut masked[..d], k, &mut keys, &mut idx);
        ws.topk_keys = keys;
        ws.topk_idx = idx;
        let loss = self.grad_into(&masked[..d], batch, ws);
        ws.masked = masked;
        let (g, out) = ws.grad_and_step(d);
        crate::tensor::sgd_control_variate_step(params, g, h, gamma, out);
        loss
    }

    /// (loss_sum, correct) over the first `valid` rows of one evaluation
    /// batch, through a caller workspace — the primitive the federation's
    /// parallel evaluation fans out over.
    fn eval_batch(
        &self,
        params: &[f32],
        batch: &Batch,
        valid: usize,
        ws: &mut Workspace,
    ) -> (f64, usize);

    /// Workspace-backed evaluation over a whole set: sequential fold of
    /// [`LocalTrainer::eval_batch`] in batch order.
    fn eval_into(&self, params: &[f32], batches: &EvalBatches, ws: &mut Workspace) -> EvalResult {
        eval_with(batches, |batch, valid| self.eval_batch(params, batch, valid, ws))
    }

    /// Mean loss + accuracy over an evaluation set (allocating wrapper
    /// over [`LocalTrainer::eval_into`] with a throwaway workspace).
    fn eval(&self, params: &[f32], batches: &EvalBatches) -> EvalResult {
        let mut ws = Workspace::new();
        self.eval_into(params, batches, &mut ws)
    }
}

/// Shared eval loop used by trainers that expose per-batch (loss_sum,
/// correct) primitives.
pub(crate) fn eval_with<F>(batches: &EvalBatches, mut eval_batch: F) -> EvalResult
where
    F: FnMut(&Batch, usize) -> (f64, usize),
{
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut examples = 0usize;
    for (batch, &valid) in batches.batches.iter().zip(&batches.valid) {
        let (l, c) = eval_batch(batch, valid);
        loss_sum += l;
        correct += c;
        examples += valid;
    }
    EvalResult {
        mean_loss: loss_sum / examples.max(1) as f64,
        accuracy: correct as f64 / examples.max(1) as f64,
        examples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;

    #[test]
    fn dims_match_paper_appendix_a() {
        // MLP 784->128->64->10
        let mlp = build_model("mlp").unwrap();
        assert_eq!(mlp.dim(), 784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(mlp.dim(), 109_386);
        // CNN conv(3->32,5), conv(32->64,5), fc 1600->384->192->10
        let cnn = build_model("cnn").unwrap();
        assert_eq!(
            cnn.dim(),
            32 * 3 * 25 + 32 + 64 * 32 * 25 + 64 + 1600 * 384 + 384 + 384 * 192 + 192 + 192 * 10 + 10
        );
        assert_eq!(cnn.dim(), 744_330);
    }

    #[test]
    fn init_is_seeded_and_scaled() {
        let mlp = build_model("mlp").unwrap();
        let mut r1 = Rng::seed_from_u64(1);
        let mut r2 = Rng::seed_from_u64(1);
        let a = init_params(&mlp, &mut r1);
        let b = init_params(&mlp, &mut r2);
        assert_eq!(a, b);
        assert_eq!(a.len(), mlp.dim());
        // He init: first-layer std ≈ sqrt(2/784) ≈ 0.0505
        let w1 = &a[..784 * 128];
        let std = (crate::tensor::norm2_sq(w1) / w1.len() as f64).sqrt();
        assert!((std - (2.0 / 784.0f64).sqrt()).abs() < 0.005, "std={std}");
        // biases zero
        assert!(a[784 * 128..784 * 128 + 128].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn model_for_dataset() {
        let mnist = DatasetSpec::mnist();
        let cifar = DatasetSpec::cifar10();
        assert_eq!(ModelSpec::for_dataset(&mnist).key(), "mlp");
        assert_eq!(ModelSpec::for_dataset(&cifar).key(), "cnn");
    }
}
