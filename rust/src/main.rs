//! `fedcomloc` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   sweep             declarative scenario sweeps (run | list, EXPERIMENTS.md)
//!   train             run one federated algorithm end-to-end (--faults injects
//!                     deterministic corruption/crash/outage chaos)
//!   experiment        regenerate paper tables/figures (sweep-preset aliases)
//!   list-experiments  show the experiment registry
//!   list-algorithms   show the algorithm registry (spec strings for --algo)
//!   list-compressors  show the compressor registry (specs for --compress-up/-down)
//!   list-models       show the model registry (spec strings for --model)
//!   list-datasets     show the dataset registry (spec strings for --dataset)
//!   list-backends     show the compute-plane backend registry (--backend keys)
//!   data-stats        Figure 11 class-distribution report
//!   artifacts         inspect artifacts/manifest.json
//!
//! `fedcomloc <subcommand> --help` prints the full option list.

use fedcomloc::cli::Command;
use fedcomloc::config::{self, presets};
use fedcomloc::data::dataset_registry;
use fedcomloc::experiments::{self, ExpOptions};
use fedcomloc::fed::transport::parse_transport;
use fedcomloc::fed::{algorithm_registry, run_with_transport, AlgorithmSpec, Variant};
use fedcomloc::model::model_registry;
use fedcomloc::sweep;
use std::path::PathBuf;

fn main() {
    init_logger();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("train") => cmd_train(&argv[1..]),
        Some("run") => cmd_run(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("ckpt") => cmd_ckpt(&argv[1..]),
        Some("experiment") => cmd_experiment(&argv[1..]),
        Some("list-experiments") => cmd_list(),
        Some("list-algorithms") => cmd_list_algorithms(),
        Some("list-compressors") => cmd_list_compressors(),
        Some("list-models") => cmd_list_models(&argv[1..]),
        Some("list-datasets") => cmd_list_datasets(&argv[1..]),
        Some("list-backends") => cmd_list_backends(),
        Some("data-stats") => cmd_data_stats(&argv[1..]),
        Some("artifacts") => cmd_artifacts(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            std::process::exit(2);
        }
    }
    .map_or_else(
        |e: anyhow::Error| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn init_logger() {
    struct Stderr;
    impl log::Log for Stderr {
        fn enabled(&self, metadata: &log::Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &log::Record) {
            if self.enabled(record.metadata()) {
                eprintln!("[{:<5}] {}", record.level(), record.args());
            }
        }
        fn flush(&self) {}
    }
    fn max_level() -> log::Level {
        match std::env::var("FEDCOMLOC_LOG").as_deref() {
            Ok("debug") => log::Level::Debug,
            Ok("trace") => log::Level::Trace,
            Ok("warn") => log::Level::Warn,
            Ok("error") => log::Level::Error,
            _ => log::Level::Info,
        }
    }
    static LOGGER: Stderr = Stderr;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Trace);
}

fn print_usage() {
    println!(
        "fedcomloc — communication-efficient federated training (FedComLoc reproduction)

USAGE:
    fedcomloc <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    sweep             declarative scenario sweeps: sweep run | sweep list
    train             run one federated algorithm end-to-end
    run               train with crash-tolerant checkpointing (bit-identical resume)
    serve             answer eval/predict requests from a checkpoint (JSON lines)
    ckpt              checkpoint utilities: ckpt inspect <file> | ckpt verify <dir>
    experiment        regenerate paper tables/figures (sweep-preset aliases)
    list-experiments  show the experiment registry
    list-algorithms   show the algorithm registry (spec strings for --algo)
    list-compressors  show the compressor registry (specs for --compress-up/-down)
    list-models       show the model registry (spec strings for --model)
    list-datasets     show the dataset registry (spec strings for --dataset)
    list-backends     show the compute-plane backend registry (--backend keys)
    data-stats        Figure 11 class-distribution report
    artifacts         inspect the AOT artifact manifest

Run 'fedcomloc <SUBCOMMAND> --help' for options."
    );
}

fn train_command() -> Command {
    train_options(Command::new("fedcomloc train", "Run one federated training job"))
}

/// The option set shared by `train` and `run` (which is `train` plus
/// checkpointing) — one place so the two commands cannot drift.
fn train_options(cmd: Command) -> Command {
    cmd.opt_default(
            "algo",
            "SPEC",
            "algorithm spec, e.g. fedcomloc-com:topk:0.1 (see list-algorithms)",
            "fedcomloc",
        )
        .opt_default("variant", "V", "FedComLoc variant: com|local|global", "com")
        .opt_default(
            "compress",
            "SPEC",
            "compressor for the --algo shim: none | topk:<d> | q<b> | a|b chains (see list-compressors)",
            "topk:0.3",
        )
        .opt(
            "compress-up",
            "SPEC",
            "uplink pipeline: none | topk:<d> | randk:<d> | q<b> | natural | a|b | ef(...) | sched:...",
        )
        .opt(
            "compress-down",
            "SPEC",
            "downlink (broadcast) pipeline, same grammar as --compress-up",
        )
        .opt(
            "scenario",
            "SPEC",
            "round runtime: sync | semisync:<K>[@<staleness>] (fold first K arrivals)",
        )
        .opt(
            "faults",
            "SPEC",
            "fault-injection plan: none | corrupt:<p>|crash:<p>|dup:<p>|outage:<p>@<secs>|quorum:<f>|retry:<n>|backoff:<secs>",
        )
        .opt_default(
            "transport",
            "SPEC",
            "transport: inproc | simnet[:MBPS[:LAT_MS[:DROP[:HET]]]]",
            "inproc",
        )
        .opt("preset", "NAME", "config preset (see list below)")
        .opt("config", "FILE", "TOML config file with a [run] table")
        .opt_default(
            "backend",
            "KEY",
            "compute-plane backend: auto|native|native-simd|native-bf16|xla (see list-backends)",
            "auto",
        )
        .opt("trainer", "T", "legacy alias for --backend (native|pjrt spellings)")
        .opt_default("artifacts", "DIR", "AOT artifacts directory", "artifacts")
        .opt_default("out", "DIR", "metrics output directory", "results")
        .opt("dataset", "SPEC", "dataset spec, e.g. mnist | synthetic:3x16x16 (see list-datasets)")
        .opt("model", "SPEC", "model spec, e.g. mlp:784x512x10 | linear:784 (see list-models; default pairs the dataset)")
        .opt("rounds", "N", "communication rounds")
        .opt("clients", "N", "total clients")
        .opt("sampled", "N", "clients sampled per round")
        .opt("alpha", "F", "Dirichlet heterogeneity factor")
        .opt("p", "F", "communication probability (FedComLoc)")
        .opt("local-steps", "N", "local steps per round (baselines)")
        .opt("gamma", "F", "learning rate")
        .opt("train-n", "N", "training examples")
        .opt("test-n", "N", "test examples")
        .opt("batch-size", "N", "train batch size")
        .opt("eval-batch", "N", "eval batch size")
        .opt("eval-every", "N", "evaluate every N rounds")
        .opt("seed", "N", "RNG seed")
        .opt("tau", "F", "local-iteration cost for the total-cost metric")
        .opt("threads", "N", "worker threads (0 = auto)")
        .opt("data-dir", "DIR", "real-dataset directory (IDX/CIFAR bins)")
        .flag("quiet", "suppress per-round logging")
}

/// The backend key from `--backend`, falling back to the legacy
/// `--trainer` spelling (kept working: scripts and CI pass
/// `--trainer native` verbatim), then to `default`. Validation happens in
/// [`fedcomloc::backend::resolve`] / `config::apply_kv`, which also map
/// the `pjrt` alias.
fn backend_arg(args: &fedcomloc::cli::Args, default: &str) -> String {
    args.get("backend")
        .or_else(|| args.get("trainer"))
        .unwrap_or(default)
        .to_string()
}

/// Resolve the run configuration and algorithm spec from parsed `train`/
/// `run` options (preset → config file → CLI overrides, then the
/// algorithm-spec sugar) — shared so both commands interpret every flag
/// identically.
fn resolve_train_setup(
    args: &fedcomloc::cli::Args,
) -> anyhow::Result<(fedcomloc::fed::RunConfig, AlgorithmSpec)> {
    let mut cfg = match args.get("preset") {
        Some(name) => presets::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown preset '{name}' (have: {})", presets::names().join(", "))
        })?,
        None => fedcomloc::fed::RunConfig::default_mnist(),
    };
    if let Some(path) = args.get("config") {
        config::load_file(&mut cfg, std::path::Path::new(path))?;
    }
    config::apply_cli(&mut cfg, &args)?;

    // Resolve the algorithm through the string-keyed registry. The bare
    // `fedcomloc` / `sparsefedavg` families keep the old CLI sugar of
    // combining with --variant / --compress; a bare `fedcomloc-*` family
    // still accepts an explicit --compress, and any other registry spec
    // must carry its compressor inline (an explicit --compress alongside
    // one is an error rather than silently ignored).
    let explicit_compress = args.get("compress");
    // The historic `--compress topk:0.3` default is suppressed only when a
    // directional flag configures the *same link the default would shim
    // into* (uplink for -Com/sparsefedavg, downlink for -Global) — a
    // silently-injected default there would conflict with the explicit
    // pipeline. -Local's compressor is the in-graph mask, not a wire
    // codec, so the directional flags never suppress it, and the opposite
    // direction's flag keeps the documented default for the shimmed one.
    let up_flag = args.get("compress-up").is_some();
    let down_flag = args.get("compress-down").is_some();
    let default_for = |suppressed: bool| if suppressed { "none" } else { "topk:0.3" };
    let spec_str = match args.get("algo").unwrap_or("fedcomloc") {
        "fedcomloc" => {
            let variant = Variant::parse(args.get("variant").unwrap_or("com"))
                .ok_or_else(|| anyhow::anyhow!("bad --variant"))?;
            let suppressed = match variant {
                Variant::Com => up_flag,
                Variant::Global => down_flag,
                Variant::Local => false,
            };
            let compress = explicit_compress.unwrap_or(default_for(suppressed));
            format!("fedcomloc-{}:{compress}", variant.name())
        }
        "sparsefedavg" => {
            format!("sparsefedavg:{}", explicit_compress.unwrap_or(default_for(up_flag)))
        }
        other => match explicit_compress {
            Some(c) if other.starts_with("fedcomloc") && !other.contains(':') => {
                format!("{other}:{c}")
            }
            Some(c) => anyhow::bail!(
                "--compress {c} cannot be combined with --algo '{other}'; \
                 embed the compressor in the spec (see list-algorithms)"
            ),
            None => other.to_string(),
        },
    };
    let spec = AlgorithmSpec::parse(&spec_str).map_err(|e| anyhow::anyhow!(e))?;
    Ok((cfg, spec))
}

fn cmd_train(argv: &[String]) -> anyhow::Result<()> {
    let cmd = train_command();
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        println!("PRESETS: {}", presets::names().join(", "));
        return Ok(());
    }
    let (cfg, spec) = resolve_train_setup(&args)?;
    let mut transport = parse_transport(args.get("transport").unwrap_or("inproc"), cfg.seed)
        .map_err(|e| anyhow::anyhow!(e))?;

    let opts = ExpOptions {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        backend: backend_arg(&args, "auto"),
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        seed: cfg.seed,
        ..Default::default()
    };
    let model = cfg.model_spec();
    let trainer = opts.trainer_for(&cfg);

    println!(
        "running {} on {} with model {} (d={}; {} clients, {} sampled, {} rounds, α={}, γ={})",
        spec.name(),
        cfg.dataset.key(),
        model.key(),
        model.dim(),
        cfg.n_clients,
        cfg.clients_per_round,
        cfg.rounds,
        cfg.dirichlet_alpha,
        cfg.gamma
    );
    if cfg.compress_up != "none" || cfg.compress_down != "none" {
        println!(
            "compression pipelines: uplink {} / downlink {}",
            cfg.compress_up, cfg.compress_down
        );
    }
    let t0 = std::time::Instant::now();
    let log = run_with_transport(&cfg, trainer, &spec, transport.as_mut());
    let elapsed = t0.elapsed();
    opts.save("train", &log);
    println!(
        "\ndone in {elapsed:?}: best_acc={:?} final_loss={:?}",
        log.best_accuracy(),
        log.final_train_loss()
    );
    println!(
        "uplink total: {} bits ({:.2} MB); downlink total: {} bits",
        log.total_uplink_bits(),
        log.total_uplink_bits() as f64 / 8e6,
        log.records.last().map(|r| r.cum_downlink_bits).unwrap_or(0),
    );
    if let Some(last) = log.records.last() {
        if last.cum_sim_secs > 0.0 {
            let dropped: u64 = log.records.iter().map(|r| r.dropped_clients).sum();
            println!(
                "simulated network: {:.2} s total, {dropped} dropped client-rounds",
                last.cum_sim_secs
            );
            let stale: u64 = log.records.iter().map(|r| r.stale_updates).sum();
            let churned: u64 = log.records.iter().map(|r| r.churned_clients).sum();
            if stale > 0 || churned > 0 {
                println!(
                    "scenario engine: {stale} stale updates folded, {churned} in-flight updates churned"
                );
            }
        }
    }
    let corrupt: u64 = log.records.iter().map(|r| r.corrupt_frames).sum();
    let retrans: u64 = log.records.iter().map(|r| r.retransmits).sum();
    let aborted: u64 = log.records.iter().map(|r| r.aborted).sum();
    if corrupt > 0 || retrans > 0 || aborted > 0 {
        let backoff: f64 = log.records.iter().map(|r| r.backoff_secs).sum();
        println!(
            "fault plane: {corrupt} corrupt frames, {retrans} retransmits \
             ({backoff:.2} s backoff), {aborted} aborted rounds"
        );
    }
    println!("metrics: {}/train/{}.csv", opts.out_dir.display(), log.run_name);
    Ok(())
}

fn run_command() -> Command {
    train_options(Command::new(
        "fedcomloc run",
        "Run one federated training job with crash-tolerant checkpointing",
    ))
    .opt_default(
        "checkpoint-dir",
        "DIR",
        "checkpoint directory; auto-resumes bit-identically from the latest snapshot",
        "checkpoints",
    )
    .opt_default("checkpoint-every", "K", "snapshot every K completed rounds", "1")
    .opt_default("checkpoint-keep", "N", "retain the newest N checkpoints (0 = all)", "3")
    .opt(
        "crash-after",
        "K",
        "stop without finalizing after K completed rounds (crash injection for resume tests)",
    )
    .opt(
        "metrics-jsonl",
        "FILE",
        "write the byte-deterministic per-round JSONL (sink schema; CI byte-diffs resumed vs uninterrupted runs)",
    )
}

fn cmd_run(argv: &[String]) -> anyhow::Result<()> {
    let cmd = run_command();
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        println!("PRESETS: {}", presets::names().join(", "));
        return Ok(());
    }
    let (cfg, spec) = resolve_train_setup(&args)?;
    let mut transport = parse_transport(args.get("transport").unwrap_or("inproc"), cfg.seed)
        .map_err(|e| anyhow::anyhow!(e))?;
    let opts = ExpOptions {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        backend: backend_arg(&args, "auto"),
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        seed: cfg.seed,
        ..Default::default()
    };
    let trainer = opts.trainer_for(&cfg);

    let ckpt_dir = PathBuf::from(args.get("checkpoint-dir").unwrap_or("checkpoints"));
    let mut ckpt = fedcomloc::ckpt::Checkpointer::new(&ckpt_dir, spec.key())
        .every(args.get_or("checkpoint-every", 1).map_err(|e| anyhow::anyhow!("{e}"))?)
        .keep_last(args.get_or("checkpoint-keep", 3).map_err(|e| anyhow::anyhow!("{e}"))?);
    if let Some(k) = args
        .get_parsed::<usize>("crash-after")
        .map_err(|e| anyhow::anyhow!("{e}"))?
    {
        ckpt = ckpt.crash_after(k);
    }

    println!(
        "running {} on {} ({} rounds, checkpoints -> {})",
        spec.name(),
        cfg.dataset.key(),
        cfg.rounds,
        ckpt_dir.display()
    );
    let t0 = std::time::Instant::now();
    let log = fedcomloc::fed::run_with_transport_observed(
        &cfg,
        trainer,
        &spec,
        transport.as_mut(),
        &mut ckpt,
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let elapsed = t0.elapsed();
    if let Some(round) = ckpt.resumed_from() {
        println!("resumed from checkpointed round {round}");
    }
    let crashed = log.records.len() < cfg.rounds;
    if crashed {
        println!(
            "stopped after {} of {} rounds (crash injection); rerun to resume",
            log.records.len(),
            cfg.rounds
        );
    }
    if let Some(path) = args.get("metrics-jsonl") {
        let path = PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = String::new();
        for r in &log.records {
            out.push_str(&sweep::sink::round_line(&log.run_name, r));
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        println!("per-round JSONL: {}", path.display());
    }
    opts.save("run", &log);
    println!(
        "\ndone in {elapsed:?}: best_acc={:?} final_loss={:?}",
        log.best_accuracy(),
        log.final_train_loss()
    );
    let corrupt: u64 = log.records.iter().map(|r| r.corrupt_frames).sum();
    let retrans: u64 = log.records.iter().map(|r| r.retransmits).sum();
    let aborted: u64 = log.records.iter().map(|r| r.aborted).sum();
    if corrupt > 0 || retrans > 0 || aborted > 0 {
        let backoff: f64 = log.records.iter().map(|r| r.backoff_secs).sum();
        println!(
            "fault plane: {corrupt} corrupt frames, {retrans} retransmits \
             ({backoff:.2} s backoff), {aborted} aborted rounds"
        );
    }
    println!("metrics: {}/run/{}.csv", opts.out_dir.display(), log.run_name);
    Ok(())
}

fn serve_command() -> Command {
    Command::new(
        "fedcomloc serve",
        "Answer eval/predict requests from a checkpoint over JSON lines",
    )
    .opt("checkpoint", "FILE", "checkpoint file (.fckp) to serve")
    .opt(
        "checkpoint-dir",
        "DIR",
        "serve the newest checkpoint in DIR (alternative to --checkpoint)",
    )
    .opt_default(
        "backend",
        "KEY",
        "compute-plane backend: auto|native|native-simd|native-bf16|xla",
        "native",
    )
    .opt("trainer", "T", "legacy alias for --backend (native|pjrt spellings)")
    .opt_default("artifacts", "DIR", "AOT artifacts directory", "artifacts")
    .opt(
        "tcp",
        "ADDR",
        "also listen on ADDR (e.g. 127.0.0.1:7878), one connection at a time; default is stdin/stdout",
    )
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    use std::io::{BufRead, Write};
    let cmd = serve_command();
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        println!(
            "\nPROTOCOL (one JSON object per line):\n\
             \x20   {{\"cmd\":\"info\"}}                  checkpoint provenance + inference-cost report\n\
             \x20   {{\"cmd\":\"eval\"}}                  evaluate over the config's test split\n\
             \x20   {{\"cmd\":\"predict\",\"x\":[...]}}    classify one feature row"
        );
        return Ok(());
    }
    let path = match (args.get("checkpoint"), args.get("checkpoint-dir")) {
        (Some(file), None) => PathBuf::from(file),
        (None, Some(dir)) => fedcomloc::ckpt::latest_checkpoint(std::path::Path::new(dir))
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow::anyhow!("no checkpoints in {dir}"))?,
        (Some(_), Some(_)) => anyhow::bail!("pass --checkpoint or --checkpoint-dir, not both"),
        (None, None) => anyhow::bail!("pass --checkpoint <file> or --checkpoint-dir <dir>"),
    };
    let mut state = fedcomloc::ckpt::ServeState::load(
        &path,
        &backend_arg(&args, "native"),
        std::path::Path::new(args.get("artifacts").unwrap_or("artifacts")),
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    eprintln!(
        "serving {} (round {}, {}): one JSON request per line",
        path.display(),
        state.round(),
        state.algo_spec()
    );
    if let Some(addr) = args.get("tcp") {
        let listener = std::net::TcpListener::bind(addr)?;
        eprintln!("listening on {addr} (sequential connections); ctrl-c to stop");
        for stream in listener.incoming() {
            let stream = stream?;
            let reader = std::io::BufReader::new(stream.try_clone()?);
            let mut writer = stream;
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                writeln!(writer, "{}", state.handle_line(&line))?;
            }
        }
        return Ok(());
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        writeln!(out, "{}", state.handle_line(&line))?;
        out.flush()?;
    }
    Ok(())
}

fn cmd_ckpt(argv: &[String]) -> anyhow::Result<()> {
    match argv.first().map(|s| s.as_str()) {
        Some("inspect") => {
            let cmd = Command::new(
                "fedcomloc ckpt inspect",
                "Print a checkpoint's schema version, round, algorithm, and state sections",
            );
            let args = cmd.parse(&argv[1..]).map_err(|e| anyhow::anyhow!("{e}"))?;
            if args.wants_help() {
                println!("{}", args.help_text());
                println!("\nUSAGE:\n    fedcomloc ckpt inspect <file.fckp>");
                return Ok(());
            }
            let file = args
                .positionals
                .first()
                .ok_or_else(|| anyhow::anyhow!("pass a checkpoint file: ckpt inspect <file.fckp>"))?;
            let snap = fedcomloc::ckpt::Snapshot::load(std::path::Path::new(file))
                .map_err(|e| anyhow::anyhow!(e))?;
            print!("{}", snap.describe());
            Ok(())
        }
        Some("verify") => {
            let cmd = Command::new(
                "fedcomloc ckpt verify",
                "CRC-check every section of every checkpoint in a directory",
            );
            let args = cmd.parse(&argv[1..]).map_err(|e| anyhow::anyhow!("{e}"))?;
            if args.wants_help() {
                println!("{}", args.help_text());
                println!("\nUSAGE:\n    fedcomloc ckpt verify <dir>");
                return Ok(());
            }
            let dir = args
                .positionals
                .first()
                .ok_or_else(|| anyhow::anyhow!("pass a checkpoint directory: ckpt verify <dir>"))?;
            match fedcomloc::ckpt::verify_dir(std::path::Path::new(dir)) {
                Ok(report) => {
                    print!("{report}");
                    Ok(())
                }
                Err(report) => anyhow::bail!("{report}"),
            }
        }
        Some("--help") | Some("-h") | None => {
            println!(
                "fedcomloc ckpt — checkpoint utilities\n\n\
                 USAGE:\n    fedcomloc ckpt inspect <file.fckp>   print schema/round/algorithm/sections\n    \
                 fedcomloc ckpt verify <dir>          CRC-check every snapshot in a directory"
            );
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown ckpt subcommand '{other}' (try inspect | verify)"),
    }
}

fn experiment_command() -> Command {
    Command::new("fedcomloc experiment", "Regenerate paper tables/figures")
        .opt("id", "ID", "experiment id (see list-experiments)")
        .flag("all", "run every experiment in the registry")
        .opt_default("scale", "F", "scale factor on rounds/sizes", "1.0")
        .opt_default(
            "backend",
            "KEY",
            "compute-plane backend: auto|native|native-simd|native-bf16|xla",
            "auto",
        )
        .opt("trainer", "T", "legacy alias for --backend (native|pjrt spellings)")
        .opt_default("artifacts", "DIR", "AOT artifacts directory", "artifacts")
        .opt_default("out", "DIR", "output directory", "results")
        .opt_default("seed", "N", "RNG seed", "42")
}

fn cmd_experiment(argv: &[String]) -> anyhow::Result<()> {
    let cmd = experiment_command();
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        return Ok(());
    }
    let opts = ExpOptions {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        scale: args.get_or("scale", 1.0).map_err(|e| anyhow::anyhow!("{e}"))?,
        backend: backend_arg(&args, "auto"),
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        seed: args.get_or("seed", 42).map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    if args.flag("all") {
        for exp in experiments::registry() {
            println!("\n################ {} ({}) ################", exp.id, exp.paper_ref);
            experiments::run(&exp, &opts)?;
        }
        return Ok(());
    }
    match args.get("id") {
        Some(id) => {
            let exp = experiments::by_id(id).ok_or_else(|| {
                anyhow::anyhow!("unknown experiment '{id}' (try list-experiments)")
            })?;
            experiments::run(&exp, &opts)
        }
        None => anyhow::bail!("pass --id <experiment> or --all"),
    }
}

fn sweep_run_command() -> Command {
    Command::new("fedcomloc sweep run", "Expand and execute a declarative sweep")
        .opt("preset", "NAME", "shipped sweep (see 'sweep list')")
        .opt("config", "FILE", "sweep TOML file (see EXPERIMENTS.md for the schema)")
        .opt_default("out", "DIR", "output root (results land in <out>/<name>/)", "results")
        .opt_default("threads", "N", "parallel runs (0 = auto; inner pools drop to 1)", "0")
        .opt_default("scale", "F", "scale factor on rounds/dataset sizes", "1.0")
        .opt("seed", "N", "base-seed override (an explicit 'seeds' axis wins)")
        .opt_default(
            "backend",
            "KEY",
            "compute-plane backend: auto|native|native-simd|native-bf16|xla (a 'backends' axis wins)",
            "auto",
        )
        .opt("trainer", "T", "legacy alias for --backend (native|pjrt spellings)")
        .opt_default("artifacts", "DIR", "AOT artifacts directory", "artifacts")
        .flag("dry-run", "print the expanded run matrix and exit")
        .flag("resume", "skip runs whose summary row exists with a matching config")
        .opt(
            "checkpoint-dir",
            "DIR",
            "per-run checkpoints in DIR/<run_id>/; with --resume, unfinished runs restart at their last snapshot",
        )
        .opt_default("checkpoint-every", "K", "snapshot cadence in rounds for --checkpoint-dir", "1")
}

fn cmd_sweep(argv: &[String]) -> anyhow::Result<()> {
    match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_sweep_run(&argv[1..]),
        Some("list") => cmd_sweep_list(),
        Some("--help") | Some("-h") | None => {
            println!(
                "fedcomloc sweep — declarative scenario sweeps over the registries\n\n\
                 USAGE:\n    fedcomloc sweep run  [OPTIONS]   expand + execute a sweep\n    \
                 fedcomloc sweep list             show the shipped sweeps\n\n\
                 Run 'fedcomloc sweep run --help' for options; EXPERIMENTS.md maps every\n\
                 paper figure to its sweep TOML and exact invocation."
            );
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown sweep subcommand '{other}' (try run | list)"),
    }
}

fn cmd_sweep_list() -> anyhow::Result<()> {
    println!("{:<16}{:<28}{:>6}  {}", "name", "paper", "runs", "title");
    for preset in sweep::sweep_presets() {
        let spec = sweep::preset_by_name(preset.name)
            .expect("listed preset resolves")
            .map_err(|e| anyhow::anyhow!(e))?;
        let paper = if preset.paper.is_empty() { "-" } else { preset.paper };
        println!("{:<16}{:<28}{:>6}  {}", preset.name, paper, spec.num_runs(), spec.title);
    }
    println!(
        "\nRun with: fedcomloc sweep run --preset <name>   (or --config <file.toml>)\n\
         The shipped TOMLs live under experiments/; EXPERIMENTS.md maps them to paper figures."
    );
    Ok(())
}

fn cmd_sweep_run(argv: &[String]) -> anyhow::Result<()> {
    let cmd = sweep_run_command();
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        let names: Vec<&str> = sweep::sweep_presets().iter().map(|p| p.name).collect();
        println!("PRESETS: {}", names.join(", "));
        return Ok(());
    }
    let spec = match (args.get("preset"), args.get("config")) {
        (Some(name), None) => sweep::preset_by_name(name)
            .ok_or_else(|| {
                let names: Vec<&str> = sweep::sweep_presets().iter().map(|p| p.name).collect();
                anyhow::anyhow!("unknown sweep preset '{name}' (have: {})", names.join(", "))
            })?
            .map_err(|e| anyhow::anyhow!(e))?,
        (None, Some(path)) => sweep::SweepSpec::load(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!(e))?,
        (Some(_), Some(_)) => anyhow::bail!("pass --preset or --config, not both"),
        (None, None) => anyhow::bail!("pass --preset <name> or --config <file> (see 'sweep list')"),
    };
    let opts = sweep::SweepOptions {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        threads: args.get_or("threads", 0).map_err(|e| anyhow::anyhow!("{e}"))?,
        dry_run: args.flag("dry-run"),
        resume: args.flag("resume"),
        scale: args.get_or("scale", 1.0).map_err(|e| anyhow::anyhow!("{e}"))?,
        seed: args.get_parsed("seed").map_err(|e| anyhow::anyhow!("{e}"))?,
        backend: backend_arg(&args, "auto"),
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
        checkpoint_every: args.get_or("checkpoint-every", 1).map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    println!("sweep '{}' — {}", spec.name, spec.title);
    if !spec.paper.is_empty() {
        println!("reproduces: {}", spec.paper);
    }
    let t0 = std::time::Instant::now();
    let outcome = sweep::run_sweep(&spec, &opts).map_err(|e| anyhow::anyhow!(e))?;
    if opts.dry_run {
        println!("\n{} runs would execute:\n", outcome.units.len());
        print!("{}", sweep::format_matrix(&outcome.units));
        return Ok(());
    }
    println!(
        "\ndone in {:?}: {} runs executed, {} resumed",
        t0.elapsed(),
        outcome.executed,
        outcome.skipped
    );
    println!(
        "summary: {}/summary.csv   per-round series: {}/rounds/*.jsonl",
        outcome.dir.display(),
        outcome.dir.display()
    );
    Ok(())
}

fn cmd_list() -> anyhow::Result<()> {
    println!("{:<10}{:<28}{:<16}{}", "id", "paper", "sweep preset", "description");
    for exp in experiments::registry() {
        println!(
            "{:<10}{:<28}{:<16}{}",
            exp.id,
            exp.paper_ref,
            exp.sweep.unwrap_or("- (report)"),
            exp.description
        );
    }
    println!(
        "\n'experiment --id <id>' is an alias for 'sweep run --preset <sweep preset>'\n\
         (fig11 is a data report, not a sweep). See EXPERIMENTS.md for the figure map."
    );
    Ok(())
}

fn cmd_list_algorithms() -> anyhow::Result<()> {
    println!("{:<18}{:<46}{}", "key", "argument", "description");
    for fam in algorithm_registry() {
        let arg = if fam.arg_help.is_empty() { "-" } else { fam.arg_help };
        println!("{:<18}{:<46}{}", fam.key, arg, fam.summary);
    }
    println!("\nSpec grammar: <key>[:<argument>], e.g. fedcomloc-com:topk:0.25+q:4");
    Ok(())
}

fn cmd_list_compressors() -> anyhow::Result<()> {
    println!("{:<10}{:<36}{}", "key", "argument", "description");
    for fam in fedcomloc::compress::compressor_registry() {
        let arg = if fam.arg_help.is_empty() { "-" } else { fam.arg_help };
        println!("{:<10}{:<36}{}", fam.key, arg, fam.summary);
    }
    println!(
        "\nCombinators (compose freely):\n\
         \x20   a|b            chain: apply a then b; a sparsifier|quantizer pair fuses\n\
         \x20                  into the sparse-quantized wire layout (topk:0.1|q8)\n\
         \x20   ef(<spec>)     error feedback: per-link residual memory (EF14-style)\n\
         \x20   sched:<f>:<from>..<to>[@linear|cosine]\n\
         \x20                  round-indexed schedule over topk/randk density or q bits\n\
         \nPass via --compress-up / --compress-down (train), the compress_up /\n\
         compress_down [run]-table keys, or the same-named sweep axes; legacy\n\
         '--algo fedcomloc-com:<spec>' embeds the uplink spec inline."
    );
    Ok(())
}

fn cmd_list_models(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("fedcomloc list-models", "Show the model registry").flag(
        "specs",
        "machine-readable output: one '<model-spec> <dataset-spec>' smoke pair per family",
    );
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        return Ok(());
    }
    if args.flag("specs") {
        // Consumed by the CI smoke job: every registered family must train.
        for fam in model_registry() {
            println!("{} {}", fam.example, fam.example_dataset);
        }
        return Ok(());
    }
    println!("{:<10}{:<66}{}", "key", "argument", "description");
    for fam in model_registry() {
        println!("{:<10}{:<66}{}", fam.key, fam.arg_help, fam.summary);
    }
    println!(
        "\nSpec grammar: <key>[:<argument>], e.g. mlp:784x512x256x10 — pass via --model \
         (default pairs the dataset: mnist->mlp, cifar10->cnn, flat synthetic->softmax)"
    );
    Ok(())
}

fn cmd_list_datasets(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("fedcomloc list-datasets", "Show the dataset registry");
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        return Ok(());
    }
    println!("{:<12}{:<70}{}", "key", "argument", "description");
    for fam in dataset_registry() {
        println!("{:<12}{:<70}{}", fam.key, fam.arg_help, fam.summary);
    }
    println!("\nSpec grammar: <key>[:<argument>], e.g. synthetic:3x16x16-c5 — pass via --dataset");
    Ok(())
}

fn cmd_list_backends() -> anyhow::Result<()> {
    println!("{:<14}{:<14}{}", "key", "numerics", "description");
    for b in fedcomloc::backend::backend_registry() {
        let numerics = if b.bit_identical() { "bit-exact" } else { "differs" };
        println!("{:<14}{:<14}{}", b.key(), numerics, b.summary());
    }
    println!(
        "\nPass via --backend (or the 'backend' [run]-table key / 'backends' sweep axis).\n\
         'auto' picks xla for the CNN when artifacts exist, native otherwise; 'pjrt' is\n\
         an alias for xla. bit-exact planes reproduce the native plane bit for bit."
    );
    Ok(())
}

fn cmd_data_stats(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("fedcomloc data-stats", "Figure 11 class distribution report")
        .opt_default("out", "DIR", "output directory", "results")
        .opt_default("seed", "N", "RNG seed", "42");
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        return Ok(());
    }
    let opts = ExpOptions {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        seed: args.get_or("seed", 42).map_err(|e| anyhow::anyhow!("{e}"))?,
        ..Default::default()
    };
    experiments::data_stats(&opts)
}

fn cmd_artifacts(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("fedcomloc artifacts", "Inspect the AOT artifact manifest")
        .opt_default("dir", "DIR", "artifacts directory", "artifacts");
    let args = cmd.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.wants_help() {
        println!("{}", args.help_text());
        return Ok(());
    }
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let manifest = fedcomloc::runtime::Manifest::load(&dir)?;
    println!("artifacts in {}:", dir.display());
    for (name, spec) in &manifest.artifacts {
        let ins: Vec<String> = spec.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        let outs: Vec<String> = spec.outputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {name:<24} in: {} -> out: {}", ins.join(","), outs.join(","));
    }
    println!("\nmodels:");
    for (name, m) in &manifest.models {
        println!(
            "  {name}: dim={} batch={} eval_batch={} input={:?}",
            m.dim, m.batch, m.eval_batch, m.input_shape
        );
    }
    Ok(())
}
