//! Minimal offline facade for the `xla` crate (xla_extension 0.5.1, PJRT
//! C API).
//!
//! The offline vendor set cannot ship the real XLA extension (it links a
//! multi-hundred-MB native library), but `runtime::engine` is written
//! against the `xla` crate's API. This facade provides exactly the subset
//! of that API the engine uses, with every entry point that would touch a
//! real PJRT client failing cleanly with [`Error::Unavailable`] —
//! `Engine::load` then surfaces the error and `runtime::build_trainer`
//! falls back to the native compute plane with a warning, which is the
//! correct behavior on any machine without compiled artifacts anyway.
//!
//! Swapping in the real crate is a one-line `Cargo.toml` change; no source
//! edits, because the signatures below mirror the real ones for the used
//! subset.

use std::path::Path;

/// The facade's single error: the PJRT runtime is not present in this
/// build.
#[derive(Debug, Clone)]
pub enum Error {
    /// Raised by every operation that would need the native XLA extension.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT/XLA extension not available in this offline build \
                 (vendored `xla` facade; swap in the real crate to enable the AOT plane)"
            ),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Host literal (facade: carries no data; cannot be constructed through a
/// fallible path, and infallible constructors produce inert values that
/// are only ever passed to operations that error first).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice (inert in the facade).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Scalar literal (inert in the facade).
    pub fn scalar(_value: f32) -> Literal {
        Literal
    }

    /// Reshape to `dims` — unavailable offline.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    /// Decompose a tuple literal — unavailable offline.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector — unavailable offline.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (facade).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file — unavailable offline.
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (facade).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (facade).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronously copy the buffer back to a host literal — unavailable
    /// offline.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (facade).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host inputs — unavailable offline.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (facade).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client — unavailable offline; this is the first
    /// call `Engine::load` makes, so the engine fails before anything else
    /// runs.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the backing PJRT plugin.
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation — unavailable offline.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope")).is_err());
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.reshape(&[1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(0.5).to_tuple().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("offline"), "{msg}");
    }
}
