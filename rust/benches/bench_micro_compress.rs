//! Micro-bench: the compression hot path (encode + decode) at model sizes.
//!
//! This is the L3 cost FedComLoc adds per communication round; the TopK
//! selection (select_nth_unstable) and the quantizer bit-packing dominate.
//! Tracked across commits via target/benchkit/*.jsonl (EXPERIMENTS.md §Perf).

use fedcomloc::compress::{Compressor, DoubleCompress, Identity, QuantizeR, TopK};
use fedcomloc::util::benchkit::{bb, Bench};
use fedcomloc::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(42);
    for &(label, d) in &[("mlp d=109k", 109_386usize), ("cnn d=744k", 744_330)] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let mut b = Bench::new(&format!("compress_{}", label.split(' ').next().unwrap()));
        let cases: Vec<(String, Box<dyn Compressor>)> = vec![
            ("identity".into(), Box::new(Identity)),
            ("topk 10%".into(), Box::new(TopK::with_density(0.10))),
            ("topk 30%".into(), Box::new(TopK::with_density(0.30))),
            ("topk 90%".into(), Box::new(TopK::with_density(0.90))),
            ("q4".into(), Box::new(QuantizeR::new(4))),
            ("q8".into(), Box::new(QuantizeR::new(8))),
            ("q16".into(), Box::new(QuantizeR::new(16))),
            ("topk25+q8".into(), Box::new(DoubleCompress::new(0.25, 8))),
        ];
        for (name, comp) in cases {
            let mut enc_rng = Rng::seed_from_u64(7);
            b.case(&format!("{label} encode {name}"), || {
                bb(comp.compress(bb(&x), &mut enc_rng));
            });
            let mut dec_rng = Rng::seed_from_u64(7);
            let encoded = comp.compress(&x, &mut dec_rng);
            b.case(&format!("{label} decode {name}"), || {
                bb(comp.decompress(bb(&encoded)));
            });
        }
        b.finish();
    }
}
