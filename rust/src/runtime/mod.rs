//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! coordinator's hot path.
//!
//! `python -m compile.aot` (Layer 2) lowers the JAX/Pallas programs to HLO
//! **text** plus a `manifest.json` describing shapes. This module wraps the
//! `xla` crate (xla_extension 0.5.1, PJRT C API, CPU plugin):
//!
//! ```text
//! PjRtClient::cpu()
//!   -> HloModuleProto::from_text_file(artifacts/<name>.hlo.txt)
//!   -> XlaComputation::from_proto -> client.compile
//!   -> Executable::call(&[inputs]) per local step
//! ```
//!
//! Python is never on this path — the Rust binary is self-contained once
//! `artifacts/` exists. [`PjrtTrainer`] adapts the compiled programs to the
//! [`crate::model::LocalTrainer`] trait so every federated algorithm runs
//! identically on the native and AOT compute planes.

pub mod artifacts;
pub mod engine;
pub mod trainer;

pub use artifacts::{ArtifactSpec, Manifest, ModelArtifact, TensorSpec};
pub use engine::{Engine, Executable};
pub use trainer::PjrtTrainer;

use std::path::{Path, PathBuf};

/// Default artifacts directory, overridable via FEDCOMLOC_ARTIFACTS.
/// Searches the working directory and then up to two parents (cargo runs
/// tests/benches from the package dir, one level below the workspace root).
pub fn default_artifacts_dir() -> PathBuf {
    if let Some(dir) = std::env::var_os("FEDCOMLOC_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    for prefix in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(prefix);
        if p.join("manifest.json").is_file() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// True when a usable manifest exists (used by tests/benches to decide
/// whether the PJRT path can run or the native trainer must stand in).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").is_file()
}
