"""L2 model: FedLab-style CNN for FedCIFAR10 over a FLAT parameter vector.

Layout (must match rust/src/model/cnn.rs):
  [Wc1 32×(3·5·5) | bc1 32 | Wc2 64×(32·5·5) | bc2 64 |
   W3 1600×384 | b3 384 | W4 384×192 | b4 192 | W5 192×10 | b5 10]
conv weights OIHW, activations NCHW, valid padding, stride 1, 2×2 maxpool.
d = 744,330.

Convolutions lower to XLA's native conv (lax.conv_general_dilated) — see
DESIGN.md §Hardware-Adaptation; the dense tail and the fused update run
through the L1 Pallas kernels so the hot dense FLOPs share the audited
BlockSpec schedule with the MLP.
"""

import jax.numpy as jnp
from jax import lax

from ..kernels import dense

IN_CH, SIDE, K = 3, 32, 5
C1, C2 = 32, 64
FC_IN, F1, F2, OUT = C2 * 5 * 5, 384, 192, 10

DIM = (
    C1 * IN_CH * K * K
    + C1
    + C2 * C1 * K * K
    + C2
    + FC_IN * F1
    + F1
    + F1 * F2
    + F2
    + F2 * OUT
    + OUT
)


def _slices():
    o = 0
    out = {}
    for name, shape in (
        ("wc1", (C1, IN_CH, K, K)),
        ("bc1", (C1,)),
        ("wc2", (C2, C1, K, K)),
        ("bc2", (C2,)),
        ("w3", (FC_IN, F1)),
        ("b3", (F1,)),
        ("w4", (F1, F2)),
        ("b4", (F2,)),
        ("w5", (F2, OUT)),
        ("b5", (OUT,)),
    ):
        size = 1
        for s in shape:
            size *= s
        out[name] = (o, o + size, shape)
        o += size
    assert o == DIM
    return out


SLICES = _slices()


def unpack(params):
    assert params.shape == (DIM,)
    return {
        name: params[lo:hi].reshape(shape)
        for name, (lo, hi, shape) in SLICES.items()
    }


def _conv(x, w, b):
    """NCHW valid conv, stride 1, + bias."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _maxpool2(x):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def forward(params, x):
    """Logits for x:[B, 3, 32, 32]."""
    p = unpack(params)
    y = jnp.maximum(_conv(x, p["wc1"], p["bc1"]), 0.0)
    y = _maxpool2(y)  # [B, 32, 14, 14]
    y = jnp.maximum(_conv(y, p["wc2"], p["bc2"]), 0.0)
    y = _maxpool2(y)  # [B, 64, 5, 5]
    y = y.reshape(y.shape[0], FC_IN)  # channel-major flatten (matches Rust)
    y = dense.dense(y, p["w3"], p["b3"], activation="relu")
    y = dense.dense(y, p["w4"], p["b4"], activation="relu")
    return dense.dense(y, p["w5"], p["b5"], activation="none")


def loss_fn(params, x, y):
    logits = forward(params, x)
    zmax = logits.max(axis=1)
    logz = jnp.log(jnp.sum(jnp.exp(logits - zmax[:, None]), axis=1)) + zmax
    label_logit = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    return jnp.mean(logz - label_logit)


def per_example_metrics(params, x, y):
    logits = forward(params, x)
    zmax = logits.max(axis=1)
    logz = jnp.log(jnp.sum(jnp.exp(logits - zmax[:, None]), axis=1)) + zmax
    label_logit = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    losses = logz - label_logit
    correct = (jnp.argmax(logits, axis=1) == y).astype(jnp.int32)
    return losses, correct
