//! Model/update compression operators and their exact wire formats.
//!
//! This module implements the paper's §3.1 operators — the biased TopK
//! sparsifier (Definition 3.1) and the unbiased stochastic quantizer Q_r
//! (Definition 3.2, QSGD-style) — plus their composition (Appendix B.3) and
//! the identity. Every compressor produces a [`Compressed`] payload with an
//! *actual serialized byte buffer*; communicated-bit metrics (the paper's
//! headline x-axis) come from real payload sizes, not nominal estimates.
//!
//! The corresponding in-graph forms (used by FedComLoc-Local, where C(x) is
//! applied inside the local training step) live in the L1 Pallas kernels
//! (`python/compile/kernels/{topk,quantize}.py`); the Rust and Pallas
//! implementations are cross-checked through the `quantize.hlo.txt` artifact
//! test in `rust/tests/runtime_artifacts.rs`.

mod identity;
mod quantize;
pub mod topk;

pub use identity::Identity;
pub use quantize::QuantizeR;
pub use topk::TopK;

use crate::util::rng::Rng;

/// A compressed parameter/update vector plus its exact wire accounting.
#[derive(Debug, Clone)]
pub struct Compressed {
    /// Serialized payload as produced by the compressor's encoder.
    pub payload: Vec<u8>,
    /// Exact number of meaningful bits in `payload` (≤ 8·payload.len(); the
    /// final byte may be padding).
    pub wire_bits: u64,
    /// Uncompressed dimension (needed by the decoder).
    pub dim: usize,
    /// Which encoder produced this (decides the decode path).
    pub codec: Codec,
}

/// Everything [`Compressed`] carries except the bytes themselves — what a
/// buffer-reusing [`Compressor::compress_into`] call returns alongside the
/// caller's payload buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecMeta {
    /// Exact number of meaningful bits written to the payload buffer.
    pub wire_bits: u64,
    /// Uncompressed dimension (needed by the decoder).
    pub dim: usize,
    /// Which encoder produced the payload (decides the decode path).
    pub codec: Codec,
}

impl CodecMeta {
    /// Attach a payload to make an owned [`Compressed`].
    pub fn with_payload(self, payload: Vec<u8>) -> Compressed {
        Compressed {
            payload,
            wire_bits: self.wire_bits,
            dim: self.dim,
            codec: self.codec,
        }
    }
}

/// Encoding identifier carried in the message header.
///
/// A `Codec` value plus the vector dimension is *sufficient to decode a
/// payload*: every parameter the decoder needs (quantizer bit width and
/// normalization bucket size) is part of the tag, so the receiving side of a
/// wire [`crate::fed::message::Message`] never needs the sender's compressor
/// instance — see [`decode_payload`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Raw little-endian f32s (32·d bits).
    Dense,
    /// TopK survivors as ⌈log₂ d⌉-bit indices + 32-bit values.
    SparseIdx,
    /// TopK survivors as a d-bit occupancy bitmap + 32-bit values.
    SparseBitmap,
    /// Bucketed stochastic quantization: per-bucket norm + sign/level bits.
    Quantized {
        /// Quantizer bit width r.
        bits: u32,
        /// Coordinates per normalization bucket.
        bucket: u32,
    },
    /// TopK-then-quantize: sparse index block + quantized value block.
    SparseQuantized {
        /// Quantizer bit width r.
        bits: u32,
        /// Survivors per normalization bucket.
        bucket: u32,
    },
}

/// Decode a serialized payload into a dense `dim`-vector from the wire
/// metadata alone. This is the single decode path for every codec: the
/// `Compressor::decompress` impls and the transport layer both dispatch
/// here, so an encoder/decoder mismatch is impossible by construction.
///
/// Panics on corrupt payloads (wire corruption is a programming error in
/// the in-process transports; a remote transport would validate framing in
/// [`crate::fed::message::Message::decode`] first).
pub fn decode_payload(codec: Codec, dim: usize, payload: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; dim];
    decode_payload_into(codec, dim, payload, &mut out);
    out
}

/// [`decode_payload`] into a caller buffer of exactly `dim` elements
/// (fully overwritten) — the zero-allocation decode path the drivers'
/// reused delivery buffers go through.
pub fn decode_payload_into(codec: Codec, dim: usize, payload: &[u8], out: &mut [f32]) {
    assert_eq!(out.len(), dim, "decode buffer must be exactly dim");
    match codec {
        Codec::Dense => identity::decode_dense_into(dim, payload, out),
        Codec::SparseIdx | Codec::SparseBitmap => topk::decode_sparse_into(codec, dim, payload, out),
        Codec::Quantized { bits, bucket } => {
            quantize::decode_quantized_into(dim, payload, bits, bucket as usize, out)
        }
        Codec::SparseQuantized { bits, bucket } => {
            quantize::decode_sparse_quantized_into(dim, payload, bits, bucket as usize, out)
        }
    }
}

/// A compression operator C(·) applied to a d-dimensional f32 vector.
///
/// `compress` may be randomized (Q_r draws stochastic rounding variables
/// from the provided RNG); TopK and Identity ignore the RNG.
///
/// The serializing primitive is [`Compressor::compress_into`], which writes
/// into a caller byte buffer (cleared, capacity kept), eliminating the
/// payload allocation; [`Compressor::compress`] is the owned-payload
/// convenience wrapper. Note the TopK-based compressors still allocate
/// O(d) *selection* scratch internally (compressors are stateless and
/// `Sync`, so they cannot hold scratch; callers that need a fully
/// allocation-free selection use [`topk::select_topk_into`] /
/// [`topk::apply_topk_with`] with their own buffers, as the masked train
/// step does).
pub trait Compressor: Send + Sync {
    /// Human-readable name used in logs/metrics ("topk(0.10)", "q4", ...).
    fn name(&self) -> String;

    /// Encode `x` into `payload` (cleared first; capacity reused) and
    /// return the wire metadata. Byte-identical to
    /// [`Compressor::compress`].
    fn compress_into(&self, x: &[f32], rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta;

    /// Encode `x` into an owned wire payload.
    fn compress(&self, x: &[f32], rng: &mut Rng) -> Compressed {
        let mut payload = Vec::new();
        let meta = self.compress_into(x, rng, &mut payload);
        meta.with_payload(payload)
    }

    /// Decode into a dense vector of length `c.dim`.
    fn decompress(&self, c: &Compressed) -> Vec<f32>;

    /// Apply the operator *in place* without serialization — the semantic
    /// effect C(x) (used by FedComLoc-Local on the Rust fallback path and by
    /// tests). Default: round-trip through the codec.
    fn apply(&self, x: &mut [f32], rng: &mut Rng) {
        let c = self.compress(x, rng);
        let dec = self.decompress(&c);
        x.copy_from_slice(&dec);
    }

    /// Bits this compressor would put on the wire for dimension `d`
    /// (worst-case/typical; used for capacity planning, not metrics).
    fn nominal_bits(&self, d: usize) -> u64;
}

/// Identity reference: 32·d bits (dense f32), the paper's K=100% baseline.
pub fn dense_bits(d: usize) -> u64 {
    32 * d as u64
}

/// Composition C₂∘C₁ specialized to the paper's Appendix B.3 "double
/// compression": TopK first, then quantize the surviving values.
#[derive(Debug, Clone)]
pub struct DoubleCompress {
    /// The sparsifier applied first.
    pub topk: TopK,
    /// The quantizer applied to the surviving values.
    pub quant: QuantizeR,
}

impl DoubleCompress {
    /// TopK at `density` followed by Q_r at `bits`.
    pub fn new(density: f64, bits: u32) -> Self {
        Self {
            topk: TopK::with_density(density),
            quant: QuantizeR::new(bits),
        }
    }
}

impl Compressor for DoubleCompress {
    fn name(&self) -> String {
        format!("topk({:.2})+q{}", self.topk.density, self.quant.bits)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta {
        // Select survivors with TopK, then quantize the K values; indices are
        // encoded exactly as in the sparse-index codec.
        let d = x.len();
        let k = self.topk.k_for(d);
        let idx = topk::select_topk_indices(x, k);
        let vals: Vec<f32> = idx.iter().map(|&i| x[i]).collect();
        let (bits, bucket) = (self.quant.bits, self.quant.bucket_size);
        quantize::encode_sparse_quantized_into(d, &idx, &vals, bits, bucket, rng, payload)
    }

    fn decompress(&self, c: &Compressed) -> Vec<f32> {
        decode_payload(c.codec, c.dim, &c.payload)
    }

    fn nominal_bits(&self, d: usize) -> u64 {
        // The encoder's maximal layout (every bucket norm nonzero), computed
        // by the same function the encoder sizes its buffer with so the two
        // cannot drift — see `sparse_quantized_wire_bits`.
        quantize::sparse_quantized_wire_bits(
            d,
            self.topk.k_for(d),
            self.quant.bits,
            self.quant.bucket_size,
        )
    }
}

/// Parse a compressor spec string, e.g. "none", "topk:0.1", "q:8",
/// "topk:0.25+q:4". Used by the CLI and config layer.
pub fn parse_spec(spec: &str) -> Result<Box<dyn Compressor>, String> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "none" || spec == "identity" {
        return Ok(Box::new(Identity));
    }
    if let Some((a, b)) = spec.split_once('+') {
        let density = parse_topk(a)?;
        let bits = parse_q(b)?;
        return Ok(Box::new(DoubleCompress::new(density, bits)));
    }
    if spec.starts_with("topk") {
        return Ok(Box::new(TopK::with_density(parse_topk(spec)?)));
    }
    if spec.starts_with('q') {
        return Ok(Box::new(QuantizeR::new(parse_q(spec)?)));
    }
    Err(format!("unknown compressor spec '{spec}'"))
}

fn parse_topk(s: &str) -> Result<f64, String> {
    let v = s
        .strip_prefix("topk")
        .and_then(|r| r.strip_prefix(':'))
        .ok_or_else(|| format!("bad topk spec '{s}'"))?;
    let density: f64 = v.parse().map_err(|_| format!("bad density '{v}'"))?;
    if !(0.0..=1.0).contains(&density) || density == 0.0 {
        return Err(format!("density must be in (0,1], got {density}"));
    }
    Ok(density)
}

fn parse_q(s: &str) -> Result<u32, String> {
    let v = s
        .strip_prefix('q')
        .map(|r| r.strip_prefix(':').unwrap_or(r))
        .ok_or_else(|| format!("bad quantizer spec '{s}'"))?;
    let bits: u32 = v.parse().map_err(|_| format!("bad bit count '{v}'"))?;
    if !(1..=32).contains(&bits) {
        return Err(format!("quantizer bits must be in 1..=32, got {bits}"));
    }
    Ok(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(parse_spec("none").unwrap().name(), "identity");
        assert_eq!(parse_spec("topk:0.3").unwrap().name(), "topk(0.30)");
        assert_eq!(parse_spec("q:8").unwrap().name(), "q8");
        assert_eq!(parse_spec("topk:0.25+q:4").unwrap().name(), "topk(0.25)+q4");
        assert!(parse_spec("topk:0").is_err());
        assert!(parse_spec("topk:1.5").is_err());
        assert!(parse_spec("q:0").is_err());
        assert!(parse_spec("q:33").is_err());
        assert!(parse_spec("wat").is_err());
    }

    #[test]
    fn double_compression_roundtrip_preserves_support() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(1);
        let x: Vec<f32> = (0..200).map(|i| ((i as f32) - 100.0) / 17.0).collect();
        let dc = DoubleCompress::new(0.25, 8);
        let c = dc.compress(&x, &mut rng);
        let y = dc.decompress(&c);
        assert_eq!(y.len(), x.len());
        let nnz = y.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= 50, "nnz={nnz}");
        // Survivors should be near their originals (8-bit quantization).
        let norm = crate::tensor::norm2(&x);
        for (yi, xi) in y.iter().zip(&x) {
            if *yi != 0.0 {
                assert!((yi - xi).abs() < 0.02 * norm, "{yi} vs {xi}");
            }
        }
    }

    #[test]
    fn nominal_bits_bound_actual_wire_for_all_codecs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(9);
        for d in [1usize, 17, 255, 1024, 5000] {
            let gaussian: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let zeros = vec![0.0f32; d];
            for x in [&gaussian, &zeros] {
                let comps: Vec<Box<dyn Compressor>> = vec![
                    Box::new(Identity),
                    Box::new(TopK::with_density(0.07)),
                    Box::new(TopK::with_density(0.6)),
                    Box::new(QuantizeR::new(4)),
                    Box::new(QuantizeR::with_bucket(3, 100)),
                    Box::new(DoubleCompress::new(0.25, 4)),
                    Box::new(DoubleCompress::new(0.5, 9)),
                ];
                for c in comps {
                    let enc = c.compress(x, &mut rng);
                    assert!(
                        c.nominal_bits(d) >= enc.wire_bits,
                        "{} d={d}: nominal {} < wire {}",
                        c.name(),
                        c.nominal_bits(d),
                        enc.wire_bits
                    );
                }
            }
        }
    }

    #[test]
    fn double_compression_nominal_is_exact_on_nonzero_input() {
        // For inputs whose survivor buckets all have nonzero norm, the
        // encoder emits exactly the maximal layout the formula counts.
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(10);
        for d in [64usize, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin() + 1.5).collect();
            let dc = DoubleCompress::new(0.3, 6);
            let enc = dc.compress(&x, &mut rng);
            assert_eq!(dc.nominal_bits(d), enc.wire_bits, "d={d}");
        }
    }

    #[test]
    fn double_compression_beats_dense_on_wire() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(2);
        let x: Vec<f32> = (0..10_000).map(|i| (i as f32).sin()).collect();
        let dc = DoubleCompress::new(0.25, 4);
        let c = dc.compress(&x, &mut rng);
        // K=2500 of d=10000 at (14 idx + 1 sign + 5 level) bits/survivor
        // ≈ 50 kbit vs 320 kbit dense: > 6x cheaper.
        assert!(c.wire_bits < dense_bits(x.len()) / 6);
        // And cheaper than TopK alone at the same density (32-bit values).
        let topk_alone = TopK::with_density(0.25).compress(&x, &mut rng);
        assert!(c.wire_bits < topk_alone.wire_bits);
    }
}
