//! Minimal bfloat16 conversions for the opt-in bf16 storage modes.
//!
//! bf16 is the upper 16 bits of an IEEE-754 f32 (1 sign, 8 exponent,
//! 7 mantissa bits): same dynamic range as f32, ~2–3 decimal digits of
//! precision. Conversion here is **round-to-nearest-even** on the
//! truncated mantissa — the rounding every mainstream bf16 hardware unit
//! (TPU, AVX-512 BF16, NEON BF16) implements — so values produced by this
//! software path match what a device with native bf16 storage would hold.
//!
//! Two consumers:
//! * the `native-bf16` backend rounds hidden activations through
//!   [`round_bf16`] after every layer (logits stay f32) — see
//!   `backend::kernels::Bf16Kernels`;
//! * the `bf16` wire codec stores model payloads as raw bf16 halves
//!   (16 bits/coordinate) — see `compress::bf16`.
//!
//! Determinism: conversion is a pure function of the input bits (no RNG,
//! no flags, no table state), so both consumers are bit-reproducible.

/// Convert one f32 to bf16 bits with round-to-nearest-even.
///
/// NaNs are quieted (the top mantissa bit is forced on) so a NaN can never
/// round to infinity; infinities and zeros pass through exactly.
#[inline]
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // Keep the sign, force a quiet NaN payload that survives the
        // truncation (an all-zero truncated mantissa would read as Inf).
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round to nearest, ties to even: add 0x7FFF plus the lowest kept
    // mantissa bit, then truncate. Overflow of the mantissa carries into
    // the exponent, correctly rounding huge finite values to infinity.
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF).wrapping_add(round_bit)) >> 16) as u16
}

/// Convert bf16 bits back to f32 (exact: bf16 ⊂ f32).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round one f32 onto the bf16 grid (an f32→bf16→f32 round trip).
#[inline]
pub fn round_bf16(v: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(v))
}

/// Round a whole slice onto the bf16 grid in place.
#[inline]
pub fn round_slice_bf16(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = round_bf16(*v);
    }
}

/// Largest relative rounding error of the bf16 grid for normal values:
/// half a ulp of a 7-bit mantissa, 2⁻⁸. Used by the tolerance goldens in
/// `tests/backend_identity.rs` to bound bf16-vs-f32 drift per operation.
pub const BF16_EPS: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_pass_through() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(round_bf16(v).to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-8 sits exactly between 1.0 and the next bf16 (1.0078125);
        // ties go to even (1.0, whose kept mantissa is even).
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(round_bf16(tie), 1.0);
        // Just above the tie rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(round_bf16(above), f32::from_bits(0x3F81_0000));
        // Just below rounds down.
        let below = f32::from_bits(0x3F80_7FFF);
        assert_eq!(round_bf16(below), 1.0);
    }

    #[test]
    fn relative_error_bounded_by_eps() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = rng.normal_f32(0.0, 10.0);
            let r = round_bf16(v);
            assert!(
                (r - v).abs() <= BF16_EPS * v.abs(),
                "{v} -> {r} (err {})",
                (r - v).abs()
            );
        }
    }

    #[test]
    fn nan_stays_nan_and_infinite_overflow() {
        assert!(round_bf16(f32::NAN).is_nan());
        assert!(bf16_to_f32(f32_to_bf16(-f32::NAN)).is_nan());
        // Largest finite f32 rounds to +inf on the bf16 grid (its nearest
        // bf16 neighbour above is out of range).
        assert_eq!(round_bf16(f32::MAX), f32::INFINITY);
        assert_eq!(round_bf16(f32::MIN), f32::NEG_INFINITY);
        // But the largest exact bf16 value stays finite.
        let max_bf16 = bf16_to_f32(0x7F7F);
        assert_eq!(round_bf16(max_bf16), max_bf16);
    }

    #[test]
    fn sign_preserved_and_idempotent() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(2);
        for _ in 0..1_000 {
            let v = rng.normal_f32(0.0, 1.0);
            let r = round_bf16(v);
            assert_eq!(r.is_sign_negative(), v.is_sign_negative());
            // Rounding is a projection: applying it twice changes nothing.
            assert_eq!(round_bf16(r).to_bits(), r.to_bits());
        }
    }

    #[test]
    fn slice_rounding_matches_scalar() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(3);
        let xs: Vec<f32> = (0..257).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut ys = xs.clone();
        round_slice_bf16(&mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(y.to_bits(), round_bf16(*x).to_bits());
        }
    }
}
