//! Minimal JSON: a writer for metrics/results output and a recursive-descent
//! parser for the artifact manifest (`artifacts/manifest.json`).
//!
//! serde is not in the offline vendor set; this covers the subset the
//! project needs (objects, arrays, strings, numbers, bools, null) with
//! strict parsing and pretty or compact serialization.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (key-ordered).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert `key = value` (panics if `self` is not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    /// Member lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key→value map, if this is an object.
    pub fn members(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented limitation).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document (strict; trailing garbage is an error).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// JSON parse failure with byte position.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut obj = Json::obj();
        obj.set("name", "fedcomloc".into());
        obj.set("rounds", 500usize.into());
        obj.set("lr", 0.05.into());
        obj.set("flags", vec![true, false].into());
        let mut inner = Json::obj();
        inner.set("alpha", 0.7.into());
        obj.set("data", inner);
        for text in [obj.to_string_compact(), obj.to_string_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, obj);
        }
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd\u{41}é");
    }

    #[test]
    fn parse_numbers() {
        let v = parse("[0, -1, 3.5, 1e3, -2.5E-2, 123456789]").unwrap();
        let arr = v.as_arr().unwrap();
        let xs: Vec<f64> = arr.iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, vec![0.0, -1.0, 3.5, 1000.0, -0.025, 123456789.0]);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("  null ").unwrap(), Json::Null);
    }
}
