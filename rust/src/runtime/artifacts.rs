//! `artifacts/manifest.json` schema: what the AOT step produced.
//!
//! The manifest is the single source of truth for executable shapes; the
//! runtime validates every call against it, so a Rust/Python layout drift
//! fails loudly at load time instead of producing garbage numerics.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one executable input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type name as the manifest spells it (`float32`, `int32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count (1 for scalars).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled program: HLO file plus its call signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Manifest key, e.g. `mlp_train_step`.
    pub name: String,
    /// Absolute path of the HLO text file.
    pub file: PathBuf,
    /// Input signature, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signature, in tuple order.
    pub outputs: Vec<TensorSpec>,
}

/// Static shapes one model family's executables were compiled for.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// Flat parameter count d.
    pub dim: usize,
    /// Train-step batch size.
    pub batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
    /// Per-example input shape (e.g. `[784]` or `[3, 32, 32]`).
    pub input_shape: Vec<usize>,
    /// Logit count.
    pub num_classes: usize,
}

impl ModelArtifact {
    /// Per-example flat input length.
    pub fn input_dim(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`: every compiled program and model
/// family the AOT step produced.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (artifact paths resolve
    /// relative to it).
    pub dir: PathBuf,
    /// Programs by manifest key.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Model families by name.
    pub models: BTreeMap<String, ModelArtifact>,
}

/// Manifest loading/validation failure.
#[derive(Debug)]
pub enum ManifestError {
    /// The manifest file could not be read.
    Io {
        /// Path that failed to read.
        path: PathBuf,
        /// Underlying I/O error.
        source: std::io::Error,
    },
    /// The manifest JSON is malformed.
    Parse(String),
    /// A required field or entry is absent (named).
    Missing(String),
    /// An artifact's HLO file is not on disk.
    FileMissing(PathBuf),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io { path, source } => {
                write!(f, "cannot read {}: {source}", path.display())
            }
            ManifestError::Parse(msg) => write!(f, "manifest parse error: {msg}"),
            ManifestError::Missing(field) => write!(f, "manifest missing field {field}"),
            ManifestError::FileMissing(path) => {
                write!(f, "artifact file missing: {}", path.display())
            }
        }
    }
}

impl std::error::Error for ManifestError {}

impl Manifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ManifestError::Io {
            path: path.clone(),
            source,
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text; `dir` anchors the artifact file paths.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ManifestError> {
        let root = json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let artifacts_obj = root
            .get("artifacts")
            .and_then(Json::members)
            .ok_or_else(|| ManifestError::Missing("artifacts".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in artifacts_obj {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Missing(format!("artifacts.{name}.file")))?;
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>, ManifestError> {
                entry
                    .get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ManifestError::Missing(format!("artifacts.{name}.{key}")))?
                    .iter()
                    .map(|t| {
                        let shape = t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| ManifestError::Missing("shape".into()))?
                            .iter()
                            .map(|v| v.as_usize().ok_or_else(|| ManifestError::Parse("bad dim".into())))
                            .collect::<Result<Vec<_>, _>>()?;
                        let dtype = t
                            .get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| ManifestError::Missing("dtype".into()))?
                            .to_string();
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_tensors("inputs")?,
                    outputs: parse_tensors("outputs")?,
                },
            );
        }

        let models_obj = root
            .get("models")
            .and_then(Json::members)
            .ok_or_else(|| ManifestError::Missing("models".into()))?;
        let mut models = BTreeMap::new();
        for (name, entry) in models_obj {
            let get = |key: &str| -> Result<usize, ManifestError> {
                entry
                    .get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| ManifestError::Missing(format!("models.{name}.{key}")))
            };
            let input_shape = entry
                .get("input_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError::Missing(format!("models.{name}.input_shape")))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            models.insert(
                name.clone(),
                ModelArtifact {
                    dim: get("dim")?,
                    batch: get("batch")?,
                    eval_batch: get("eval_batch")?,
                    input_shape,
                    num_classes: get("num_classes")?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            models,
        })
    }

    /// Look up an artifact and verify its HLO file exists on disk.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, ManifestError> {
        let spec = self
            .artifacts
            .get(name)
            .ok_or_else(|| ManifestError::Missing(format!("artifact '{name}'")))?;
        if !spec.file.is_file() {
            return Err(ManifestError::FileMissing(spec.file.clone()));
        }
        Ok(spec)
    }

    /// Look up a model family's compiled shapes.
    pub fn model(&self, name: &str) -> Result<&ModelArtifact, ManifestError> {
        self.models
            .get(name)
            .ok_or_else(|| ManifestError::Missing(format!("model '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1,
        "hlo": "text",
        "artifacts": {
            "mlp_train_step": {
                "file": "mlp_train_step.hlo.txt",
                "inputs": [
                    {"shape": [109386], "dtype": "float32"},
                    {"shape": [109386], "dtype": "float32"},
                    {"shape": [64, 784], "dtype": "float32"},
                    {"shape": [64], "dtype": "int32"},
                    {"shape": [], "dtype": "float32"}
                ],
                "outputs": [
                    {"shape": [109386], "dtype": "float32"},
                    {"shape": [], "dtype": "float32"}
                ]
            }
        },
        "models": {
            "mlp": {"dim": 109386, "batch": 64, "eval_batch": 256,
                     "input_shape": [784], "num_classes": 10}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        let a = &m.artifacts["mlp_train_step"];
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[0].elements(), 109_386);
        assert_eq!(a.inputs[2].shape, vec![64, 784]);
        assert_eq!(a.inputs[4].shape, Vec::<usize>::new()); // scalar
        assert_eq!(a.outputs[1].dtype, "float32");
        let model = m.model("mlp").unwrap();
        assert_eq!(model.dim, 109_386);
        assert_eq!(model.input_dim(), 784);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"artifacts":{}}"#).is_err());
        let bad = r#"{"artifacts": {"x": {"inputs": [], "outputs": []}}, "models": {}}"#;
        assert!(matches!(
            Manifest::parse(Path::new("/tmp"), bad),
            Err(ManifestError::Missing(_))
        ));
    }

    #[test]
    fn artifact_checks_file_presence() {
        let m = Manifest::parse(Path::new("/definitely/missing"), SAMPLE).unwrap();
        assert!(matches!(
            m.artifact("mlp_train_step"),
            Err(ManifestError::FileMissing(_))
        ));
        assert!(matches!(m.artifact("nope"), Err(ManifestError::Missing(_))));
    }
}
