//! Adversarial wire-format property test: [`Message::decode`] over
//! mutated, truncated, and garbage-extended frames must **never panic or
//! over-allocate** — every outcome is either a structured `WireError` or
//! a message whose declared geometry survived full payload validation
//! (in which case decoding the payload to a dense vector is total).
//!
//! Valid frames are produced by the real codec registry (every family
//! plus a chain), so the declared-length checks are exercised against
//! every payload layout the federation actually ships.
//!
//! The second half extends the totality contract from single frames to
//! adversarial *delivery sequences* at the transport boundary: duplicated
//! frames, frames replayed from earlier rounds, and rounds arriving out
//! of order must all be absorbed by the fault plane ([`FaultNet`]) as
//! counted, structured outcomes — never a panic, never a silently
//! accepted stale update.

use fedcomloc::compress::CompressorSpec;
use fedcomloc::fed::faults::{FaultNet, FaultSpec};
use fedcomloc::fed::message::Message;
use fedcomloc::fed::transport::{InProc, Transport};
use fedcomloc::util::quickcheck::{check, Gen};
use fedcomloc::util::rng::Rng;

/// One spec per codec family, plus the chained spelling (its own codec
/// tag) and the bf16 truncation codec (tag 6, the `native-bf16` plane's
/// wire twin) — the full set of wire formats `Message::decode` accepts.
const SPECS: &[&str] = &[
    "none",
    "topk:0.25",
    "randk:0.25",
    "q:8",
    "q:4",
    "natural",
    "topk:0.1|q8",
    "bf16",
];

/// Encode a valid frame for a random codec, dimension, and payload.
fn valid_frame(g: &mut Gen) -> Vec<u8> {
    let spec = *g.choose(SPECS);
    let dim = g.usize_in(1..=64);
    let x = g.vec_f32(dim..=dim, -4.0, 4.0);
    let mut pipe = CompressorSpec::parse(spec).unwrap().build(dim);
    let mut rng = Rng::seed_from_u64(g.rng().next_u64());
    let enc = pipe.compress(&x, 0, &mut rng);
    Message::from_compressed(0, 1, enc).encode()
}

#[test]
fn valid_frames_of_every_codec_family_roundtrip() {
    check("wire roundtrip", 200, |g| {
        let bytes = valid_frame(g);
        let msg = Message::decode(&bytes)
            .map_err(|e| format!("valid frame rejected: {e:?} ({} bytes)", bytes.len()))?;
        // A validated payload must decode to the declared dimension.
        let dense = msg.to_dense();
        if dense.len() != msg.header.dim as usize {
            return Err(format!("dim {} decoded to {} values", msg.header.dim, dense.len()));
        }
        Ok(())
    });
}

#[test]
fn mutated_frames_never_panic() {
    check("wire fuzz", 400, |g| {
        let mut bytes = valid_frame(g);
        match g.usize_in(0..=2) {
            0 => {
                // Truncate anywhere, including inside the header.
                let keep = g.usize_in(0..=bytes.len());
                bytes.truncate(keep);
            }
            1 => {
                // Flip a handful of bytes — header fields (magic, codec
                // tag, declared dim/params) and payload alike.
                for _ in 0..g.usize_in(1..=4) {
                    if bytes.is_empty() {
                        break;
                    }
                    let pos = g.rng().below_usize(bytes.len());
                    let val = (g.rng().next_u64() & 0xFF) as u8;
                    bytes[pos] = val;
                }
            }
            _ => {
                // Graft trailing garbage (decode must bound itself by the
                // declared frame length, not the buffer length).
                let extra = g.usize_in(1..=64);
                for _ in 0..extra {
                    bytes.push((g.rng().next_u64() & 0xFF) as u8);
                }
            }
        }
        // The property is totality: every outcome is a structured error
        // or a message whose payload decodes without panicking.
        if let Ok(msg) = Message::decode(&bytes) {
            let dense = msg.to_dense();
            if dense.len() != msg.header.dim as usize {
                return Err(format!(
                    "accepted frame decodes {} values for declared dim {}",
                    dense.len(),
                    msg.header.dim
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn declared_length_bombs_are_rejected_before_allocation() {
    // A frame whose header declares a huge dimension but carries a tiny
    // payload must be rejected by the length validation — not trusted
    // into a multi-gigabyte allocation.
    let mut bytes = Message::dense(0, 1, &[1.0, 2.0]).encode();
    // dim is the little-endian u32 after magic(2) + version(1) + codec
    // tag(1) + quantizer bits(1) + bucket(4).
    let dim_pos = 9;
    bytes[dim_pos..dim_pos + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Message::decode(&bytes).is_err(), "dim bomb must be rejected");
}

#[test]
fn duplicated_deliveries_are_counted_and_collapse_to_one_update() {
    // dup:1 duplicates every uplink delivery; the caller still observes
    // exactly one received message per send, and the extra physical frame
    // is billed and counted rather than folded twice.
    let mut inner = InProc::default();
    let mut net = FaultNet::new(&mut inner, FaultSpec::parse("dup:1").unwrap(), 11);
    let clients = [0usize, 1, 2];
    let down = Message::dense(0, u32::MAX, &[1.0, 2.0]);
    assert_eq!(net.broadcast(&clients, &down), clients.to_vec());
    for &c in &clients {
        let up = Message::dense(0, c as u32, &[0.5, 0.5]);
        assert!(net.uplink(c, up).is_some(), "client {c} must deliver once");
    }
    let report = net.end_round();
    assert_eq!(report.dup_frames, 3, "every uplink duplicated");
    // 3 clean sends + 3 duplicates cross the wire.
    assert_eq!(report.usage.uplink_msgs, 6);
    assert!(!report.aborted);
}

#[test]
fn frames_replayed_from_earlier_rounds_are_rejected() {
    // Capture a round-0 uplink frame, then replay its decoded message into
    // round 2: the fault plane must reject it as stale (None) and count
    // it, not hand the driver a stale update.
    let replayed_bytes = Message::dense(0, 7, &[9.0, 9.0]).encode();
    let replayed = Message::decode(&replayed_bytes).expect("captured frame is valid");

    let mut inner = InProc::default();
    let mut net = FaultNet::new(&mut inner, FaultSpec::default(), 5);
    let down = Message::dense(2, u32::MAX, &[1.0, 2.0]);
    assert_eq!(net.broadcast(&[7], &down), vec![7]);
    assert!(net.uplink(7, replayed).is_none(), "stale frame must be dropped");
    assert_eq!(net.stale_frames(), 1);
    // The client's *current* frame still goes through afterwards.
    assert!(net.uplink(7, Message::dense(2, 7, &[1.0, 1.0])).is_some());
    let report = net.end_round();
    assert!(!report.aborted);
}

#[test]
fn out_of_order_rounds_never_leak_stale_state_across_round_boundaries() {
    // Drive rounds 5 then 3 then 5 again (a reordered scheduler would do
    // this after a recovery): each round's sequencing is self-contained —
    // frames stamped with the round broadcast last are accepted, anything
    // else is stale, and per-round fate maps reset at end_round.
    let mut inner = InProc::default();
    let mut net = FaultNet::new(&mut inner, FaultSpec::default(), 5);
    for &round in &[5u32, 3, 5] {
        let down = Message::dense(round as usize, u32::MAX, &[1.0]);
        assert_eq!(net.broadcast(&[0, 1], &down), vec![0, 1]);
        // A frame from any *other* round is stale for this one.
        let other = if round == 5 { 3 } else { 5 };
        assert!(net.uplink(0, Message::dense(other as usize, 0, &[2.0])).is_none());
        assert_eq!(net.stale_frames(), 1, "one replay rejected this round");
        assert!(net.uplink(0, Message::dense(round as usize, 0, &[2.0])).is_some());
        assert!(net.uplink(1, Message::dense(round as usize, 1, &[2.0])).is_some());
        let report = net.end_round();
        assert!(!report.aborted, "full participation can never miss quorum");
        assert_eq!(net.stale_frames(), 0, "per-round counters reset at end_round");
    }
}
