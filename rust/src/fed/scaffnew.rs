//! FedComLoc (paper Algorithm 1): Scaffnew/ProxSkip local training with
//! compression, in the three variants of §3.2.
//!
//! Iteration structure. The server pre-commits to the Bernoulli(p) coin
//! sequence θ_0..θ_{T−1} (Algorithm 1 line 2); a *communication round* is a
//! maximal run of θ=0 iterations followed by the θ=1 iteration that
//! triggers aggregation, so segment lengths are Geometric(p) with mean 1/p
//! — the paper's "average of 10 local iterations per round" at p = 0.1.
//!
//! Client sampling (paper §4: 10 of 100 per round) follows the standard
//! FL deployment shape: the sampled set receives the current global model,
//! runs the whole segment locally, and participates in the aggregation;
//! control variates h_i of unsampled clients stay frozen.
//!
//! Compression points (and one deliberate reading choice): Algorithm 1's
//! line 8 notationally applies C(x̂) every iteration, but between
//! communications x̂ never crosses the network, so -Com compresses exactly
//! the transmitted update (at θ=1). In-iteration model compression is
//! precisely the -Local variant (line 6½), which we implement via the
//! in-graph TopK Pallas kernel. -Global compresses the aggregated model
//! server-side (lines 11–12), and the h-refresh (line 16) uses the
//! *compressed* x_{t+1}, faithful to the pseudocode.
//!
//! Invariant (tested): with -Com/-Local, Σ_i h_i stays 0 — each round's
//! updates sum to (p/γ)·(m·mean(ε) − Σ ε) = 0.

use super::transport::send_through;
use super::{Federation, RoundLogger, RunConfig, Variant};
use crate::compress::Compressor;
use crate::metrics::MetricsLog;
use crate::util::rng::Rng;

/// One client's segment result.
struct SegmentResult {
    /// Receiver-side reconstruction of the uplinked model ε_i.
    epsilon: Vec<f32>,
    uplink_bits: u64,
    loss_sum: f64,
    steps: usize,
}

/// Draw the next segment length: iterations until (and including) the next
/// θ=1 coin. Shared server/worker stream per Algorithm 1 lines 2–3.
pub fn next_segment_len(coin_rng: &mut Rng, p: f64) -> usize {
    let mut len = 1;
    while !coin_rng.bernoulli(p) {
        len += 1;
    }
    len
}

pub fn run(
    cfg: &RunConfig,
    fed: &mut Federation,
    variant: Variant,
    compressor: &dyn Compressor,
) -> MetricsLog {
    let name = format!(
        "fedcomloc-{}[{}]-{}-a{}",
        variant.name(),
        compressor.name(),
        fed.model.name(),
        cfg.dirichlet_alpha
    );
    let log = MetricsLog::new(&name)
        .with_meta("algorithm", format!("fedcomloc-{}", variant.name()))
        .with_meta("compressor", compressor.name())
        .with_meta("p", cfg.p)
        .with_meta("gamma", cfg.gamma)
        .with_meta("alpha", cfg.dirichlet_alpha)
        .with_meta("clients", cfg.n_clients)
        .with_meta("sampled", cfg.clients_per_round);
    let mut logger = RoundLogger::new(cfg, log);
    let mut coin_rng = fed.rng.derive(0x5EED_C019);
    let mut server_rng = fed.rng.derive(0x5E2E_5EED);
    let dim = fed.x.len();
    let p_over_gamma = (cfg.p / cfg.gamma as f64) as f32;
    // Wire size of the current global model as the sampled clients will
    // receive it (Global keeps a compressed model; others send dense).
    let mut downlink_bits_per_client: u64 = crate::compress::dense_bits(dim);

    // Extract density for the -Local in-graph masked step (TopK only; the
    // -Local variant is defined for sparsity in the paper's experiments).
    let local_density = compressor_density(compressor);

    for round in 0..cfg.rounds {
        logger.begin_round();
        let seg_len = next_segment_len(&mut coin_rng, cfg.p);
        let sampled = fed.sample_clients(cfg.clients_per_round);

        // ---- downlink: broadcast current model to the sampled set ----
        let mut usage = super::transport::WireUsage::default();
        for _ in &sampled {
            usage.add_downlink(downlink_bits_per_client);
        }

        // ---- local segments in parallel ----
        let x = fed.x.clone();
        let trainer = &fed.trainer;
        let clients = &fed.clients;
        let gamma = cfg.gamma;
        let results: Vec<SegmentResult> = fed.pool.map(&sampled, |_, &ci| {
            let mut state = clients[ci].lock().unwrap();
            let mut xi = x.clone();
            let mut loss_sum = 0.0f64;
            for _ in 0..seg_len {
                let batch = state.loader.next_batch();
                let (next, loss) = match (variant, local_density) {
                    (Variant::Local, Some(density)) => {
                        trainer.train_step_masked(&xi, &state.h, &batch, gamma, density)
                    }
                    _ => trainer.train_step(&xi, &state.h, &batch, gamma),
                };
                xi = next;
                loss_sum += loss as f64;
            }
            // ---- uplink: transmit x̂ (compressed for -Com) ----
            let (epsilon, bits) = match variant {
                Variant::Com => send_through(compressor, &xi, &mut state.rng),
                _ => (xi, crate::compress::dense_bits(dim)),
            };
            SegmentResult {
                epsilon,
                uplink_bits: bits,
                loss_sum,
                steps: seg_len,
            }
        });

        // ---- aggregate (Algorithm 1 line 10) ----
        let rows: Vec<&[f32]> = results.iter().map(|r| r.epsilon.as_slice()).collect();
        crate::tensor::mean_into(&rows, &mut fed.x);
        // -Global: compress the aggregated model server-side (lines 11–12);
        // subsequent downlinks ship the compressed form.
        if variant == Variant::Global {
            let (compressed, bits) = send_through(compressor, &fed.x, &mut server_rng);
            fed.x = compressed;
            downlink_bits_per_client = bits;
        }

        // ---- control-variate refresh (line 16) for participants ----
        for (r, &ci) in results.iter().zip(&sampled) {
            let mut state = fed.clients[ci].lock().unwrap();
            crate::tensor::control_variate_update(&mut state.h, &fed.x, &r.epsilon, p_over_gamma);
        }

        for r in &results {
            usage.add_uplink(r.uplink_bits);
        }
        let total_steps: usize = results.iter().map(|r| r.steps).sum();
        let loss_sum: f64 = results.iter().map(|r| r.loss_sum).sum();
        let train_loss = loss_sum / total_steps.max(1) as f64;

        let eval = if (round + 1) % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(fed.evaluate())
        } else {
            None
        };
        if let Some(e) = &eval {
            log::info!(
                "[{name}] round {round}: loss {train_loss:.4} acc {:.4} up {} bits",
                e.accuracy,
                usage.uplink_bits
            );
        }
        logger.end_round(
            round,
            seg_len,
            train_loss,
            usage.uplink_bits,
            usage.downlink_bits,
            eval,
        );
    }
    logger.finish()
}

/// Density of a TopK(-like) compressor for the -Local masked step; None for
/// quantizers (the -Local variant is sparsity-based in the paper).
fn compressor_density(c: &dyn Compressor) -> Option<f64> {
    let name = c.name();
    if let Some(rest) = name.strip_prefix("topk(") {
        rest.split(')')
            .next()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|d| (0.0..=1.0).contains(d))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_lengths_geometric() {
        let mut rng = Rng::seed_from_u64(1);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| next_segment_len(&mut rng, 0.1) as f64).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.3, "mean={mean}");
        let mut rng = Rng::seed_from_u64(2);
        let mean: f64 =
            (0..n).map(|_| next_segment_len(&mut rng, 0.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn density_extraction() {
        use crate::compress::{parse_spec, TopK};
        assert_eq!(
            compressor_density(&TopK::with_density(0.25)),
            Some(0.25)
        );
        let q = parse_spec("q:8").unwrap();
        assert_eq!(compressor_density(q.as_ref()), None);
    }
}
