//! Micro-bench: PJRT executable call latency (the AOT plane's hot path).
//!
//! Requires `make artifacts`; prints a note and exits cleanly otherwise.
//! Compares the compiled train_step/grad/evaluate against the native plane
//! so the auto trainer policy in experiments::ExpOptions stays justified.

use fedcomloc::data::loader::{eval_batches, ClientLoader};
use fedcomloc::data::{synthetic, DatasetSpec};
use fedcomloc::model::native::NativeTrainer;
use fedcomloc::model::{build_model, init_params, LocalTrainer};
use fedcomloc::runtime::{artifacts_available, default_artifacts_dir, PjrtTrainer};
use fedcomloc::util::benchkit::{bb, Bench};
use fedcomloc::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir) {
        println!("bench_micro_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    for (model_spec, dataset) in [("mlp", DatasetSpec::mnist()), ("cnn", DatasetSpec::cifar10())] {
        let model = build_model(model_spec).unwrap();
        let pjrt = match PjrtTrainer::load(&dir, &model) {
            Ok(t) => t,
            Err(e) => {
                println!("skip {model_spec}: {e}");
                continue;
            }
        };
        let native = NativeTrainer::new(model.clone());
        let mut rng = Rng::seed_from_u64(5);
        let tt = synthetic::generate(&dataset, 512, 256, &mut rng);
        let data = Arc::new(tt.train);
        let mut loader = ClientLoader::new(
            Arc::clone(&data),
            (0..512).collect(),
            pjrt.batch_size(),
            Rng::seed_from_u64(6),
        );
        let batch = loader.next_batch();
        let params = init_params(&model, &mut rng);
        let h = vec![0.0f32; params.len()];
        let eb = eval_batches(&tt.test, pjrt.eval_batch_size());

        let mut b = Bench::new(&format!("runtime_{}", model.name()));
        b.case("pjrt train_step", || {
            bb(pjrt.train_step(bb(&params), bb(&h), bb(&batch), 0.05));
        });
        b.case("native train_step", || {
            bb(native.train_step(bb(&params), bb(&h), bb(&batch), 0.05));
        });
        b.case("pjrt train_step_masked 30%", || {
            bb(pjrt.train_step_masked(bb(&params), bb(&h), bb(&batch), 0.05, 0.3));
        });
        b.case("pjrt grad", || {
            bb(pjrt.grad(bb(&params), bb(&batch)));
        });
        b.case("pjrt eval (full test set)", || {
            bb(pjrt.eval(bb(&params), bb(&eb)));
        });
        b.finish();
    }
}
