//! Named sweep presets: the shipped TOMLs under `experiments/` embedded at
//! compile time, so `fedcomloc sweep run --preset <name>` works from any
//! working directory and the binary can never drift from the files it
//! ships. `experiments/<name>.toml` is the source of truth — edit the file,
//! rebuild, done.

use super::spec::SweepSpec;

/// One shipped sweep: its registry name and the embedded TOML text.
pub struct SweepPreset {
    /// Preset name (also the TOML's `name` and file stem).
    pub name: &'static str,
    /// Paper figures/tables this sweep reproduces.
    pub paper: &'static str,
    /// The embedded `experiments/<name>.toml` source.
    pub toml: &'static str,
}

static SWEEP_PRESETS: [SweepPreset; 13] = [
    SweepPreset {
        name: "sparsity",
        paper: "Table 1, Figure 1",
        toml: include_str!("../../../experiments/sparsity.toml"),
    },
    SweepPreset {
        name: "heterogeneity",
        paper: "Table 2, Figures 2, 12",
        toml: include_str!("../../../experiments/heterogeneity.toml"),
    },
    SweepPreset {
        name: "cifar",
        paper: "Figure 3",
        toml: include_str!("../../../experiments/cifar.toml"),
    },
    SweepPreset {
        name: "quantization",
        paper: "Figures 5, 7, 14, 15",
        toml: include_str!("../../../experiments/quantization.toml"),
    },
    SweepPreset {
        name: "local_iters",
        paper: "Figure 8",
        toml: include_str!("../../../experiments/local_iters.toml"),
    },
    SweepPreset {
        name: "baselines",
        paper: "Figure 9",
        toml: include_str!("../../../experiments/baselines.toml"),
    },
    SweepPreset {
        name: "variants",
        paper: "Figure 10",
        toml: include_str!("../../../experiments/variants.toml"),
    },
    SweepPreset {
        name: "double",
        paper: "Figure 16",
        toml: include_str!("../../../experiments/double.toml"),
    },
    SweepPreset {
        name: "bidir",
        paper: "Figure 16 (extended)",
        toml: include_str!("../../../experiments/bidir.toml"),
    },
    SweepPreset {
        name: "stragglers",
        paper: "",
        toml: include_str!("../../../experiments/stragglers.toml"),
    },
    SweepPreset {
        name: "smoke",
        paper: "",
        toml: include_str!("../../../experiments/smoke.toml"),
    },
    SweepPreset {
        name: "scale",
        paper: "",
        toml: include_str!("../../../experiments/scale.toml"),
    },
    SweepPreset {
        name: "chaos",
        paper: "",
        toml: include_str!("../../../experiments/chaos.toml"),
    },
];

/// Every shipped sweep, in paper order.
pub fn sweep_presets() -> &'static [SweepPreset] {
    &SWEEP_PRESETS
}

/// Parse the shipped sweep named `name` (None if unknown).
pub fn preset_by_name(name: &str) -> Option<Result<SweepSpec, String>> {
    sweep_presets()
        .iter()
        .find(|p| p.name == name)
        .map(|p| SweepSpec::parse_str(p.toml).map_err(|e| format!("preset '{name}': {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_parses_expands_and_matches_its_name() {
        for preset in sweep_presets() {
            let spec = preset_by_name(preset.name)
                .unwrap()
                .unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(spec.name, preset.name, "file name vs TOML name");
            let units = spec
                .expand(1.0, None)
                .unwrap_or_else(|e| panic!("{}: {e}", preset.name));
            assert!(!units.is_empty(), "{}", preset.name);
            // Run ids must be unique (they key resume and JSONL files).
            let mut ids: Vec<_> = units.iter().map(|u| u.id.clone()).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), units.len(), "{}", preset.name);
        }
        assert!(preset_by_name("nope").is_none());
    }

    #[test]
    fn shipped_matrix_sizes_match_the_legacy_experiment_grids() {
        let runs = |name: &str| {
            preset_by_name(name)
                .unwrap()
                .unwrap()
                .expand(1.0, None)
                .unwrap()
                .len()
        };
        assert_eq!(runs("sparsity"), 6, "K in {{100,10,30,50,70,90}}%");
        assert_eq!(runs("heterogeneity"), 18, "3 densities x 6 alphas");
        assert_eq!(runs("cifar"), 12, "4 densities x 3 stepsizes");
        assert_eq!(runs("quantization"), 4 + 8 + 4, "fig5 + fig7/14 + fig15");
        assert_eq!(runs("local_iters"), 5, "p grid");
        assert_eq!(runs("baselines"), 1 + 3 + 4, "fig9 panels");
        assert_eq!(runs("variants"), 9, "3 densities x 3 variants");
        assert_eq!(runs("double"), 5, "fig16 cases");
        assert_eq!(runs("bidir"), 6 + 4, "up curve + asymmetric grid");
        assert_eq!(runs("stragglers"), 6, "2 uplinks x 3 scenarios");
        assert_eq!(runs("smoke"), 2);
        assert_eq!(runs("chaos"), 6, "fault-free baseline + 5 fault plans");
    }
}
