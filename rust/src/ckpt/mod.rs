//! Crash-tolerant checkpointing and checkpoint-backed inference serving.
//!
//! This subsystem closes the train→deploy loop: `fedcomloc run` snapshots
//! the *entire* federation state at round boundaries, a killed run resumes
//! **bit-identically** (the paper's determinism story extended across
//! process lifetimes), and `fedcomloc serve` answers inference requests
//! straight from a checkpoint file.
//!
//! Three pieces:
//!
//! * [`snapshot`] — the versioned, self-describing binary container
//!   ([`Snapshot`]): a schema-tagged header plus named, length-framed,
//!   CRC-guarded state sections, written atomically (tmp + fsync + rename)
//!   so a crash mid-write can never corrupt the latest good checkpoint.
//! * [`checkpointer`] — [`Checkpointer`], a
//!   [`crate::fed::DriveObserver`] that captures/restores every
//!   cross-round state stream: model parameters, the federation root RNG,
//!   per-client control variates + RNG streams + loader cursors + `ef`
//!   residuals, the downlink pipeline, the algorithm's
//!   [`crate::fed::AlgoState`], the transport (including the scenario
//!   engine's virtual clock and pending straggler buffer), the cumulative
//!   metric counters, and the per-round records already emitted. Resume is
//!   *bit-identical*: a run killed at any checkpointed round and restarted
//!   produces byte-identical metrics to an uninterrupted run (pinned by
//!   `rust/tests/checkpoint_resume.rs` across all four algorithms,
//!   stateful `ef` pipelines, and `semisync` scenarios).
//! * [`serve`] — [`ServeState`], the deploy side: loads a checkpoint,
//!   rebuilds the model + eval set from the embedded config, and answers
//!   `info`/`eval`/`predict` requests over a JSON-lines protocol, each
//!   reply carrying the dense vs masked vs quantized inference cost
//!   (parameters touched, wire-equivalent bytes, multiply-adds).
//!
//! The checkpoint embeds its full [`crate::fed::RunConfig`] as canonical
//! key/value pairs ([`crate::config::to_kv`]); resume validates them
//! against the live config and refuses a mismatch, naming the offending
//! key — a checkpoint can never silently continue under different
//! hyperparameters.

pub mod checkpointer;
pub mod serve;
pub mod snapshot;

pub use checkpointer::Checkpointer;
pub use serve::ServeState;
pub use snapshot::{latest_checkpoint, verify_dir, Snapshot};

/// Checkpoint container schema version ([`Snapshot`] refuses other
/// versions). Bump on any layout change to the header or the section
/// encodings in [`checkpointer`].
///
/// v2: the embedded config gained the `faults` key and per-round records
/// carry the fault/recovery counters (`corrupt_frames`, `retransmits`,
/// `dup_frames`, `backoff_secs`, `aborted`).
pub const SCHEMA_VERSION: u16 = 2;
