//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! This is the repository's proof-of-composition (EXPERIMENTS.md §E2E):
//!
//!   L1/L2  Pallas kernels + JAX model, AOT-lowered by `make artifacts`
//!   L3     this Rust coordinator loads the HLO via PJRT and trains the
//!          109k-parameter MLP federatedly on synthetic FedMNIST:
//!          100 clients, 10 sampled/round, Dirichlet α=0.7, p=0.1,
//!          FedComLoc-Com with 30% TopK — the paper's §4 default —
//!          for a few hundred communication rounds, logging the loss
//!          curve, test accuracy, and exact communicated bits.
//!
//!     make artifacts && cargo run --release --example e2e_fedmnist
//!
//! Flags: --rounds N (default 200), --native (skip PJRT), --dense.

use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
use fedcomloc::model::{build_model, native::NativeTrainer, LocalTrainer};
use fedcomloc::runtime::{artifacts_available, default_artifacts_dir, PjrtTrainer};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |flag: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let rounds = get("--rounds", 200);
    let force_native = args.iter().any(|a| a == "--native");
    let dense = args.iter().any(|a| a == "--dense");

    let cfg = RunConfig {
        rounds,
        train_n: 12_000,
        test_n: 2_000,
        eval_every: 10,
        ..RunConfig::default_mnist()
    };

    // Compute plane: AOT artifacts through PJRT when available.
    let dir = default_artifacts_dir();
    let model = build_model("mlp").unwrap();
    let dim = model.dim();
    let trainer: Arc<dyn LocalTrainer> = if !force_native && artifacts_available(&dir) {
        println!("compute plane: PJRT (AOT artifacts from {})", dir.display());
        Arc::new(PjrtTrainer::load(&dir, &model).expect("artifacts load"))
    } else {
        println!("compute plane: native Rust (run `make artifacts` for the AOT plane)");
        Arc::new(NativeTrainer::new(model.clone()))
    };

    let spec = AlgorithmSpec::parse(if dense {
        "fedcomloc-com:none"
    } else {
        "fedcomloc-com:topk:0.3"
    })
    .unwrap();
    println!(
        "e2e: {} | {} clients ({} sampled) | {} rounds | p={} γ={} α={}",
        spec.name(),
        cfg.n_clients,
        cfg.clients_per_round,
        cfg.rounds,
        cfg.p,
        cfg.gamma,
        cfg.dirichlet_alpha
    );

    let t0 = std::time::Instant::now();
    let log = run(&cfg, trainer, &spec);
    let wall = t0.elapsed();

    println!("\n-- loss curve (communication rounds) --");
    println!("round  local_steps  train_loss  test_acc   cum_uplink_MB  total_cost");
    for r in &log.records {
        if r.test_accuracy.is_some() || r.round % 10 == 0 {
            println!(
                "{:>5}  {:>11}  {:>10.4}  {:>8}  {:>13.2}  {:>10.2}",
                r.round,
                r.local_steps,
                r.train_loss,
                r.test_accuracy
                    .map(|a| format!("{a:.4}"))
                    .unwrap_or_else(|| "-".into()),
                r.cum_uplink_bits as f64 / 8e6,
                r.total_cost,
            );
        }
    }
    let total_steps: usize = log.records.iter().map(|r| r.local_steps).sum();
    println!("\n== e2e summary ==");
    println!("wall time:            {wall:?}");
    println!("communication rounds: {}", log.records.len());
    println!("local iterations:     {total_steps} (expected ≈ rounds/p = {})", (rounds as f64 / cfg.p) as usize);
    println!("best test accuracy:   {:.4}", log.best_accuracy().unwrap());
    println!("final train loss:     {:.4}", log.final_train_loss().unwrap());
    println!(
        "uplink total:         {:.2} MB (dense equivalent {:.2} MB)",
        log.total_uplink_bits() as f64 / 8e6,
        (32 * dim * cfg.clients_per_round * rounds) as f64 / 8e6
    );
    let _ = log.save(std::path::Path::new("results/e2e"));
    println!("metrics saved under results/e2e/");
}
