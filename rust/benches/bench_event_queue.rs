//! Micro-bench: the scenario engine's event queue under a 10k-client
//! semi-synchronous round.
//!
//! The scheduler's per-round cost is one `push` per delivered client plus
//! one `pop` per accepted arrival, all on the `(time, seq)`-keyed heap —
//! this is the only data structure the discrete-event runtime adds to the
//! round loop, so its throughput bounds how far `n_clients` can scale.
//! Exports `BENCH_event_queue.json`; CI's `perf-smoke` job gates it
//! against `benches/baseline/BENCH_event_queue.json`.

use fedcomloc::fed::sim::EventQueue;
use fedcomloc::util::benchkit::{self, bb, Bench};
use fedcomloc::util::rng::Rng;

const ROUND: usize = 10_000;

fn main() {
    // Pre-drawn arrival times: the bench measures the queue, not the RNG.
    let mut rng = Rng::seed_from_u64(42);
    let times: Vec<f64> = (0..ROUND).map(|_| rng.uniform() * 100.0).collect();

    let mut b = Bench::new("event_queue");

    // Full round: every delivered client schedules one arrival, then the
    // server drains the heap in virtual-time order (K = n worst case).
    let mut q = EventQueue::new();
    b.case("10k-client round: push all + drain", || {
        for (c, &t) in times.iter().enumerate() {
            q.push(t, c);
        }
        while let Some(ev) = q.pop() {
            bb(ev);
        }
    });
    b.record_metric(
        "10k-client round events",
        2.0 * ROUND as f64,
        "events/round",
    );

    // FedBuff acceptance: push everyone, pop only the first K arrivals —
    // the common case leaves most of the heap unpopped each round.
    let k = 100;
    let mut q = EventQueue::new();
    b.case("10k-client round: push all + pop first 100", || {
        for (c, &t) in times.iter().enumerate() {
            q.push(t, c);
        }
        for _ in 0..k {
            bb(q.pop());
        }
        while q.pop().is_some() {} // reset without measuring a leak
    });

    // Steady-state churn: an interleaved push/pop stream at constant
    // occupancy, the long-run shape of a multi-round simulation.
    let mut q = EventQueue::new();
    for (c, &t) in times.iter().take(1_000).enumerate() {
        q.push(t, c);
    }
    let mut i = 0usize;
    b.case("steady-state push+pop at 1k occupancy", || {
        let (t, c) = q.pop().expect("occupancy stays positive");
        bb((t, c));
        q.push(t + times[i % ROUND], c);
        i += 1;
    });

    b.finish();
    std::process::exit(benchkit::finalize("event_queue"));
}
