//! Total-cost model (paper §4.5, Figure 8).
//!
//! "A communication round has unit cost while a local training round has
//! cost τ. In a realistic FL system, τ is typically much less than 1, as the
//! primary bottleneck is often communication" — the paper sets τ = 0.01.

/// total = communication_rounds · 1 + local_iterations · τ
pub fn total_cost(comm_rounds: u64, local_iterations: u64, tau: f64) -> f64 {
    comm_rounds as f64 + local_iterations as f64 * tau
}

/// Expected total cost of T Scaffnew iterations at communication
/// probability p: T·p communication rounds + T local iterations · τ.
/// Used by the Fig. 8 bench to cross-check measured against expected cost.
pub fn expected_scaffnew_cost(iterations: u64, p: f64, tau: f64) -> f64 {
    iterations as f64 * p + iterations as f64 * tau
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_costs() {
        assert_eq!(total_cost(10, 100, 0.01), 10.0 + 1.0);
        assert_eq!(total_cost(0, 0, 0.5), 0.0);
    }

    #[test]
    fn smaller_p_trades_comm_for_local() {
        // Same iteration budget: p=0.05 has half the comm cost of p=0.1.
        let a = expected_scaffnew_cost(1000, 0.05, 0.01);
        let b = expected_scaffnew_cost(1000, 0.1, 0.01);
        assert!(a < b);
        assert!((b - a - 50.0).abs() < 1e-9);
    }
}
