//! Round-indexed compression schedules: `sched:topk:0.3..0.05@cosine`.
//!
//! A [`Schedule`] anneals one compressor family's strength over the run's
//! communication rounds — sparsity for `topk`/`randk`, bit width for `q` —
//! the "start dense, finish sparse" curriculum the sparse-training
//! literature uses to buy early optimization progress before clamping the
//! communication budget. The schedule is a *spec*, not state: the value at
//! round t is a pure function of (t, total_rounds), so scheduled pipelines
//! stay bit-deterministic under any worker count.
//!
//! Grammar (the part after the `sched:` prefix):
//!
//! ```text
//! <family>:<from>..<to>[@<curve>]     family ∈ {topk, randk, q}
//! ```
//!
//! `from` is the round-0 value and `to` the final-round value (either may
//! be the larger); `curve` is `linear` (default) or `cosine` (half-cosine
//! anneal). A single-round run sits at `from`.

use super::quantize::QuantizeR;
use super::topk::{RandK, TopK};
use super::{CodecMeta, Compressor};
use crate::util::rng::Rng;

/// Interpolation curve between the schedule's endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Curve {
    /// Straight-line interpolation from `from` to `to`.
    Linear,
    /// Half-cosine anneal: flat near both endpoints, steep in the middle.
    Cosine,
}

impl Curve {
    /// Parse a curve name (`linear` | `cosine`).
    pub fn parse(s: &str) -> Option<Curve> {
        match s {
            "linear" => Some(Curve::Linear),
            "cosine" => Some(Curve::Cosine),
            _ => None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Curve::Linear => "linear",
            Curve::Cosine => "cosine",
        }
    }

    /// Interpolation weight toward `to` at progress `t ∈ [0, 1]`.
    fn weight(self, t: f64) -> f64 {
        match self {
            Curve::Linear => t,
            Curve::Cosine => 0.5 * (1.0 - (std::f64::consts::PI * t).cos()),
        }
    }
}

/// The compressor family a schedule anneals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedFamily {
    /// TopK density in (0, 1].
    TopK,
    /// RandK density in (0, 1].
    RandK,
    /// Quantizer bit width in 1..=32 (rounded to the nearest integer).
    Bits,
}

/// A parsed, validated schedule (see module docs for the grammar).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Schedule {
    /// Which compressor family the scheduled value parameterizes.
    pub family: SchedFamily,
    /// Value at round 0.
    pub from: f64,
    /// Value at the final round.
    pub to: f64,
    /// Interpolation curve.
    pub curve: Curve,
}

impl Schedule {
    /// Parse the part after the `sched:` prefix, e.g. `topk:0.3..0.05@cosine`.
    pub fn parse(s: &str) -> Result<Schedule, String> {
        let (head, rest) = s
            .split_once(':')
            .ok_or_else(|| format!("bad schedule '{s}' (want <family>:<from>..<to>[@curve])"))?;
        let family = match head {
            "topk" => SchedFamily::TopK,
            "randk" => SchedFamily::RandK,
            "q" => SchedFamily::Bits,
            other => return Err(format!("unschedulable family '{other}' (have: topk, randk, q)")),
        };
        let (range, curve) = match rest.split_once('@') {
            Some((r, c)) => (
                r,
                Curve::parse(c).ok_or_else(|| format!("unknown curve '{c}' (have: linear, cosine)"))?,
            ),
            None => (rest, Curve::Linear),
        };
        let (a, b) = range
            .split_once("..")
            .ok_or_else(|| format!("bad schedule range '{range}' (want <from>..<to>)"))?;
        let parse_v = |v: &str| -> Result<f64, String> {
            v.parse::<f64>().map_err(|_| format!("bad schedule value '{v}'"))
        };
        let (from, to) = (parse_v(a)?, parse_v(b)?);
        let check = |v: f64| -> Result<(), String> {
            match family {
                SchedFamily::TopK | SchedFamily::RandK => {
                    if !(v > 0.0 && v <= 1.0) {
                        return Err(format!("density must be in (0,1], got {v}"));
                    }
                }
                SchedFamily::Bits => {
                    if !(1.0..=32.0).contains(&v) {
                        return Err(format!("quantizer bits must be in 1..=32, got {v}"));
                    }
                }
            }
            Ok(())
        };
        check(from)?;
        check(to)?;
        Ok(Schedule {
            family,
            from,
            to,
            curve,
        })
    }

    /// Canonical spec string (the parseable `sched:` suffix).
    pub fn key(&self) -> String {
        let fam = match self.family {
            SchedFamily::TopK => "topk",
            SchedFamily::RandK => "randk",
            SchedFamily::Bits => "q",
        };
        format!("sched:{fam}:{}..{}@{}", self.from, self.to, self.curve.name())
    }

    /// The scheduled value at communication round `round` of a
    /// `total_rounds`-round run: `from` at round 0, `to` at the final
    /// round, interpolated by the curve in between. A single-round run
    /// (and round indices past the end) clamp into [0, total−1].
    pub fn value_at(&self, round: usize, total_rounds: usize) -> f64 {
        let t = if total_rounds <= 1 {
            0.0
        } else {
            round.min(total_rounds - 1) as f64 / (total_rounds - 1) as f64
        };
        self.from + (self.to - self.from) * self.curve.weight(t)
    }

    /// Encode `x` with the round-`round` instantiation of the scheduled
    /// family (byte-identical to building that compressor directly).
    pub fn compress_into(
        &self,
        round: usize,
        total_rounds: usize,
        x: &[f32],
        rng: &mut Rng,
        payload: &mut Vec<u8>,
    ) -> CodecMeta {
        let v = self.value_at(round, total_rounds);
        match self.family {
            SchedFamily::TopK => TopK::with_density(v).compress_into(x, rng, payload),
            SchedFamily::RandK => RandK::with_density(v).compress_into(x, rng, payload),
            SchedFamily::Bits => {
                QuantizeR::new((v.round() as u32).clamp(1, 32)).compress_into(x, rng, payload)
            }
        }
    }

    /// Worst-case wire bits of the round-`round` instantiation.
    pub fn nominal_bits(&self, round: usize, total_rounds: usize, d: usize) -> u64 {
        let v = self.value_at(round, total_rounds);
        match self.family {
            SchedFamily::TopK => TopK::with_density(v).nominal_bits(d),
            SchedFamily::RandK => RandK::with_density(v).nominal_bits(d),
            SchedFamily::Bits => QuantizeR::new((v.round() as u32).clamp(1, 32)).nominal_bits(d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_roundtrips_key() {
        let s = Schedule::parse("topk:0.3..0.05@cosine").unwrap();
        assert_eq!(s.family, SchedFamily::TopK);
        assert_eq!((s.from, s.to), (0.3, 0.05));
        assert_eq!(s.curve, Curve::Cosine);
        assert_eq!(s.key(), "sched:topk:0.3..0.05@cosine");
        // Default curve is linear; q schedules parse too.
        let q = Schedule::parse("q:8..2").unwrap();
        assert_eq!(q.family, SchedFamily::Bits);
        assert_eq!(q.curve, Curve::Linear);
        assert_eq!(Schedule::parse("randk:0.5..0.1@linear").unwrap().family, SchedFamily::RandK);
    }

    #[test]
    fn bad_schedules_rejected() {
        for bad in [
            "topk:0.3",            // no range
            "topk:0..0.1",         // zero density
            "topk:0.3..1.5",       // density > 1
            "q:0..8",              // bits out of range
            "q:8..64",             // bits out of range
            "nat:0.1..0.2",        // unschedulable family
            "topk:0.3..0.1@step",  // unknown curve
            "topk:a..b",           // unparsable values
        ] {
            assert!(Schedule::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn boundary_values_hit_the_endpoints() {
        for curve in ["linear", "cosine"] {
            let s = Schedule::parse(&format!("topk:0.3..0.05@{curve}")).unwrap();
            for total in [1usize, 2, 7, 100] {
                assert_eq!(s.value_at(0, total), 0.3, "{curve} T={total}");
                if total > 1 {
                    let last = s.value_at(total - 1, total);
                    assert!((last - 0.05).abs() < 1e-12, "{curve} T={total}: {last}");
                    // Past-the-end rounds clamp to the final value.
                    assert_eq!(s.value_at(total + 5, total), last);
                }
            }
            // Single-round run sits at `from`.
            assert_eq!(s.value_at(0, 1), 0.3);
            assert_eq!(s.value_at(3, 1), 0.3);
        }
    }

    #[test]
    fn schedules_are_monotone_between_endpoints() {
        for curve in [Curve::Linear, Curve::Cosine] {
            let s = Schedule {
                family: SchedFamily::TopK,
                from: 0.3,
                to: 0.05,
                curve,
            };
            let total = 50;
            let vals: Vec<f64> = (0..total).map(|r| s.value_at(r, total)).collect();
            assert!(
                vals.windows(2).all(|w| w[1] <= w[0] + 1e-12),
                "{curve:?} not non-increasing"
            );
            assert!(vals.iter().all(|&v| (0.05..=0.3).contains(&v)));
        }
    }

    #[test]
    fn scheduled_encode_matches_direct_compressor() {
        use crate::util::rng::Rng;
        let x: Vec<f32> = (0..500).map(|i| ((i as f32) * 0.11).sin()).collect();
        let s = Schedule::parse("topk:0.3..0.1@linear").unwrap();
        let total = 5;
        for round in [0usize, 2, 4] {
            let mut payload = Vec::new();
            let mut rng = Rng::seed_from_u64(7);
            let meta = s.compress_into(round, total, &x, &mut rng, &mut payload);
            let direct = TopK::with_density(s.value_at(round, total))
                .compress(&x, &mut Rng::seed_from_u64(7));
            assert_eq!(payload, direct.payload, "round {round}");
            assert_eq!(meta.wire_bits, direct.wire_bits);
            assert!(meta.wire_bits <= s.nominal_bits(round, total, x.len()));
        }
        // An annealing q schedule changes the wire cost over rounds.
        let q = Schedule::parse("q:16..2@linear").unwrap();
        let mut rng = Rng::seed_from_u64(9);
        let mut p0 = Vec::new();
        let mut p9 = Vec::new();
        let m0 = q.compress_into(0, 10, &x, &mut rng, &mut p0);
        let m9 = q.compress_into(9, 10, &x, &mut rng, &mut p9);
        assert!(m9.wire_bits < m0.wire_bits);
    }
}
