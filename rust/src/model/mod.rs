//! Model definitions and the trainer abstraction.
//!
//! The paper's two models (Appendix A.1) are expressed over a single flat
//! f32 parameter vector so the coordinator, compressors and transport treat
//! model state uniformly:
//!
//! * **MLP** for FedMNIST — 784 → 128 → 64 → 10, ReLU (d = 109,386);
//! * **CNN** for FedCIFAR10 — conv5×5(3→32) → pool → conv5×5(32→64) → pool →
//!   fc 1600→384 → fc 384→192 → fc 192→10, ReLU (d = 744,330), the FedLab
//!   reference architecture.
//!
//! Two interchangeable [`LocalTrainer`] implementations execute the local
//! objective: [`native::NativeTrainer`] (pure Rust, in `ops.rs`) and
//! `runtime::PjrtTrainer` (AOT-compiled HLO from the JAX/Pallas layers).
//! The parameter memory layout is identical across both — it is pinned down
//! in `python/compile/models/` and cross-checked by integration tests.

pub mod cnn;
pub mod mlp;
pub mod native;
pub mod ops;

use crate::data::loader::{Batch, EvalBatches};
use crate::data::DatasetKind;
use crate::util::rng::Rng;

/// Which architecture a flat parameter vector parameterizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Cnn,
}

impl ModelKind {
    /// The paper pairs MLP↔FedMNIST and CNN↔FedCIFAR10.
    pub fn for_dataset(d: DatasetKind) -> ModelKind {
        match d {
            DatasetKind::Mnist => ModelKind::Mlp,
            DatasetKind::Cifar10 => ModelKind::Cnn,
        }
    }

    /// Total parameter count d.
    pub fn dim(self) -> usize {
        match self {
            ModelKind::Mlp => mlp::DIM,
            ModelKind::Cnn => cnn::DIM,
        }
    }

    pub fn input_dim(self) -> usize {
        match self {
            ModelKind::Mlp => 784,
            ModelKind::Cnn => 3 * 32 * 32,
        }
    }

    pub fn num_classes(self) -> usize {
        10
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Cnn => "cnn",
        }
    }
}

/// He-normal weight init, zero biases — shared by both trainers so every
/// algorithm starts from the identical x₀ given the same seed.
pub fn init_params(kind: ModelKind, rng: &mut Rng) -> Vec<f32> {
    match kind {
        ModelKind::Mlp => mlp::init(rng),
        ModelKind::Cnn => cnn::init(rng),
    }
}

/// Evaluation result over a test set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub mean_loss: f64,
    pub accuracy: f64,
    pub examples: usize,
}

/// Executes the local objective: gradients, fused Scaffnew steps, and
/// evaluation. Implementations must be deterministic given their inputs.
pub trait LocalTrainer: Send + Sync {
    fn model(&self) -> ModelKind;

    fn dim(&self) -> usize {
        self.model().dim()
    }

    /// Minibatch gradient of the local empirical loss at `params`.
    /// Returns (∇f(params), loss).
    fn grad(&self, params: &[f32], batch: &Batch) -> (Vec<f32>, f32);

    /// Fused Scaffnew local step (Algorithm 1 line 7):
    /// x̂ = params − γ·(∇f(params) − h). Returns (x̂, loss).
    fn train_step(&self, params: &[f32], h: &[f32], batch: &Batch, gamma: f32) -> (Vec<f32>, f32) {
        let (g, loss) = self.grad(params, batch);
        let mut out = vec![0.0f32; params.len()];
        crate::tensor::sgd_control_variate_step(params, &g, h, gamma, &mut out);
        (out, loss)
    }

    /// FedComLoc-Local step (Algorithm 1 line 6½): the gradient is evaluated
    /// at the TopK-masked parameters, g = ∇f(TopK_{density}(params)), while
    /// the update is applied to the *unmasked* params.
    fn train_step_masked(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
    ) -> (Vec<f32>, f32) {
        let mut masked = params.to_vec();
        let k = ((density * params.len() as f64).ceil() as usize).clamp(1, params.len());
        crate::compress::topk::apply_topk(&mut masked, k);
        let (g, loss) = self.grad(&masked, batch);
        let mut out = vec![0.0f32; params.len()];
        crate::tensor::sgd_control_variate_step(params, &g, h, gamma, &mut out);
        (out, loss)
    }

    /// Mean loss + accuracy over an evaluation set.
    fn eval(&self, params: &[f32], batches: &EvalBatches) -> EvalResult;
}

/// Shared eval loop used by trainers that expose per-batch (loss_sum,
/// correct) primitives.
pub(crate) fn eval_with<F>(batches: &EvalBatches, mut eval_batch: F) -> EvalResult
where
    F: FnMut(&Batch, usize) -> (f64, usize),
{
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut examples = 0usize;
    for (batch, &valid) in batches.batches.iter().zip(&batches.valid) {
        let (l, c) = eval_batch(batch, valid);
        loss_sum += l;
        correct += c;
        examples += valid;
    }
    EvalResult {
        mean_loss: loss_sum / examples.max(1) as f64,
        accuracy: correct as f64 / examples.max(1) as f64,
        examples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_paper_appendix_a() {
        // MLP 784->128->64->10
        assert_eq!(
            ModelKind::Mlp.dim(),
            784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10
        );
        assert_eq!(ModelKind::Mlp.dim(), 109_386);
        // CNN conv(3->32,5), conv(32->64,5), fc 1600->384->192->10
        assert_eq!(
            ModelKind::Cnn.dim(),
            32 * 3 * 25 + 32 + 64 * 32 * 25 + 64 + 1600 * 384 + 384 + 384 * 192 + 192 + 192 * 10 + 10
        );
        assert_eq!(ModelKind::Cnn.dim(), 744_330);
    }

    #[test]
    fn init_is_seeded_and_scaled() {
        let mut r1 = Rng::seed_from_u64(1);
        let mut r2 = Rng::seed_from_u64(1);
        let a = init_params(ModelKind::Mlp, &mut r1);
        let b = init_params(ModelKind::Mlp, &mut r2);
        assert_eq!(a, b);
        assert_eq!(a.len(), ModelKind::Mlp.dim());
        // He init: first-layer std ≈ sqrt(2/784) ≈ 0.0505
        let w1 = &a[..784 * 128];
        let std = (crate::tensor::norm2_sq(w1) / w1.len() as f64).sqrt();
        assert!((std - (2.0 / 784.0f64).sqrt()).abs() < 0.005, "std={std}");
        // biases zero
        assert!(a[784 * 128..784 * 128 + 128].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn model_for_dataset() {
        assert_eq!(ModelKind::for_dataset(DatasetKind::Mnist), ModelKind::Mlp);
        assert_eq!(ModelKind::for_dataset(DatasetKind::Cifar10), ModelKind::Cnn);
    }
}
