"""L1 Pallas kernel: stochastic quantizer Q_r (paper Definition 3.2).

Per coordinate: level = ⌊2^r·y⌋ + 1[u < frac] with y = |x_i|/‖x‖₂, output
‖x‖₂·sgn(x_i)·level/2^r. The rounding uniforms u are an explicit input —
randomness is externalized so (a) the kernel is a pure function checkable
against `ref.quantize_ref`, and (b) the Rust coordinator can drive the same
stochastic rounding it uses for the wire codec. The global ‖x‖₂ reduction is
computed once in jnp; Pallas owns the elementwise quantization stream.
"""

import jax.numpy as jnp

from . import common


def _quant_kernel(x_ref, u_ref, norm_ref, s_ref, o_ref):
    norm = norm_ref[0, 0]
    s = s_ref[0, 0]
    x = x_ref[...]
    safe = jnp.where(norm > 0, norm, jnp.float32(1.0))
    scaled = jnp.abs(x) / safe * s
    lo = jnp.floor(scaled)
    frac = scaled - lo
    level = lo + (u_ref[...] < frac).astype(jnp.float32)
    q = norm * jnp.sign(x) * level / s
    o_ref[...] = jnp.where(norm > 0, q, jnp.zeros_like(x))


def quantize(x, u, bits):
    """Q_r(x) with rounding uniforms u ∈ [0,1); bits may be traced."""
    assert x.shape == u.shape and x.ndim == 1
    x = x.astype(jnp.float32)
    s = jnp.float32(2.0) ** jnp.asarray(bits, jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    return common.elementwise_call(
        _quant_kernel, jnp.float32, x, u.astype(jnp.float32), scalars=(norm, s)
    )
