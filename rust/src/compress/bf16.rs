//! bf16 storage codec: deterministic round-to-nearest-even truncation to
//! bfloat16, 16 bits per coordinate on the wire.
//!
//! Unlike the stochastic quantizer Q_r this operator is *deterministic*
//! (and therefore biased): every coordinate is independently rounded to the
//! nearest bfloat16 (ties to even) and shipped as its 16-bit pattern. It is
//! the wire twin of the `native-bf16` backend's activation storage
//! ([`crate::backend::bf16`]) — a run that stores activations in bf16 can
//! ship its payloads in the same precision, halving dense wire cost with a
//! bounded relative error of [`crate::backend::bf16::BF16_EPS`] per
//! coordinate. Exact wire format: `2·dim` little-endian `u16` bf16
//! patterns, no header.

use super::{CodecMeta, Codec, Compressed, Compressor};
use crate::backend::bf16::{bf16_to_f32, f32_to_bf16, round_slice_bf16};
use crate::util::rng::Rng;

/// Deterministic bf16 truncation codec (`bf16` in the registry).
#[derive(Debug, Clone, Copy, Default)]
pub struct Bf16C;

impl Compressor for Bf16C {
    fn name(&self) -> String {
        "bf16".to_string()
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Rng, payload: &mut Vec<u8>) -> CodecMeta {
        payload.clear();
        payload.reserve(2 * x.len());
        for &v in x {
            payload.extend_from_slice(&f32_to_bf16(v).to_le_bytes());
        }
        CodecMeta {
            wire_bits: 16 * x.len() as u64,
            dim: x.len(),
            codec: Codec::Bf16,
        }
    }

    fn decompress(&self, c: &Compressed) -> Vec<f32> {
        super::decode_payload(c.codec, c.dim, &c.payload)
    }

    fn apply(&self, x: &mut [f32], _rng: &mut Rng) {
        // Semantically identical to the codec round-trip, without touching
        // any bytes — bf16 rounding is idempotent and elementwise.
        round_slice_bf16(x);
    }

    fn nominal_bits(&self, d: usize) -> u64 {
        16 * d as u64
    }
}

/// Decode a bf16 payload (`2·dim` LE bytes) into `out` (length `dim`).
pub(super) fn decode_bf16_into(dim: usize, payload: &[u8], out: &mut [f32]) {
    debug_assert_eq!(payload.len(), 2 * dim);
    for (o, pair) in out.iter_mut().zip(payload.chunks_exact(2)) {
        *o = bf16_to_f32(u16::from_le_bytes([pair[0], pair[1]]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::bf16::BF16_EPS;

    fn sample(d: usize) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(21);
        (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn wire_is_exactly_two_bytes_per_coordinate() {
        let x = sample(257);
        let mut rng = Rng::seed_from_u64(0);
        let c = Bf16C.compress(&x, &mut rng);
        assert_eq!(c.payload.len(), 2 * x.len());
        assert_eq!(c.wire_bits, 16 * x.len() as u64);
        assert_eq!(c.dim, x.len());
        assert_eq!(Bf16C.nominal_bits(x.len()), c.wire_bits);
    }

    #[test]
    fn roundtrip_matches_apply_and_bounds_relative_error() {
        let x = sample(400);
        let mut rng = Rng::seed_from_u64(0);
        let c = Bf16C.compress(&x, &mut rng);
        let y = Bf16C.decompress(&c);
        let mut applied = x.clone();
        Bf16C.apply(&mut applied, &mut rng);
        assert_eq!(y, applied, "codec roundtrip must equal in-place apply");
        for (yi, xi) in y.iter().zip(&x) {
            assert!((yi - xi).abs() <= BF16_EPS * xi.abs(), "{yi} vs {xi}");
        }
    }

    #[test]
    fn deterministic_and_rng_free() {
        let x = sample(64);
        let mut rng = Rng::seed_from_u64(7);
        let a = Bf16C.compress(&x, &mut rng);
        let b = Bf16C.compress(&x, &mut rng);
        assert_eq!(a.payload, b.payload);
        // No randomness consumed.
        let mut fresh = Rng::seed_from_u64(7);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn exactly_representable_values_pass_through() {
        let x = vec![0.0f32, -0.0, 1.0, -2.5, 0.15625, f32::INFINITY];
        let mut rng = Rng::seed_from_u64(0);
        let c = Bf16C.compress(&x, &mut rng);
        assert_eq!(Bf16C.decompress(&c), x);
    }
}
