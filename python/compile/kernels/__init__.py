"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

Modules:
  dense     — fused y = act(x @ W + b) MXU-blocked matmul
  sgd_cv    — fused Scaffnew step x − γ(g − h)
  topk      — TopK threshold-mask (Definition 3.1)
  quantize  — stochastic quantizer Q_r (Definition 3.2)
  ref       — pure-jnp oracles for all of the above
  common    — shared tiling/BlockSpec plumbing

All kernels lower with interpret=True (CPU-PJRT compatible HLO); see
DESIGN.md §Hardware-Adaptation.
"""

from . import common, dense, quantize, ref, sgd_cv, topk  # noqa: F401
