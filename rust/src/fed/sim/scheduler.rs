//! The semi-synchronous round scheduler: a [`Transport`] decorator that
//! places every message on a virtual clock and splits each round's
//! participants into first-K **accepted** clients and **stragglers** whose
//! updates land staleness-weighted in a later round (FedBuff-style
//! buffered aggregation).
//!
//! # Event model
//!
//! Per client `c`, one round is three virtual-time intervals:
//!
//! ```text
//! t_down(c)  = Σ broadcast link_secs      (queried from the inner transport)
//! compute(c) = local_steps · τ · speed_c  (speed_c: per-client multiplier)
//! t_up(c)    = Σ uplink link_secs
//! ```
//!
//! The per-client compute-speed multipliers `speed_c` are log-uniform on
//! `[1, SPEED_SPREAD]`, *derived on demand* from the run seed (salt
//! [`SPEED_SALT`]) and the client **id** via the pure [`Rng::derive`]
//! label mix — the compute twin of [`SimNetCfg`]'s bandwidth
//! heterogeneity, an independent RNG stream from every training/transport
//! stream (so enabling a scenario never perturbs training randomness),
//! and O(1) memory regardless of population size (so a million-client
//! federation never materializes a speed table).
//!
//! Acceptance is decided once per round on the deterministic
//! [`EventQueue`]: clients ranked by ready-to-upload deadline
//! `t_down(c) + n₀·τ·speed_c` (n₀ = `cfg.local_steps`, the *nominal*
//! segment length — exact for the fixed-step drivers, and for FedComLoc's
//! geometric segments the per-round segment length is shared by all
//! clients, so scaling it never reorders the deadlines), ties broken by
//! delivery order. The first K pop as accepted; the round completes — and
//! `sim_secs` is measured — at the slowest *accepted* client's arrival,
//! computed from the actual step count. Stragglers' uplinks are decoded
//! into additive deltas (per the algorithm's
//! [`UplinkKind`](crate::fed::algorithm::UplinkKind)), buffered, and
//! folded by [`ScenarioNet::fold_arrivals`] once the virtual clock passes
//! their arrival, weighted `(1+s)^(−α) / K_origin` at staleness `s`
//! rounds.
//!
//! # Dropout vs churn: one owner each
//!
//! Round-level *unavailability* is owned by the inner transport and its
//! single RNG stream ([`SimNetCfg::drop_prob`]): a client the inner
//! transport drops is never delivered to, never scheduled, and never
//! buffered here — so it is counted exactly once, in the inner transport's
//! `dropped_clients`. The scheduler draws **no** second availability coin.
//! *Churn* is this layer's own, RNG-free notion: an in-flight straggler
//! update is discarded when its client is re-sampled into a newer round
//! before arrival (the fresh model supersedes the stale work), counted in
//! `churned_clients`.
//!
//! [`SimNetCfg`]: crate::fed::transport::SimNetCfg
//! [`SimNetCfg::drop_prob`]: crate::fed::transport::SimNetCfg::drop_prob

use super::queue::EventQueue;
use crate::fed::algorithm::UplinkKind;
use crate::fed::message::Message;
use crate::fed::transport::{LinkReport, Transport};
use crate::fed::RunConfig;
use crate::util::rng::Rng;
use std::collections::{HashMap, HashSet};

/// Salt deriving the per-client compute-speed stream from `cfg.seed`
/// (distinct from every transport/training salt in the tree).
pub const SPEED_SALT: u64 = 0x5C_ED01;

/// Log-uniform spread of the per-client compute-speed multipliers: the
/// slowest client computes up to this factor slower than the fastest —
/// mirroring [`crate::fed::transport::SimNetCfg`]'s default bandwidth
/// heterogeneity of 4×.
pub const SPEED_SPREAD: f64 = 4.0;

/// One buffered straggler update awaiting its virtual-time arrival.
struct Pending {
    client: usize,
    origin_round: usize,
    /// Absolute virtual-clock arrival time at the server.
    arrival: f64,
    /// Accepted-set size of the origin round (the mean divisor the
    /// algorithm applied that round — the stale fold uses the same one).
    k_origin: usize,
    delta: Vec<f32>,
}

/// The scheduling [`Transport`] decorator (see module docs). Built per run
/// by [`super::drive_scenario`]; all scheduling state lives here, on the
/// coordinator thread, so results are byte-invariant to `--threads`.
pub struct ScenarioNet<'a> {
    inner: &'a mut dyn Transport,
    k: usize,
    staleness: f64,
    kind: UplinkKind,
    tau: f64,
    nominal_steps: usize,
    /// Root of the per-client compute-speed streams; client `c`'s
    /// multiplier is a pure function of this root and `c` (see
    /// [`ScenarioNet::speed`]), so no per-client table is ever built.
    speed_rng: Rng,
    /// Test hook: pin exact per-client speeds for hand-computed schedules.
    #[cfg(test)]
    speed_override: Option<Vec<f64>>,
    /// The virtual clock: absolute start time of the current round.
    now: f64,
    round: usize,
    // --- per-round state, reset by `begin_round` ---
    delivered_order: Vec<usize>,
    t_down: HashMap<usize, f64>,
    up_secs: HashMap<usize, f64>,
    accepted: Vec<usize>,
    decided: bool,
    bcast_x: Option<Vec<f32>>,
    staged: Vec<(usize, Vec<f32>)>,
    straggler_streams: HashSet<usize>,
    actual_steps: Option<usize>,
    stale_this_round: u64,
    churned_this_round: u64,
    // --- cross-round state ---
    pending: Vec<Pending>,
}

impl<'a> ScenarioNet<'a> {
    /// Wrap `inner` in a semi-synchronous scheduler accepting the first
    /// `k` arrivals per round, weighting stragglers by `(1+s)^(−staleness)`.
    pub fn new(
        inner: &'a mut dyn Transport,
        k: usize,
        staleness: f64,
        kind: UplinkKind,
        cfg: &RunConfig,
    ) -> ScenarioNet<'a> {
        assert!(k >= 1, "semisync K must be >= 1");
        ScenarioNet {
            inner,
            k,
            staleness,
            kind,
            tau: cfg.tau,
            nominal_steps: cfg.local_steps.max(1),
            speed_rng: Rng::seed_from_u64(cfg.seed ^ SPEED_SALT),
            #[cfg(test)]
            speed_override: None,
            now: 0.0,
            round: 0,
            delivered_order: Vec::new(),
            t_down: HashMap::new(),
            up_secs: HashMap::new(),
            accepted: Vec::new(),
            decided: false,
            bcast_x: None,
            staged: Vec::new(),
            straggler_streams: HashSet::new(),
            actual_steps: None,
            stale_this_round: 0,
            churned_this_round: 0,
            pending: Vec::new(),
        }
    }

    /// Client `c`'s compute-speed multiplier, log-uniform on
    /// `[1, SPEED_SPREAD]` — a pure function of the run seed and `c`
    /// (identical whether queried once, repeatedly, or never), so a
    /// million-client population costs nothing until a client is actually
    /// scheduled.
    fn speed(&self, client: usize) -> f64 {
        #[cfg(test)]
        if let Some(ov) = &self.speed_override {
            return ov[client];
        }
        let mut stream = self.speed_rng.derive(client as u64);
        (stream.uniform() * SPEED_SPREAD.ln()).exp()
    }

    fn compute_secs(&self, client: usize, steps: usize) -> f64 {
        steps as f64 * self.tau * self.speed(client)
    }

    /// Fold every buffered straggler update whose arrival time the virtual
    /// clock has passed into the global model `x`, weighted
    /// `(1+s)^(−α) / K_origin` (s = `round` − origin round). Call at round
    /// start, *before* sampling. Sets this round's `stale_updates` count.
    pub fn fold_arrivals(&mut self, round: usize, x: &mut [f32]) {
        let now = self.now;
        let mut folded = 0u64;
        let mut keep = Vec::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            if p.arrival <= now {
                let s = (round - p.origin_round) as f64;
                let w = ((1.0 + s).powf(-self.staleness) / p.k_origin as f64) as f32;
                crate::tensor::axpy(w, &p.delta, x);
                folded += 1;
            } else {
                keep.push(p);
            }
        }
        self.pending = keep;
        self.stale_this_round = folded;
    }

    /// Start round `round` with participant set `sampled`: discard
    /// in-flight updates from re-sampled clients (churn — the fresh model
    /// supersedes their stale work) and reset per-round scheduling state.
    pub fn begin_round(&mut self, round: usize, sampled: &[usize]) {
        let before = self.pending.len();
        self.pending.retain(|p| !sampled.contains(&p.client));
        self.churned_this_round = (before - self.pending.len()) as u64;
        self.round = round;
        self.delivered_order.clear();
        self.t_down.clear();
        self.up_secs.clear();
        self.accepted.clear();
        self.decided = false;
        self.bcast_x = None;
        self.staged.clear();
        self.straggler_streams.clear();
        self.actual_steps = None;
    }

    /// Record the actual local-step count the algorithm ran this round
    /// (FedComLoc's geometric segments differ from the nominal). Call
    /// between the algorithm's round and [`Transport::end_round`]; arrival
    /// times and `sim_secs` use it.
    pub fn note_local_steps(&mut self, steps: usize) {
        self.actual_steps = Some(steps.max(1));
    }

    /// Buffered straggler updates currently in flight (for tests/driver
    /// diagnostics).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Rank this round's delivered clients by ready-to-upload deadline on
    /// the event queue and accept the first K (see module docs). Decided
    /// lazily at the first uplink, after every broadcast has landed.
    fn decide_accept(&mut self) {
        self.decided = true;
        let mut queue = EventQueue::new();
        for &c in &self.delivered_order {
            queue.push(self.t_down[&c] + self.compute_secs(c, self.nominal_steps), c);
        }
        let k = self.k.min(queue.len());
        self.accepted = (0..k).filter_map(|_| queue.pop().map(|(_, c)| c)).collect();
    }
}

impl Transport for ScenarioNet<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn broadcast(&mut self, clients: &[usize], msg: &Message) -> Vec<usize> {
        let delivered = self.inner.broadcast(clients, msg);
        let bits = msg.wire_bits();
        for &c in &delivered {
            let secs = self.inner.link_secs(c, bits);
            match self.t_down.entry(c) {
                // A later broadcast stream (Scaffold's c after x) extends
                // the client's downlink completion time.
                std::collections::hash_map::Entry::Occupied(mut e) => *e.get_mut() += secs,
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(self.now + secs);
                    self.delivered_order.push(c);
                }
            }
        }
        // Retain the first decoded broadcast: the base a Model-kind
        // straggler's delta is taken against.
        if self.kind == UplinkKind::Model && self.bcast_x.is_none() {
            self.bcast_x = Some(msg.to_dense());
        }
        delivered
    }

    fn uplink(&mut self, client: usize, msg: Message) -> Option<Message> {
        if !self.decided {
            self.decide_accept();
        }
        let bits = msg.wire_bits();
        let received = self.inner.uplink(client, msg)?;
        *self.up_secs.entry(client).or_insert(0.0) += self.inner.link_secs(client, bits);
        if self.accepted.contains(&client) {
            return Some(received);
        }
        // Straggler: buffer the first stream as an additive delta; any
        // further stream this round (Scaffold's Δc) is transmitted — and
        // billed — but its server-side effect is forfeited, like a
        // dropped client's.
        if self.straggler_streams.insert(client) {
            let delta = match self.kind {
                UplinkKind::Delta => received.to_dense(),
                UplinkKind::Model => {
                    let mut d = received.to_dense();
                    let base = self
                        .bcast_x
                        .as_ref()
                        .expect("Model-kind uplink before any broadcast this round");
                    for (dj, bj) in d.iter_mut().zip(base) {
                        *dj -= bj;
                    }
                    d
                }
            };
            self.staged.push((client, delta));
        }
        None
    }

    fn end_round(&mut self) -> LinkReport {
        let steps = self.actual_steps.unwrap_or(self.nominal_steps);
        // The round completes when the slowest accepted arrival lands.
        let mut done = self.now;
        for &c in &self.accepted {
            let arrival = self.t_down[&c]
                + self.compute_secs(c, steps)
                + self.up_secs.get(&c).copied().unwrap_or(0.0);
            done = done.max(arrival);
        }
        let k_origin = self.accepted.len().max(1);
        let origin_round = self.round;
        for (c, delta) in self.staged.drain(..) {
            let arrival = self.t_down[&c]
                + self.compute_secs(c, steps)
                + self.up_secs.get(&c).copied().unwrap_or(0.0);
            self.pending.push(Pending {
                client: c,
                origin_round,
                arrival,
                k_origin,
                delta,
            });
        }
        let mut sim_secs = done - self.now;
        self.now = done;
        let inner = self.inner.end_round();
        // A wrapped fault plane spends extra simulated time in retransmit
        // backoff and outages; that time belongs to the round's clock too.
        sim_secs += inner.backoff_secs;
        self.now += inner.backoff_secs;
        LinkReport {
            usage: inner.usage,
            sim_secs,
            // Unavailability is counted exactly once, by the layer that
            // owns it (the inner transport); churn is this layer's.
            dropped_clients: inner.dropped_clients,
            stale_updates: self.stale_this_round,
            churned_clients: self.churned_this_round,
            corrupt_frames: inner.corrupt_frames,
            retransmits: inner.retransmits,
            dup_frames: inner.dup_frames,
            backoff_secs: inner.backoff_secs,
            aborted: inner.aborted,
        }
    }

    fn link_secs(&self, client: usize, bits: u64) -> f64 {
        self.inner.link_secs(client, bits)
    }

    fn save_state(&self) -> Vec<u8> {
        // Cross-round state at a round boundary: the virtual clock plus the
        // in-flight straggler buffer (`speed` is re-drawn from cfg.seed at
        // construction; all per-round fields are empty between rounds). The
        // inner transport's section is nested length-prefixed so one opaque
        // blob round-trips the whole decorator stack.
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_f64(self.now);
        w.put_u64(self.pending.len() as u64);
        for p in &self.pending {
            w.put_u64(p.client as u64);
            w.put_u64(p.origin_round as u64);
            w.put_f64(p.arrival);
            w.put_u64(p.k_origin as u64);
            w.put_f32s(&p.delta);
        }
        w.put_bytes(&self.inner.save_state());
        w.into_bytes()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::bytes::ByteReader::new(bytes, "scenario state");
        self.now = r.take_f64()?;
        let n = r.take_u64()? as usize;
        self.pending.clear();
        for _ in 0..n {
            let client = r.take_u64()? as usize;
            let origin_round = r.take_u64()? as usize;
            let arrival = r.take_f64()?;
            let k_origin = r.take_u64()? as usize;
            let delta = r.take_f32s()?;
            self.pending.push(Pending {
                client,
                origin_round,
                arrival,
                k_origin,
                delta,
            });
        }
        let inner = r.take_bytes()?;
        r.finish()?;
        self.inner.restore_state(&inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::message::SERVER;
    use crate::fed::transport::InProc;

    /// A 3-client, K=1 schedule computed by hand: InProc links (zero link
    /// time), unit τ, one local step, speeds {1, 2, 4}, staleness α = 1.
    ///
    /// Round 0 at t=0: deadlines {c0: 1, c1: 2, c2: 4} ⇒ c0 accepted; the
    /// round completes at t=1 (sim_secs = 1); c1/c2 buffer deltas arriving
    /// at t=2 and t=4 with K_origin = 1. Round 1 ends at t=2. At round 2,
    /// c1's update (arrival 2 ≤ clock 2) folds with weight
    /// (1+2)^(−1)/1 = 1/3; c2 is re-sampled and churns.
    #[test]
    fn hand_computed_three_client_schedule() {
        let cfg = RunConfig {
            n_clients: 3,
            clients_per_round: 1,
            local_steps: 1,
            tau: 1.0,
            ..RunConfig::default_mnist()
        };
        let mut inner = InProc::default();
        let mut net = ScenarioNet::new(&mut inner, 1, 1.0, UplinkKind::Model, &cfg);
        net.speed_override = Some(vec![1.0, 2.0, 4.0]);
        let mut x = vec![10.0f32];

        // ---- round 0: broadcast x=10, clients reply 11/12/13 ----
        net.fold_arrivals(0, &mut x);
        net.begin_round(0, &[0, 1, 2]);
        let bcast = Message::dense(0, SERVER, &x);
        assert_eq!(net.broadcast(&[0, 1, 2], &bcast), vec![0, 1, 2]);
        assert!(net.uplink(0, Message::dense(0, 0, &[11.0])).is_some(), "c0 accepted");
        assert!(net.uplink(1, Message::dense(0, 1, &[12.0])).is_none(), "c1 straggles");
        assert!(net.uplink(2, Message::dense(0, 2, &[13.0])).is_none(), "c2 straggles");
        net.note_local_steps(1);
        let r0 = net.end_round();
        assert!((r0.sim_secs - 1.0).abs() < 1e-12, "{}", r0.sim_secs);
        assert_eq!((r0.stale_updates, r0.churned_clients), (0, 0));
        assert_eq!(net.pending_len(), 2);
        assert!((net.pending[0].arrival - 2.0).abs() < 1e-12);
        assert!((net.pending[1].arrival - 4.0).abs() < 1e-12);
        assert_eq!(net.pending[0].k_origin, 1);
        // Model-kind deltas are taken against the broadcast base.
        assert_eq!(net.pending[0].delta, vec![2.0]);
        assert_eq!(net.pending[1].delta, vec![3.0]);
        x = vec![11.0]; // the algorithm would aggregate the accepted set

        // ---- round 1: only c0 sampled; nothing has arrived yet ----
        net.fold_arrivals(1, &mut x);
        assert_eq!(net.pending_len(), 2, "arrivals at t=2,4 > clock t=1");
        net.begin_round(1, &[0]);
        let bcast = Message::dense(1, SERVER, &x);
        net.broadcast(&[0], &bcast);
        assert!(net.uplink(0, Message::dense(1, 0, &[11.5])).is_some());
        net.note_local_steps(1);
        let r1 = net.end_round();
        assert!((r1.sim_secs - 1.0).abs() < 1e-12, "clock 1 -> 2");
        assert_eq!((r1.stale_updates, r1.churned_clients), (0, 0));

        // ---- round 2: c1's update folds at weight 1/3; c2 churns ----
        net.fold_arrivals(2, &mut x);
        let w = (3.0f64.powf(-1.0) as f32) * 2.0; // (1+2)^(-1)/1 · Δ
        assert!((x[0] - (11.0 + w)).abs() < 1e-6, "{}", x[0]);
        net.begin_round(2, &[2]);
        assert_eq!(net.pending_len(), 0, "c2 re-sampled before arrival");
        let bcast = Message::dense(2, SERVER, &x);
        net.broadcast(&[2], &bcast);
        assert!(net.uplink(2, Message::dense(2, 2, &[14.0])).is_some(), "K=1 of 1");
        net.note_local_steps(1);
        let r2 = net.end_round();
        assert_eq!((r2.stale_updates, r2.churned_clients), (1, 1));
        assert!((r2.sim_secs - 4.0).abs() < 1e-12, "c2: 1 step x 4.0 speed from t=2");
    }

    #[test]
    fn degenerate_k_accepts_everyone() {
        let cfg = RunConfig {
            n_clients: 4,
            local_steps: 2,
            tau: 0.5,
            ..RunConfig::default_mnist()
        };
        let mut inner = InProc::default();
        let mut net = ScenarioNet::new(&mut inner, 4, 0.5, UplinkKind::Model, &cfg);
        net.begin_round(0, &[0, 1, 2, 3]);
        let bcast = Message::dense(0, SERVER, &[1.0, 2.0]);
        net.broadcast(&[0, 1, 2, 3], &bcast);
        for c in 0..4usize {
            assert!(
                net.uplink(c, Message::dense(0, c as u32, &[0.0, 0.0])).is_some(),
                "K = |S_r|: every delivered uplink is accepted"
            );
        }
        net.note_local_steps(2);
        let r = net.end_round();
        assert_eq!(r.stale_updates, 0);
        assert_eq!(net.pending_len(), 0);
        // sim_secs = slowest accepted compute: 2 steps x 0.5 tau x max speed.
        let max_speed = (0..4).map(|c| net.speed(c)).fold(0.0f64, f64::max);
        assert!((r.sim_secs - max_speed).abs() < 1e-12);
    }

    #[test]
    fn scheduler_state_roundtrips_clock_and_pending() {
        let cfg = RunConfig {
            n_clients: 3,
            clients_per_round: 1,
            local_steps: 1,
            tau: 1.0,
            ..RunConfig::default_mnist()
        };
        let mut inner = InProc::default();
        let mut net = ScenarioNet::new(&mut inner, 1, 1.0, UplinkKind::Model, &cfg);
        net.speed_override = Some(vec![1.0, 2.0, 4.0]);
        let mut x = vec![10.0f32];
        net.fold_arrivals(0, &mut x);
        net.begin_round(0, &[0, 1, 2]);
        let bcast = Message::dense(0, SERVER, &x);
        net.broadcast(&[0, 1, 2], &bcast);
        net.uplink(0, Message::dense(0, 0, &[11.0]));
        net.uplink(1, Message::dense(0, 1, &[12.0]));
        net.uplink(2, Message::dense(0, 2, &[13.0]));
        net.note_local_steps(1);
        net.end_round();
        let state = net.save_state();

        // Restore onto a freshly constructed decorator of the same spec.
        let mut inner2 = InProc::default();
        let mut net2 = ScenarioNet::new(&mut inner2, 1, 1.0, UplinkKind::Model, &cfg);
        net2.speed_override = Some(vec![1.0, 2.0, 4.0]);
        net2.restore_state(&state).unwrap();
        assert_eq!(net2.now, net.now);
        assert_eq!(net2.pending_len(), 2);
        assert_eq!(net2.pending[0].delta, vec![2.0]);
        assert_eq!(net2.pending[1].arrival, net.pending[1].arrival);
        assert_eq!(net2.pending[0].k_origin, 1);

        // Truncated state errors cleanly instead of panicking.
        assert!(net2.restore_state(&state[..state.len() - 3]).is_err());
    }

    #[test]
    fn speeds_are_seeded_log_uniform_and_deterministic() {
        let cfg = RunConfig {
            n_clients: 200,
            ..RunConfig::default_mnist()
        };
        let mut a = InProc::default();
        let mut b = InProc::default();
        let na = ScenarioNet::new(&mut a, 1, 0.5, UplinkKind::Model, &cfg);
        let nb = ScenarioNet::new(&mut b, 1, 0.5, UplinkKind::Model, &cfg);
        let speeds_a: Vec<f64> = (0..200).map(|c| na.speed(c)).collect();
        let speeds_b: Vec<f64> = (0..200).map(|c| nb.speed(c)).collect();
        assert_eq!(speeds_a, speeds_b, "same seed, same speeds");
        // Pure per-id derivation: repeated queries agree, in any order.
        assert_eq!(na.speed(137), na.speed(137));
        assert_eq!(na.speed(0), speeds_a[0]);
        assert!(speeds_a.iter().all(|&s| (1.0..SPEED_SPREAD).contains(&s)));
        let spread = speeds_a.iter().cloned().fold(0.0f64, f64::max)
            / speeds_a.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 2.0, "spread {spread}");
        // Keyed by id, not population: a million-client config derives the
        // same multiplier for a shared id without building any table.
        let big = RunConfig {
            n_clients: 1_000_000,
            ..RunConfig::default_mnist()
        };
        let mut c = InProc::default();
        let nc = ScenarioNet::new(&mut c, 1, 0.5, UplinkKind::Model, &big);
        assert_eq!(nc.speed(137), na.speed(137));
        assert!((1.0..SPEED_SPREAD).contains(&nc.speed(999_999)));
    }
}
