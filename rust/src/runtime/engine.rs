//! PJRT engine: owns the client and the compiled executables.
//!
//! ## Thread-safety
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), and
//! `execute` clones the client into every output buffer, so concurrent calls
//! from multiple coordinator workers would race on the `Rc` refcount. All
//! engine state therefore lives behind one `Mutex`, and `unsafe impl
//! Send/Sync` is justified by the invariant that *every* touch of an xla
//! type goes through that lock. Serializing calls costs little here: the
//! XLA-CPU executable parallelizes internally (Eigen thread pool), so the
//! device is already saturated by one call at a time.

use super::artifacts::{ArtifactSpec, Manifest};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// PJRT engine failure.
#[derive(Debug)]
pub enum RuntimeError {
    /// The artifact manifest failed to load or validate.
    Manifest(super::artifacts::ManifestError),
    /// An error surfaced by the underlying `xla` crate.
    Xla(String),
    /// A host input's element count disagrees with the manifest.
    BadInput {
        /// Artifact name.
        name: String,
        /// Zero-based input position.
        index: usize,
        /// Element count the manifest declares.
        expected: usize,
        /// Element count the caller supplied.
        got: usize,
    },
    /// Wrong number of inputs for an artifact call.
    BadArity {
        /// Artifact name.
        name: String,
        /// Input count the manifest declares.
        expected: usize,
        /// Input count the caller supplied.
        got: usize,
    },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Manifest(err) => write!(f, "manifest: {err}"),
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::BadInput {
                name,
                index,
                expected,
                got,
            } => write!(
                f,
                "artifact '{name}' input {index}: expected {expected} elements, got {got}"
            ),
            RuntimeError::BadArity {
                name,
                expected,
                got,
            } => write!(f, "artifact '{name}': expected {expected} inputs, got {got}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<super::artifacts::ManifestError> for RuntimeError {
    fn from(e: super::artifacts::ManifestError) -> RuntimeError {
        RuntimeError::Manifest(e)
    }
}

fn xla_err(e: xla::Error) -> RuntimeError {
    RuntimeError::Xla(e.to_string())
}

/// A host-side input value for an executable call.
pub enum Input<'a> {
    /// Dense f32 tensor data (row-major).
    F32(&'a [f32]),
    /// Dense i32 tensor data (row-major).
    I32(&'a [i32]),
    /// A single f32 scalar.
    ScalarF32(f32),
}

impl Input<'_> {
    fn elements(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
            Input::ScalarF32(_) => 1,
        }
    }
}

/// A host-side output value from an executable call.
#[derive(Debug, Clone)]
pub enum Output {
    /// Dense f32 tensor data (row-major).
    F32(Vec<f32>),
    /// Dense i32 tensor data (row-major).
    I32(Vec<i32>),
}

impl Output {
    /// The f32 data (panics on dtype mismatch).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Output::F32(v) => v,
            Output::I32(_) => panic!("output is i32, expected f32"),
        }
    }

    /// The i32 data (panics on dtype mismatch).
    pub fn as_i32(&self) -> &[i32] {
        match self {
            Output::I32(v) => v,
            Output::F32(_) => panic!("output is f32, expected i32"),
        }
    }

    /// The single f32 value of a scalar output (panics otherwise).
    pub fn scalar_f32(&self) -> f32 {
        let v = self.as_f32();
        assert_eq!(v.len(), 1, "expected scalar output");
        v[0]
    }
}

struct Inner {
    /// Kept alive for the executables' lifetime (they borrow the client
    /// through internal refcounts).
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: BTreeMap<String, (xla::PjRtLoadedExecutable, ArtifactSpec)>,
}

// SAFETY: all xla values (client, executables, literals, buffers) are only
// created/used/dropped inside `Engine` methods while holding `self.inner`'s
// mutex, so the non-atomic Rc refcounts inside them are never touched from
// two threads at once. See module docs.
unsafe impl Send for Inner {}

/// Compiled-artifact registry + PJRT client (see module docs for locking).
pub struct Engine {
    inner: Mutex<Inner>,
    manifest: Manifest,
}

impl Engine {
    /// Create a CPU PJRT client and eagerly compile the named artifacts
    /// (compile once, execute many — the coordinator's hot path never
    /// compiles).
    pub fn load(dir: &Path, names: &[&str]) -> Result<Engine, RuntimeError> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xla_err)?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut executables = BTreeMap::new();
        for &name in names {
            let spec = manifest.artifact(name)?.clone();
            let t0 = std::time::Instant::now();
            let proto =
                xla::HloModuleProto::from_text_file(&spec.file).map_err(xla_err)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(xla_err)?;
            log::info!("compiled {name} in {:?}", t0.elapsed());
            executables.insert(name.to_string(), (exe, spec));
        }
        Ok(Engine {
            inner: Mutex::new(Inner {
                client,
                executables,
            }),
            manifest,
        })
    }

    /// The manifest the engine's executables were loaded from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact with host inputs, returning host outputs.
    /// Shapes are validated against the manifest before the PJRT call.
    pub fn call(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Output>, RuntimeError> {
        let inner = self.inner.lock().expect("engine poisoned");
        let (exe, spec) = inner
            .executables
            .get(name)
            .unwrap_or_else(|| panic!("artifact '{name}' not loaded"));
        if inputs.len() != spec.inputs.len() {
            return Err(RuntimeError::BadArity {
                name: name.into(),
                expected: spec.inputs.len(),
                got: inputs.len(),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (index, (input, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if input.elements() != ispec.elements().max(1) {
                return Err(RuntimeError::BadInput {
                    name: name.into(),
                    index,
                    expected: ispec.elements(),
                    got: input.elements(),
                });
            }
            let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
            let lit = match input {
                Input::F32(v) => xla::Literal::vec1(v).reshape(&dims).map_err(xla_err)?,
                Input::I32(v) => xla::Literal::vec1(v).reshape(&dims).map_err(xla_err)?,
                Input::ScalarF32(v) => xla::Literal::scalar(*v),
            };
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals).map_err(xla_err)?;
        let tuple = result[0][0].to_literal_sync().map_err(xla_err)?;
        // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
        let parts = tuple.to_tuple().map_err(xla_err)?;
        let mut outputs = Vec::with_capacity(parts.len());
        for (part, ospec) in parts.into_iter().zip(&spec.outputs) {
            let out = match ospec.dtype.as_str() {
                "int32" => Output::I32(part.to_vec::<i32>().map_err(xla_err)?),
                _ => Output::F32(part.to_vec::<f32>().map_err(xla_err)?),
            };
            outputs.push(out);
        }
        Ok(outputs)
    }
}

// SAFETY: see Inner — the Mutex is the sole access path.
unsafe impl Sync for Engine {}

/// Convenience alias kept public for doc examples.
pub type Executable = ();
