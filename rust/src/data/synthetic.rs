//! Deterministic synthetic stand-ins for MNIST / CIFAR-10.
//!
//! The paper's experiments need a 10-class image dataset whose federated
//! partitions produce heterogeneous, learnable local objectives. We build
//! class-conditional generative models with enough intra-class variation
//! that the tasks are non-trivial (a linear model does not saturate them)
//! yet cheap to generate:
//!
//! * each class has `MODES` sub-prototypes, smooth low-frequency random
//!   fields (sums of 2-D cosines with class-specific spectra) — this gives
//!   images local spatial correlation like natural digits/photos;
//! * a sample picks a mode, scales it by a random amplitude, applies a
//!   small random translation (±2 px), and adds pixel noise;
//! * CIFAR-like data correlates the three channels through a class hue.
//!
//! For image shapes the pixel range is [0, 1] after the same normalization
//! the real loaders use, so model code is agnostic to which source produced
//! the data. Flat `synthetic:<d>` datasets (Gaussian mixtures for the
//! convex `linear`/`softmax` workloads) are **unbounded and signed** — do
//! not assume the [0, 1] invariant for them.

use super::{DataShape, Dataset, DatasetSpec, TrainTest};
use crate::util::rng::Rng;

const MODES: usize = 3;

/// Class-conditional generator parameters for one (class, mode) pair.
struct Prototype {
    /// Full-resolution single-channel field in [0,1].
    field: Vec<f32>,
    side: usize,
}

fn make_prototype(side: usize, rng: &mut Rng) -> Prototype {
    // Sum of random low-frequency cosines: smooth blobs, distinct per draw.
    let waves = 6;
    let params: Vec<(f32, f32, f32, f32)> = (0..waves)
        .map(|_| {
            (
                rng.uniform_range(0.5, 3.5) as f32,                    // fx
                rng.uniform_range(0.5, 3.5) as f32,                    // fy
                rng.uniform_range(0.0, std::f64::consts::TAU) as f32,  // phase
                rng.uniform_range(0.4, 1.0) as f32,                    // amplitude
            )
        })
        .collect();
    let mut field = vec![0.0f32; side * side];
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for y in 0..side {
        for x in 0..side {
            let (u, v) = (x as f32 / side as f32, y as f32 / side as f32);
            let mut s = 0.0;
            for &(fx, fy, ph, amp) in &params {
                s += amp * (std::f32::consts::TAU * (fx * u + fy * v) + ph).cos();
            }
            field[y * side + x] = s;
            lo = lo.min(s);
            hi = hi.max(s);
        }
    }
    let span = (hi - lo).max(1e-6);
    for p in &mut field {
        *p = (*p - lo) / span;
    }
    Prototype { field, side }
}

impl Prototype {
    /// Sample the field at (x, y) with an integer translation, clamped.
    #[inline]
    fn at(&self, x: i32, y: i32) -> f32 {
        let cx = x.clamp(0, self.side as i32 - 1) as usize;
        let cy = y.clamp(0, self.side as i32 - 1) as usize;
        self.field[cy * self.side + cx]
    }
}

/// Generate a train/test pair for any [`DatasetSpec`] shape. Labels are
/// balanced (round-robin) before shuffling so Dirichlet partitions see the
/// full class palette. Image shapes use the class-conditional field
/// generator above; flat shapes use a Gaussian-mixture generator (one
/// random centroid per class) whose classification objective is convex
/// under the `linear`/`softmax` models.
pub fn generate(spec: &DatasetSpec, train_n: usize, test_n: usize, rng: &mut Rng) -> TrainTest {
    let classes = spec.num_classes();
    let (side, channels) = match spec.shape() {
        DataShape::Image { channels, side } => (side, channels),
        DataShape::Flat { dim } => return generate_flat(spec, dim, train_n, test_n, rng),
    };
    // Build the generator bank once from a derived stream so train and test
    // come from the *same* distribution.
    let mut proto_rng = rng.derive(0xB10B);
    let protos: Vec<Vec<Prototype>> = (0..classes)
        .map(|_| (0..MODES).map(|_| make_prototype(side, &mut proto_rng)).collect())
        .collect();
    // Class hue rotation for multi-channel data.
    let hues: Vec<[f32; 3]> = (0..classes)
        .map(|c| {
            let theta = c as f32 / classes as f32 * std::f32::consts::TAU;
            [
                0.6 + 0.4 * theta.cos(),
                0.6 + 0.4 * (theta + 2.1).cos(),
                0.6 + 0.4 * (theta + 4.2).cos(),
            ]
        })
        .collect();

    let make_split = |n: usize, rng: &mut Rng| -> Dataset {
        let dim = spec.feature_dim();
        let mut features = vec![0.0f32; n * dim];
        let mut labels = vec![0u8; n];
        // Balanced labels, then shuffle example order.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for (slot, &i) in order.iter().enumerate() {
            let class = slot % classes;
            labels[i] = class as u8;
            let proto = &protos[class][rng.below_usize(MODES)];
            let amp = rng.uniform_range(0.7, 1.3) as f32;
            let (dx, dy) = (
                rng.below(5) as i32 - 2, // ±2 px translation
                rng.below(5) as i32 - 2,
            );
            let noise_std = 0.12f32;
            let base = i * dim;
            for ch in 0..channels {
                // Hue triplets cycle for exotic channel counts (the spec
                // grammar allows any `synthetic:<ch>x<s>x<s>`); 1-channel
                // data stays unscaled and 3-channel data is unaffected.
                let gain = if channels == 1 { 1.0 } else { hues[class][ch % 3] };
                for y in 0..side {
                    for x in 0..side {
                        let v = proto.at(x as i32 + dx, y as i32 + dy) * amp * gain
                            + rng.normal_f32(0.0, noise_std);
                        features[base + ch * side * side + y * side + x] = v.clamp(0.0, 1.0);
                    }
                }
            }
        }
        Dataset {
            spec: spec.clone(),
            features,
            labels,
            feature_dim: dim,
            num_classes: classes,
        }
    };

    let mut train_rng = rng.derive(0x7124);
    let mut test_rng = rng.derive(0x7E57);
    TrainTest {
        train: make_split(train_n, &mut train_rng),
        test: make_split(test_n, &mut test_rng),
    }
}

/// Flat Gaussian-mixture features: one N(0,1) centroid per class, samples
/// are amplitude-jittered centroids plus isotropic noise. Same derived-RNG
/// structure as the image path so train and test share the distribution.
fn generate_flat(
    spec: &DatasetSpec,
    dim: usize,
    train_n: usize,
    test_n: usize,
    rng: &mut Rng,
) -> TrainTest {
    let classes = spec.num_classes();
    let mut proto_rng = rng.derive(0xB10B);
    let means: Vec<Vec<f32>> = (0..classes)
        .map(|_| {
            let mut m = vec![0.0f32; dim];
            proto_rng.fill_normal_f32(&mut m, 0.0, 1.0);
            m
        })
        .collect();

    let make_split = |n: usize, rng: &mut Rng| -> Dataset {
        let mut features = vec![0.0f32; n * dim];
        let mut labels = vec![0u8; n];
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for (slot, &i) in order.iter().enumerate() {
            let class = slot % classes;
            labels[i] = class as u8;
            let amp = rng.uniform_range(0.7, 1.3) as f32;
            let mean = &means[class];
            let row = &mut features[i * dim..(i + 1) * dim];
            for (v, &m) in row.iter_mut().zip(mean) {
                *v = m * amp + rng.normal_f32(0.0, 0.8);
            }
        }
        Dataset {
            spec: spec.clone(),
            features,
            labels,
            feature_dim: dim,
            num_classes: classes,
        }
    };

    let mut train_rng = rng.derive(0x7124);
    let mut test_rng = rng.derive(0x7E57);
    TrainTest {
        train: make_split(train_n, &mut train_rng),
        test: make_split(test_n, &mut test_rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(spec: &DatasetSpec, n: usize) -> TrainTest {
        let mut rng = Rng::seed_from_u64(42);
        generate(spec, n, n / 4, &mut rng)
    }

    #[test]
    fn shapes_and_ranges() {
        let tt = gen(&DatasetSpec::mnist(), 400);
        assert_eq!(tt.train.len(), 400);
        assert_eq!(tt.train.features.len(), 400 * 784);
        assert!(tt.train.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(tt.train.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn labels_balanced() {
        let tt = gen(&DatasetSpec::mnist(), 1000);
        let counts = tt.train.class_counts();
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen(&DatasetSpec::mnist(), 100);
        let b = gen(&DatasetSpec::mnist(), 100);
        assert_eq!(a.train.features, b.train.features);
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn classes_are_separable_by_centroid() {
        // A nearest-class-centroid classifier on train centroids must beat
        // chance by a wide margin on test — i.e. the task is learnable.
        let tt = gen(&DatasetSpec::mnist(), 2000);
        let d = tt.train.feature_dim;
        let mut centroids = vec![vec![0.0f64; d]; 10];
        let mut counts = [0usize; 10];
        for i in 0..tt.train.len() {
            let (x, y) = tt.train.example(i);
            counts[y as usize] += 1;
            for (c, &v) in centroids[y as usize].iter_mut().zip(x) {
                *c += v as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            c.iter_mut().for_each(|v| *v /= n as f64);
        }
        let mut correct = 0;
        for i in 0..tt.test.len() {
            let (x, y) = tt.test.example(i);
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = centroids[a]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    let db: f64 = centroids[b]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / tt.test.len() as f64;
        assert!(acc > 0.5, "centroid accuracy too low: {acc}");
    }

    #[test]
    fn not_trivially_constant_within_class() {
        // Within-class variance must be non-negligible (modes + noise),
        // otherwise the FL dynamics would be unrealistically easy.
        let tt = gen(&DatasetSpec::mnist(), 500);
        let (x0, y0) = tt.train.example(0);
        let mut max_dist = 0.0f32;
        for i in 1..tt.train.len() {
            let (xi, yi) = tt.train.example(i);
            if yi == y0 {
                let dist = crate::tensor::l2_distance(x0, xi);
                max_dist = max_dist.max(dist);
            }
        }
        assert!(max_dist > 1.0, "within-class spread too small: {max_dist}");
    }

    #[test]
    fn cifar_has_three_correlated_channels() {
        let tt = gen(&DatasetSpec::cifar10(), 100);
        assert_eq!(tt.train.feature_dim, 3072);
        let (x, _) = tt.train.example(0);
        let (r, g) = (&x[0..1024], &x[1024..2048]);
        // channels share the spatial field -> strongly correlated
        let corr = correlation(r, g);
        assert!(corr > 0.3, "channel correlation {corr}");
    }

    #[test]
    fn exotic_channel_counts_generate_without_panic() {
        // The spec grammar allows any channel count; hue triplets cycle.
        let spec = DatasetSpec::parse("synthetic:4x8x8").unwrap();
        let tt = gen(&spec, 40);
        assert_eq!(tt.train.feature_dim, 4 * 64);
        assert!(tt.train.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn flat_mixture_is_deterministic_and_centroid_separable() {
        let spec = DatasetSpec::parse("synthetic:64-c5").unwrap();
        let a = gen(&spec, 500);
        let b = gen(&spec, 500);
        assert_eq!(a.train.features, b.train.features);
        assert_eq!(a.train.feature_dim, 64);
        assert_eq!(a.train.num_classes, 5);
        let counts = a.train.class_counts();
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
        // Nearest-train-centroid classification on test must beat chance
        // by a wide margin (the mixture is meant to be separable).
        let d = a.train.feature_dim;
        let mut centroids = vec![vec![0.0f64; d]; 5];
        let mut n_per = [0usize; 5];
        for i in 0..a.train.len() {
            let (x, y) = a.train.example(i);
            n_per[y as usize] += 1;
            for (c, &v) in centroids[y as usize].iter_mut().zip(x) {
                *c += v as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(n_per) {
            c.iter_mut().for_each(|v| *v /= n as f64);
        }
        let mut correct = 0;
        for i in 0..a.test.len() {
            let (x, y) = a.test.example(i);
            let pred = (0..5)
                .min_by(|&p, &q| {
                    let dp: f64 = centroids[p]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    let dq: f64 = centroids[q]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    dp.partial_cmp(&dq).unwrap()
                })
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / a.test.len() as f64;
        assert!(acc > 0.6, "centroid accuracy too low: {acc}");
    }

    fn correlation(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            cov += (x as f64 - ma) * (y as f64 - mb);
            va += (x as f64 - ma).powi(2);
            vb += (y as f64 - mb).powi(2);
        }
        cov / (va.sqrt() * vb.sqrt()).max(1e-12)
    }
}
