//! Lazy/eager partition identity pin: `partition_streaming` must be
//! **bit-identical** to the eager `partition` reference — element-for-element
//! shards, identical post-call RNG state (so everything downstream of the
//! partitioner sees the same stream), and equal diagnostics — across a grid
//! of population sizes (including populations larger than the dataset),
//! concentrations (including the degenerate α=0.01 regime), and seeds.

use fedcomloc::data::dirichlet::{partition, partition_streaming};
use fedcomloc::data::{synthetic, Dataset, DatasetSpec};
use fedcomloc::util::rng::Rng;

fn dataset(n: usize) -> Dataset {
    synthetic::generate(&DatasetSpec::mnist(), n, 10, &mut Rng::seed_from_u64(9)).train
}

#[test]
fn lazy_partition_matches_eager_across_grid() {
    let data = dataset(500);
    // min_per_client mirrors Federation::new: capped by the per-client share
    // so oversubscribed populations degrade to best-effort (floor 1).
    let n_grid = [1usize, 7, 100, 600, 2_000, 5_000];
    let alpha_grid = [0.01f64, 0.1, 0.7, 10.0];
    for &n_clients in &n_grid {
        for &alpha in &alpha_grid {
            for seed in 0..3u64 {
                let min_per_client = (data.len() / n_clients).clamp(1, 16);
                let mut eager_rng = Rng::seed_from_u64(seed);
                let eager = partition(&data, n_clients, alpha, min_per_client, &mut eager_rng);
                let mut lazy_rng = Rng::seed_from_u64(seed);
                let lazy =
                    partition_streaming(&data, n_clients, alpha, min_per_client, &mut lazy_rng);

                let tag = format!("n={n_clients} alpha={alpha} seed={seed}");
                assert_eq!(lazy.num_clients(), eager.num_clients(), "{tag}");
                // Post-call RNG state equality is the keystone: it means the
                // model init, loader seeds and server streams that follow are
                // untouched by swapping the partitioner.
                assert_eq!(eager_rng.state(), lazy_rng.state(), "rng diverged: {tag}");

                let mut nonempty = 0usize;
                for c in 0..n_clients {
                    let e = &eager.client_indices[c];
                    let l = lazy.shard(c);
                    assert_eq!(l, e.as_slice(), "shard {c} differs: {tag}");
                    if !e.is_empty() {
                        nonempty += 1;
                    }
                }
                assert_eq!(lazy.num_nonempty(), nonempty, "{tag}");

                // Diagnostics computed on the lazy view must agree exactly.
                assert_eq!(
                    lazy.class_histogram(&data),
                    eager.class_histogram(&data),
                    "histogram differs: {tag}"
                );
                let tv_e = eager.heterogeneity_tv(&data);
                let tv_l = lazy.heterogeneity_tv(&data);
                assert_eq!(tv_e.to_bits(), tv_l.to_bits(), "tv differs: {tag}");
            }
        }
    }
}

#[test]
fn lazy_partition_handles_tiny_datasets_and_huge_populations() {
    // Fewer examples than classes: some class buckets are empty, and with
    // n_clients ≫ examples nearly every shard is empty. The sparse view must
    // still agree with the eager reference on every id.
    let data = dataset(8);
    for &n_clients in &[3usize, 8, 50, 10_000] {
        for seed in 0..2u64 {
            let mut eager_rng = Rng::seed_from_u64(seed);
            let eager = partition(&data, n_clients, 0.5, 1, &mut eager_rng);
            let mut lazy_rng = Rng::seed_from_u64(seed);
            let lazy = partition_streaming(&data, n_clients, 0.5, 1, &mut lazy_rng);
            assert_eq!(eager_rng.state(), lazy_rng.state(), "n={n_clients} seed={seed}");
            for c in 0..n_clients {
                assert_eq!(
                    lazy.shard(c),
                    eager.client_indices[c].as_slice(),
                    "n={n_clients} seed={seed} shard {c}"
                );
            }
            // Sparse storage really is sparse: at most one entry per example.
            assert!(lazy.num_nonempty() <= data.len());
        }
    }
}

#[test]
fn lazy_partition_iterates_nonempty_in_ascending_order() {
    let data = dataset(120);
    let mut rng = Rng::seed_from_u64(4);
    let lazy = partition_streaming(&data, 3_000, 0.3, 1, &mut rng);
    let mut prev: Option<usize> = None;
    let mut total = 0usize;
    for (id, shard) in lazy.nonempty() {
        assert!(prev.map_or(true, |p| p < id), "nonempty() not ascending");
        assert!(!shard.is_empty());
        assert!(id < lazy.num_clients());
        prev = Some(id);
        total += shard.len();
    }
    assert_eq!(total, data.len(), "every example lands in exactly one shard");
}
