//! Command-line argument parsing substrate (clap is not vendored offline).
//!
//! Supports the patterns the `fedcomloc` binary uses: positional
//! subcommands, `--flag`, `--key value` / `--key=value`, repeated options,
//! and auto-generated `--help` text from registered option metadata.

use std::collections::BTreeMap;

/// Declarative option spec used for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value_name: Option<&'static str>, // None => boolean flag
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, usize>,
    specs: Vec<OptSpec>,
    program: String,
    about: String,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    InvalidValue {
        key: String,
        value: String,
        reason: String,
    },
    Other(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => {
                write!(f, "unknown option '--{name}' (try --help)")
            }
            CliError::MissingValue(name) => write!(f, "option '--{name}' requires a value"),
            CliError::InvalidValue { key, value, reason } => {
                write!(f, "invalid value for '--{key}': '{value}' ({reason})")
            }
            CliError::Other(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for CliError {}

/// Builder for a command's interface.
pub struct Command {
    name: String,
    about: String,
    specs: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
        }
    }

    /// `--name <VALUE>` option.
    pub fn opt(mut self, name: &'static str, value_name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            value_name: Some(value_name),
            help,
            default: None,
        });
        self
    }

    /// `--name <VALUE>` option with default shown in help.
    pub fn opt_default(
        mut self,
        name: &'static str,
        value_name: &'static str,
        help: &'static str,
        default: &str,
    ) -> Self {
        self.specs.push(OptSpec {
            name,
            value_name: Some(value_name),
            help,
            default: Some(default.to_string()),
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            value_name: None,
            help,
            default: None,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{}\n\nUSAGE:\n    {} [OPTIONS]\n\nOPTIONS:\n", self.about, self.name);
        for spec in &self.specs {
            let lhs = match spec.value_name {
                Some(v) => format!("--{} <{}>", spec.name, v),
                None => format!("--{}", spec.name),
            };
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("    {lhs:<28} {}{}\n", spec.help, default));
        }
        s.push_str("    --help                       Print this help\n");
        s
    }

    /// Parse a token stream (not including the program/subcommand name).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, CliError> {
        let is_flag = |name: &str| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .map(|s| s.value_name.is_none())
        };
        let mut args = Args {
            program: self.name.clone(),
            about: self.about.clone(),
            specs: self.specs.clone(),
            ..Default::default()
        };
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                args.flags.insert("help".into(), 1);
                i += 1;
                continue;
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_value) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                match is_flag(&name) {
                    None => return Err(CliError::UnknownOption(name)),
                    Some(true) => {
                        *args.flags.entry(name).or_insert(0) += 1;
                        i += 1;
                    }
                    Some(false) => {
                        let value = if let Some(v) = inline_value {
                            v
                        } else {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        };
                        args.values.entry(name).or_default().push(value);
                        i += 1;
                    }
                }
            } else {
                args.positionals.push(tok.clone());
                i += 1;
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn wants_help(&self) -> bool {
        self.flags.contains_key("help")
    }

    pub fn help_text(&self) -> String {
        Command {
            name: self.program.clone(),
            about: self.about.clone(),
            specs: self.specs.clone(),
        }
        .help_text()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|e| CliError::InvalidValue {
                key: name.to_string(),
                value: raw.to_string(),
                reason: e.to_string(),
            }),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    /// Parse a comma-separated list option, e.g. `--densities 0.1,0.3,1.0`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse::<T>().map_err(|e| CliError::InvalidValue {
                        key: name.to_string(),
                        value: s.to_string(),
                        reason: e.to_string(),
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "Train a federated model")
            .opt_default("rounds", "N", "communication rounds", "500")
            .opt("lr", "F", "learning rate")
            .opt("density", "F", "TopK density ratio")
            .flag("verbose", "log per-round metrics")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let args = cmd()
            .parse(&toks(&["--rounds", "100", "--lr=0.05", "--verbose", "extra"]))
            .unwrap();
        assert_eq!(args.get("rounds"), Some("100"));
        assert_eq!(args.get_or::<f64>("lr", 0.1).unwrap(), 0.05);
        assert!(args.flag("verbose"));
        assert_eq!(args.positionals, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let args = cmd().parse(&toks(&[])).unwrap();
        assert_eq!(args.get_or::<usize>("rounds", 500).unwrap(), 500);
        assert!(!args.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            cmd().parse(&toks(&["--nope"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cmd().parse(&toks(&["--lr"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_value_carries_context() {
        let args = cmd().parse(&toks(&["--lr", "abc"])).unwrap();
        let err = args.get_parsed::<f64>("lr").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("lr") && msg.contains("abc"), "{msg}");
    }

    #[test]
    fn list_option() {
        let args = cmd().parse(&toks(&["--density", "0.1,0.3,1.0"])).unwrap();
        let v: Vec<f64> = args.get_list("density").unwrap().unwrap();
        assert_eq!(v, vec![0.1, 0.3, 1.0]);
    }

    #[test]
    fn help_text_lists_options() {
        let h = cmd().help_text();
        assert!(h.contains("--rounds <N>"));
        assert!(h.contains("[default: 500]"));
        assert!(h.contains("--verbose"));
        let args = cmd().parse(&toks(&["--help"])).unwrap();
        assert!(args.wants_help());
    }

    #[test]
    fn repeated_options_keep_all_last_wins() {
        let args = cmd().parse(&toks(&["--lr", "0.1", "--lr", "0.2"])).unwrap();
        assert_eq!(args.get("lr"), Some("0.2"));
        assert_eq!(args.get_all("lr"), vec!["0.1", "0.2"]);
    }
}
