//! Pluggable transports: every [`Message`] crossing the client/server
//! boundary goes through a [`Transport`], so communicated-bit metrics are
//! *measured* (real serialized payloads), never estimated.
//!
//! Two implementations ship:
//!
//! * [`InProc`] — the in-process "network" of the seed: zero latency, no
//!   loss, byte-exact delivery. Accounting matches the pre-trait drivers
//!   bit for bit (the regression test in `tests/api_regression.rs` pins
//!   this).
//! * [`SimNet`] — a simulated network with configurable per-link bandwidth
//!   (with deterministic per-client heterogeneity), per-message latency,
//!   and per-client round dropout. It feeds a simulated wall-clock and a
//!   drop count into each [`crate::metrics::RoundRecord`], enabling the
//!   straggler/dropout scenarios the paper's heterogeneity experiments
//!   gesture at without changing any algorithm code.
//!
//! Delivery happens on the coordinator thread (workers hand finished
//! messages back from the fork-join), keeping per-link accounting off the
//! training hot path and transports free of internal locking.

use super::message::Message;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Accumulated wire usage for one round.
#[derive(Debug, Default, Clone, Copy)]
pub struct WireUsage {
    /// Client→server bits put on the wire this round.
    pub uplink_bits: u64,
    /// Server→client bits put on the wire this round.
    pub downlink_bits: u64,
    /// Client→server messages this round.
    pub uplink_msgs: u64,
    /// Server→client messages this round.
    pub downlink_msgs: u64,
}

impl WireUsage {
    /// Account one uplink message of `bits` meaningful payload bits.
    pub fn add_uplink(&mut self, bits: u64) {
        self.uplink_bits += bits;
        self.uplink_msgs += 1;
    }

    /// Account one downlink message of `bits` meaningful payload bits.
    pub fn add_downlink(&mut self, bits: u64) {
        self.downlink_bits += bits;
        self.downlink_msgs += 1;
    }

    /// Fold another usage tally into this one.
    pub fn merge(&mut self, other: WireUsage) {
        self.uplink_bits += other.uplink_bits;
        self.downlink_bits += other.downlink_bits;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
    }
}

/// Per-round roll-up a transport hands back to the drive loop.
#[derive(Debug, Default, Clone, Copy)]
pub struct LinkReport {
    /// Bits/messages in both directions this round.
    pub usage: WireUsage,
    /// Simulated wall-clock for the round: the slowest participating
    /// client's total link time (0 for [`InProc`]).
    pub sim_secs: f64,
    /// Sampled clients that were unreachable this round (0 for [`InProc`]).
    pub dropped_clients: u64,
    /// Straggler updates folded staleness-weighted into this round by a
    /// semi-synchronous scenario (0 for plain transports — only the
    /// scenario engine in [`crate::fed::sim`] produces these).
    pub stale_updates: u64,
    /// In-flight straggler updates discarded this round because their
    /// client was re-sampled before arrival (0 for plain transports).
    pub churned_clients: u64,
    /// Frames the fault plane corrupted in flight this round (0 unless a
    /// [`crate::fed::faults::FaultNet`] wraps the transport).
    pub corrupt_frames: u64,
    /// Retransmission attempts the recovery layer issued this round after
    /// corrupted deliveries (0 without an active fault plane).
    pub retransmits: u64,
    /// Duplicated deliveries the fault plane injected (and the receiver
    /// deduplicated) this round (0 without an active fault plane).
    pub dup_frames: u64,
    /// Simulated seconds spent in retransmit backoff and link outages this
    /// round — already included in `sim_secs` (0 without a fault plane).
    pub backoff_secs: f64,
    /// True when the round failed its `quorum:<f>` threshold: too few
    /// uplinks survived, so the server aggregated nothing and the model is
    /// carried over unchanged (never set without an active fault plane).
    pub aborted: bool,
}

/// A bidirectional client/server message channel with per-round accounting.
///
/// Contract: within one round, [`Transport::broadcast`] decides each
/// client's availability exactly once (repeated broadcasts to the same
/// client reuse the decision, so multi-vector downlinks like Scaffold's
/// `(x, c)` see one coherent participant set); [`Transport::end_round`]
/// drains the accounting and resets per-round state.
pub trait Transport: Send {
    /// Short channel name for logs/CLI (`inproc`, `simnet`).
    fn name(&self) -> &'static str;

    /// Server → clients. Encodes once, accounts per recipient, and returns
    /// the subset of `clients` that actually received the message (a
    /// dropped client is unreachable for the whole round).
    fn broadcast(&mut self, clients: &[usize], msg: &Message) -> Vec<usize>;

    /// Client → server. Accounts the link and returns the message as the
    /// server receives it, or `None` if the link lost it.
    fn uplink(&mut self, client: usize, msg: Message) -> Option<Message>;

    /// Drain this round's accounting.
    fn end_round(&mut self) -> LinkReport;

    /// One-way transfer time for `bits` over this client's link, in
    /// simulated seconds. The scenario engine ([`crate::fed::sim`]) queries
    /// this to place message arrivals on its virtual clock; transports
    /// without a timing model ([`InProc`]) report instantaneous links.
    fn link_secs(&self, _client: usize, _bits: u64) -> f64 {
        0.0
    }

    /// Serialize the transport's cross-round state for a checkpoint
    /// ([`crate::ckpt`]), taken at a round boundary (after
    /// [`Transport::end_round`] has drained per-round state). Stateless
    /// transports like [`InProc`] return an empty section.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore a [`Transport::save_state`] section onto a freshly
    /// constructed transport of the same spec. The default accepts only an
    /// empty section, so a checkpoint from a stateful transport cannot be
    /// silently dropped on a stateless one.
    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        if bytes.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "transport '{}' is stateless but checkpoint carries {} state bytes",
                self.name(),
                bytes.len()
            ))
        }
    }
}

/// The in-process transport: today's semantics, byte-exact, zero loss.
#[derive(Debug, Default)]
pub struct InProc {
    usage: WireUsage,
}

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn broadcast(&mut self, clients: &[usize], msg: &Message) -> Vec<usize> {
        for _ in clients {
            self.usage.add_downlink(msg.wire_bits());
        }
        clients.to_vec()
    }

    fn uplink(&mut self, _client: usize, msg: Message) -> Option<Message> {
        self.usage.add_uplink(msg.wire_bits());
        Some(msg)
    }

    fn end_round(&mut self) -> LinkReport {
        LinkReport {
            usage: std::mem::take(&mut self.usage),
            ..LinkReport::default()
        }
    }
}

/// Parameters for the simulated network.
#[derive(Debug, Clone, Copy)]
pub struct SimNetCfg {
    /// Mean per-link bandwidth in bits/second.
    pub bandwidth_bps: f64,
    /// One-way per-message latency in seconds.
    pub latency_secs: f64,
    /// Probability a sampled client is unreachable for a round.
    pub drop_prob: f64,
    /// Per-client bandwidth heterogeneity factor `h ≥ 1`: client bandwidth
    /// is drawn log-uniformly from `[bandwidth/h, bandwidth]` at
    /// construction (h = 1 ⇒ homogeneous links).
    pub heterogeneity: f64,
}

impl Default for SimNetCfg {
    fn default() -> Self {
        // 10 Mbit/s links, 50 ms latency, no dropout, 4× straggler spread —
        // a plausible cross-device FL profile.
        SimNetCfg {
            bandwidth_bps: 10e6,
            latency_secs: 0.05,
            drop_prob: 0.0,
            heterogeneity: 4.0,
        }
    }
}

/// Simulated network with per-link bandwidth/latency and client dropout.
pub struct SimNet {
    cfg: SimNetCfg,
    rng: Rng,
    /// Root of the per-client bandwidth streams: client `c`'s fixed
    /// bandwidth is a pure function of this root and `c` (see
    /// [`SimNet::client_bw`]), so no per-client table is ever built and a
    /// million-client population costs nothing.
    bw_root: Rng,
    usage: WireUsage,
    /// Accumulated link seconds per participating client this round.
    round_secs: HashMap<usize, f64>,
    /// Availability decision per sampled client this round.
    round_avail: HashMap<usize, bool>,
}

impl SimNet {
    /// Build a simulated network. The population size is not a parameter:
    /// per-client bandwidths are derived from `seed` and the client *id*
    /// on demand, deterministic per run at any population.
    pub fn new(cfg: SimNetCfg, seed: u64) -> SimNet {
        assert!(cfg.bandwidth_bps > 0.0, "bandwidth must be positive");
        assert!((0.0..=1.0).contains(&cfg.drop_prob), "drop_prob in [0,1]");
        assert!(cfg.heterogeneity >= 1.0, "heterogeneity factor >= 1");
        SimNet {
            cfg,
            rng: Rng::seed_from_u64(seed ^ 0x51A1_4E7),
            bw_root: Rng::seed_from_u64(seed ^ 0xB0AD_BA4D),
            usage: WireUsage::default(),
            round_secs: HashMap::new(),
            round_avail: HashMap::new(),
        }
    }

    /// Client `c`'s fixed link bandwidth (bits/sec), log-uniform on
    /// `[bandwidth/h, bandwidth]` — a pure per-id derivation, identical
    /// whether queried once, repeatedly, or never.
    fn client_bw(&self, client: usize) -> f64 {
        let mut stream = self.bw_root.derive(client as u64);
        self.cfg.bandwidth_bps * (-stream.uniform() * self.cfg.heterogeneity.ln()).exp()
    }
}

impl Transport for SimNet {
    fn name(&self) -> &'static str {
        "simnet"
    }

    fn broadcast(&mut self, clients: &[usize], msg: &Message) -> Vec<usize> {
        let mut delivered = Vec::with_capacity(clients.len());
        for &c in clients {
            let drop_prob = self.cfg.drop_prob;
            let rng = &mut self.rng;
            let available = *self
                .round_avail
                .entry(c)
                .or_insert_with(|| !rng.bernoulli(drop_prob));
            // Server egress is spent whether or not the client is up.
            self.usage.add_downlink(msg.wire_bits());
            if available {
                let secs = self.link_secs(c, msg.wire_bits());
                *self.round_secs.entry(c).or_insert(0.0) += secs;
                delivered.push(c);
            }
        }
        delivered
    }

    fn uplink(&mut self, client: usize, msg: Message) -> Option<Message> {
        let available = *self.round_avail.entry(client).or_insert(true);
        self.usage.add_uplink(msg.wire_bits());
        if !available {
            return None;
        }
        let secs = self.link_secs(client, msg.wire_bits());
        *self.round_secs.entry(client).or_insert(0.0) += secs;
        Some(msg)
    }

    fn end_round(&mut self) -> LinkReport {
        let sim_secs = self
            .round_secs
            .values()
            .fold(0.0f64, |acc, &s| acc.max(s));
        let dropped = self.round_avail.values().filter(|&&a| !a).count() as u64;
        self.round_secs.clear();
        self.round_avail.clear();
        LinkReport {
            usage: std::mem::take(&mut self.usage),
            sim_secs,
            dropped_clients: dropped,
            ..LinkReport::default()
        }
    }

    fn link_secs(&self, client: usize, bits: u64) -> f64 {
        self.cfg.latency_secs + bits as f64 / self.client_bw(client)
    }

    fn save_state(&self) -> Vec<u8> {
        // The only cross-round state is the dropout RNG stream: bandwidths
        // are pure per-id derivations from the seed (so a same-spec rebuild
        // reproduces them), and `round_secs`/`round_avail` are empty at
        // round boundaries.
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_rng(&self.rng);
        w.into_bytes()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::bytes::ByteReader::new(bytes, "simnet state");
        self.rng = r.take_rng()?;
        r.finish()
    }
}

/// Parse a transport spec string: `inproc` (default) or
/// `simnet[:BW_MBPS[:LATENCY_MS[:DROP_PROB[:HETEROGENEITY]]]]`, e.g.
/// `simnet:10:50:0.1:4`.
pub fn parse_transport(spec: &str, seed: u64) -> Result<Box<dyn Transport>, String> {
    let spec = spec.trim();
    let (kind, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    match kind.to_ascii_lowercase().as_str() {
        "" | "inproc" => {
            if rest.is_some() {
                return Err("inproc takes no parameters".into());
            }
            Ok(Box::new(InProc::default()))
        }
        "simnet" => {
            let mut cfg = SimNetCfg::default();
            if let Some(rest) = rest {
                let parts: Vec<&str> = rest.split(':').collect();
                if parts.len() > 4 {
                    return Err(format!("too many simnet parameters in '{spec}'"));
                }
                let parse = |s: &str, what: &str| {
                    s.parse::<f64>().map_err(|_| format!("bad simnet {what} '{s}'"))
                };
                if let Some(s) = parts.first() {
                    cfg.bandwidth_bps = parse(s, "bandwidth (Mbit/s)")? * 1e6;
                }
                if let Some(s) = parts.get(1) {
                    cfg.latency_secs = parse(s, "latency (ms)")? / 1e3;
                }
                if let Some(s) = parts.get(2) {
                    cfg.drop_prob = parse(s, "drop probability")?;
                }
                if let Some(s) = parts.get(3) {
                    cfg.heterogeneity = parse(s, "heterogeneity factor")?;
                }
            }
            if cfg.bandwidth_bps <= 0.0 {
                return Err("simnet bandwidth must be positive".into());
            }
            if !(0.0..=1.0).contains(&cfg.drop_prob) {
                return Err("simnet drop probability must be in [0,1]".into());
            }
            if cfg.heterogeneity < 1.0 {
                return Err("simnet heterogeneity factor must be >= 1".into());
            }
            Ok(Box::new(SimNet::new(cfg, seed)))
        }
        other => Err(format!("unknown transport '{other}' (have: inproc, simnet)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::message::SERVER;

    fn dense_msg(d: usize) -> Message {
        Message::dense(0, SERVER, &vec![1.0f32; d])
    }

    #[test]
    fn inproc_accounts_and_delivers_everything() {
        let mut t = InProc::default();
        let msg = dense_msg(100);
        let delivered = t.broadcast(&[3, 5, 9], &msg);
        assert_eq!(delivered, vec![3, 5, 9]);
        let up = t.uplink(5, dense_msg(100)).expect("inproc never drops");
        assert_eq!(up.to_dense(), vec![1.0f32; 100]);
        let report = t.end_round();
        assert_eq!(report.usage.downlink_bits, 3 * 3200);
        assert_eq!(report.usage.uplink_bits, 3200);
        assert_eq!(report.usage.downlink_msgs, 3);
        assert_eq!(report.sim_secs, 0.0);
        assert_eq!(report.dropped_clients, 0);
        // Accounting was drained.
        assert_eq!(t.end_round().usage.uplink_bits, 0);
    }

    #[test]
    fn simnet_latency_and_bandwidth_accumulate() {
        let cfg = SimNetCfg {
            bandwidth_bps: 1e6,
            latency_secs: 0.1,
            drop_prob: 0.0,
            heterogeneity: 1.0,
        };
        let mut t = SimNet::new(cfg, 7);
        let msg = dense_msg(1000); // 32_000 bits -> 0.032 s at 1 Mbit/s
        let delivered = t.broadcast(&[0, 1], &msg);
        assert_eq!(delivered, vec![0, 1]);
        for c in delivered {
            assert!(t.uplink(c, dense_msg(1000)).is_some());
        }
        let report = t.end_round();
        // Each client: 2 messages x (0.1 latency + 0.032 transfer).
        assert!((report.sim_secs - 0.264).abs() < 1e-9, "{}", report.sim_secs);
        assert_eq!(report.usage.uplink_bits, 64_000);
        assert_eq!(report.dropped_clients, 0);
    }

    #[test]
    fn simnet_drops_are_deterministic_and_sticky() {
        let cfg = SimNetCfg {
            drop_prob: 0.5,
            heterogeneity: 1.0,
            ..SimNetCfg::default()
        };
        let clients: Vec<usize> = (0..64).collect();
        let run = |seed: u64| {
            let mut t = SimNet::new(cfg, seed);
            let msg = dense_msg(10);
            let first = t.broadcast(&clients, &msg);
            // Second broadcast in the same round sees the same availability.
            let second = t.broadcast(&clients, &msg);
            assert_eq!(first, second);
            let report = t.end_round();
            assert_eq!(report.dropped_clients as usize, 64 - first.len());
            first
        };
        assert_eq!(run(11), run(11), "same seed, same drops");
        let a = run(11);
        assert!(!a.is_empty() && a.len() < 64, "p=0.5 over 64 clients");
    }

    #[test]
    fn simnet_heterogeneity_spreads_bandwidth() {
        let cfg = SimNetCfg {
            heterogeneity: 8.0,
            ..SimNetCfg::default()
        };
        let t = SimNet::new(cfg, 3);
        let bws: Vec<f64> = (0..200).map(|c| t.client_bw(c)).collect();
        let min = bws.iter().cloned().fold(f64::MAX, f64::min);
        let max = bws.iter().cloned().fold(0.0, f64::max);
        assert!(max <= cfg.bandwidth_bps + 1e-6);
        assert!(min >= cfg.bandwidth_bps / 8.0 - 1e-6);
        assert!(max / min > 2.0, "spread {}", max / min);
        // Pure per-id derivation: stable across queries and independent of
        // population size — a million-client net derives the same link.
        assert_eq!(t.client_bw(137).to_bits(), t.client_bw(137).to_bits());
        let big = SimNet::new(cfg, 3);
        assert_eq!(big.client_bw(137).to_bits(), t.client_bw(137).to_bits());
        let far = big.client_bw(999_999);
        assert!(far <= cfg.bandwidth_bps + 1e-6 && far >= cfg.bandwidth_bps / 8.0 - 1e-6);
    }

    #[test]
    fn transport_spec_parsing() {
        assert_eq!(parse_transport("inproc", 0).unwrap().name(), "inproc");
        assert_eq!(parse_transport("", 0).unwrap().name(), "inproc");
        assert_eq!(parse_transport("simnet", 0).unwrap().name(), "simnet");
        assert_eq!(
            parse_transport("simnet:10:50:0.1:4", 0).unwrap().name(),
            "simnet"
        );
        assert!(parse_transport("simnet:0", 0).is_err());
        assert!(parse_transport("simnet:10:50:1.5", 0).is_err());
        assert!(parse_transport("simnet:1:1:0:0.5", 0).is_err());
        assert!(parse_transport("carrier-pigeon", 0).is_err());
        assert!(parse_transport("inproc:fast", 0).is_err());
    }

    #[test]
    fn simnet_state_roundtrip_continues_drop_stream() {
        let cfg = SimNetCfg {
            drop_prob: 0.5,
            heterogeneity: 1.0,
            ..SimNetCfg::default()
        };
        let clients: Vec<usize> = (0..32).collect();
        let msg = dense_msg(10);
        let mut a = SimNet::new(cfg, 9);
        // Advance a few rounds, snapshot, rebuild-from-spec + restore.
        for _ in 0..3 {
            a.broadcast(&clients, &msg);
            a.end_round();
        }
        let state = a.save_state();
        let mut b = SimNet::new(cfg, 9);
        b.restore_state(&state).unwrap();
        for round in 0..4 {
            assert_eq!(
                a.broadcast(&clients, &msg),
                b.broadcast(&clients, &msg),
                "round {round}"
            );
            a.end_round();
            b.end_round();
        }
        // A stateless transport rejects a non-empty section.
        assert!(InProc::default().restore_state(&state).is_err());
        assert!(InProc::default().restore_state(&[]).is_ok());
    }

    #[test]
    fn usage_merges() {
        let mut a = WireUsage::default();
        a.add_uplink(10);
        a.add_downlink(20);
        let mut b = WireUsage::default();
        b.add_uplink(5);
        b.merge(a);
        assert_eq!(b.uplink_bits, 15);
        assert_eq!(b.downlink_bits, 20);
        assert_eq!(b.uplink_msgs, 2);
    }
}
