"""AOT path: lowering to HLO text and manifest generation.

Full-size artifact builds run in `make artifacts`; here we lower the real
programs (cheap — tracing only) and check the HLO text + manifest contract
the Rust runtime depends on.
"""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M


def test_mlp_programs_lower_to_hlo_text():
    for program in ("train_step", "grad", "evaluate"):
        text, entry = aot.lower_program("mlp", program)
        assert text.startswith("HloModule"), f"{program}: not HLO text"
        assert entry["file"] == f"mlp_{program}.hlo.txt"
        assert len(entry["inputs"]) == len(M.example_args("mlp", program))
        # No serialized-proto path anywhere (xla 0.5.1 rejects 64-bit ids).
        assert "0x" not in text[:100]


def test_train_step_local_has_density_input():
    _, entry = aot.lower_program("mlp", "train_step_local")
    assert len(entry["inputs"]) == 6
    assert entry["inputs"][5]["shape"] == []


def test_quantize_lowering():
    text, entry = aot.lower_quantize(dim=512)
    assert text.startswith("HloModule")
    assert entry["inputs"][0]["shape"] == [512]


def test_build_all_writes_manifest(tmp_path):
    out = str(tmp_path / "arts")
    # Only the MLP family to keep the test fast.
    aot.build_all(out, models=("mlp",))
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["hlo"] == "text"
    assert "mlp_train_step" in manifest["artifacts"]
    assert "quantize" in manifest["artifacts"]
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(out, entry["file"])
        assert os.path.isfile(path), name
        head = open(path).read(16)
        assert head.startswith("HloModule")
        assert len(entry["sha256"]) == 64
    model = manifest["models"]["mlp"]
    assert model["dim"] == 109_386
    assert model["batch"] == 64
    assert model["eval_batch"] == 256


def test_manifest_matches_eval_shape():
    # jax.eval_shape agreement guards against drift between the lowered
    # program and the manifest the Rust side validates calls against.
    fn = M.PROGRAMS["train_step"]("mlp")
    args = M.example_args("mlp", "train_step")
    out = jax.eval_shape(fn, *args)
    flat = jax.tree_util.tree_leaves(out)
    assert flat[0].shape == (M.MODELS["mlp"].DIM,)
    assert flat[1].shape == ()


def test_unknown_model_rejected():
    with pytest.raises(SystemExit):
        aot.main(["--models", "transformer"])
