//! The declarative sweep schema: [`SweepSpec`] (parsed from TOML) and its
//! expansion into a flat, validated list of [`RunUnit`]s.
//!
//! A sweep file is one document:
//!
//! ```toml
//! schema = 1
//! name  = "sparsity"                      # output dir + run-id prefix
//! title = "TopK sparsity ratios on FedMNIST"
//! paper = "Table 1, Figure 1"
//!
//! [base]                                  # fixed run settings
//! preset = "scaled-mnist"                 # config::presets starting point
//! rounds = 60                             # any [run]-table key (config::apply_kv)
//!
//! [[grid]]                                # one cross-product block
//! algos  = ["fedcomloc-com:none", "fedcomloc-com:topk:0.1"]
//! alphas = [0.1, 0.7]                     # scalar grids multiply out
//!
//! [[grid]]                                # further blocks append their
//! preset = "scaled-cifar"                 # own cross-products (with an
//! algos  = ["fedcomloc-com:q:8"]          # optional per-block preset)
//! ```
//!
//! Axis keys (each accepts a scalar or a list; a missing axis inherits the
//! base value): `algos`, `models`, `datasets`, `transports`, `scenarios`
//! (`sync` / `semisync:<K>[@<staleness>]` round runtimes — see
//! [`crate::fed::sim`]), `faults` (fault-injection plans —
//! [`crate::fed::faults::FaultSpec`] grammar), `backends` (compute-plane
//! keys — the [`crate::backend`] registry), `compress_up`,
//! `compress_down` over the
//! string-keyed registries, plus scalar grids `rounds`, `local_iters`,
//! `alphas`, `gammas`, `ps`, `seeds`, and the population-scale axes
//! `clients` (`n_clients`) / `sampled` (`clients_per_round`). Any *other*
//! key inside a `[[grid]]` block is a fixed per-block override routed
//! through [`crate::config::apply_kv`], exactly like a `[run]`-table key.
//!
//! Expansion order is canonical and documented: grid blocks in file order;
//! within a block, nested loops over dataset → model → transport →
//! scenario → compress_up → compress_down → algo → rounds → local_iters →
//! alpha → gamma → p → seed → clients → sampled → faults → backends.
//! Every expanded unit is fully validated (registry
//! specs resolve, model/dataset dims agree, directional pipelines don't
//! collide with algorithm-embedded compressors) before anything runs, so a
//! typo fails the whole sweep up front instead of panicking inside a
//! worker thread.

use crate::compress::CompressorSpec;
use crate::config::{self, presets};
use crate::data::DatasetSpec;
use crate::fed::transport::parse_transport;
use crate::fed::{embedded_wire_specs, AlgorithmSpec, RunConfig};
use crate::model::ModelSpec;
use crate::util::toml::{self, TomlTable, TomlValue};

/// Version of the sweep-*file* schema this crate reads (`schema = 1` in a
/// sweep TOML). The *result* schema the sink writes is versioned
/// separately — see [`crate::sweep::sink::RESULT_SCHEMA`] (bumped to 2
/// when the summary gained `compress_up`/`compress_down` columns; sweep
/// files were unaffected).
pub const SCHEMA_VERSION: i64 = 1;

/// One `[[grid]]` block: registry axes plus scalar grids, with optional
/// per-block preset and fixed overrides. Empty axes inherit the base value.
#[derive(Debug, Clone, Default)]
pub struct GridBlock {
    /// Per-block `config::presets` starting point (overrides the sweep's).
    pub preset: Option<String>,
    /// Fixed per-block `[run]`-table overrides, applied in key order (the
    /// TOML table is sorted — don't set one setting through two alias
    /// keys like `gamma`/`lr`).
    pub fixed: Vec<(String, TomlValue)>,
    /// Algorithm registry specs (required, at least one).
    pub algos: Vec<String>,
    /// Model registry specs (`"default"` = the dataset's pairing).
    pub models: Vec<String>,
    /// Dataset registry specs.
    pub datasets: Vec<String>,
    /// Transport specs (`inproc`, `simnet[:...]`).
    pub transports: Vec<String>,
    /// Round-runtime scenario specs (`sync`,
    /// `semisync:<K>[@<staleness>]` — [`crate::fed::sim::Scenario`]
    /// grammar), stored canonicalized.
    pub scenarios: Vec<String>,
    /// Uplink compression pipeline specs
    /// ([`crate::compress::CompressorSpec`] grammar).
    pub compress_up: Vec<String>,
    /// Downlink compression pipeline specs.
    pub compress_down: Vec<String>,
    /// Communication-round counts.
    pub rounds: Vec<usize>,
    /// Local iterations per round (baseline algorithms' `local_steps`).
    pub local_iters: Vec<usize>,
    /// Dirichlet heterogeneity factors α.
    pub alphas: Vec<f64>,
    /// Learning rates γ.
    pub gammas: Vec<f64>,
    /// Scaffnew communication probabilities p.
    pub ps: Vec<f64>,
    /// RNG seeds.
    pub seeds: Vec<u64>,
    /// Federated population sizes (`n_clients`) — the million-client scale
    /// axis; the lazy partition/state store keep memory O(sampled).
    pub clients: Vec<usize>,
    /// Cohort sizes per round (`clients_per_round`).
    pub sampled: Vec<usize>,
    /// Fault-injection plans ([`crate::fed::faults::FaultSpec`] grammar),
    /// stored canonicalized.
    pub faults: Vec<String>,
    /// Compute-plane backend keys ([`crate::backend`] registry: `auto`,
    /// `native`, `native-simd`, `native-bf16`, `xla`; alias `pjrt`),
    /// stored canonicalized. An explicit axis entry pins the unit's plane
    /// and wins over the CLI `--backend`.
    pub backends: Vec<String>,
}

/// A parsed, not-yet-expanded sweep file.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Sweep name: the output subdirectory and run-id prefix.
    pub name: String,
    /// Human-readable one-liner shown by `sweep list` / `sweep run`.
    pub title: String,
    /// Paper figure/table this sweep reproduces (empty if none).
    pub paper: String,
    /// `config::presets` starting point (default `scaled-mnist`).
    pub preset: String,
    /// Fixed `[base]` overrides applied after the preset, in key order
    /// (the TOML table is sorted — don't set one setting through two
    /// alias keys like `gamma`/`lr`).
    pub base: Vec<(String, TomlValue)>,
    /// Cross-product blocks, expanded in file order.
    pub grids: Vec<GridBlock>,
}

/// One fully-resolved run of an expanded sweep: the algorithm + transport
/// registry specs and the complete [`RunConfig`]. Units are independent —
/// each seeds its own RNG streams from `cfg.seed`, so sweep results do not
/// depend on execution order or worker count.
#[derive(Debug, Clone)]
pub struct RunUnit {
    /// Position in the canonical expansion order (also the resume key).
    pub index: usize,
    /// Stable, filesystem-safe id: `r<index>-<algo slug>`.
    pub id: String,
    /// Algorithm registry spec, e.g. `fedcomloc-com:topk:0.1`.
    pub algo: String,
    /// Transport spec, e.g. `inproc` or `simnet:10:50:0.1:4`.
    pub transport: String,
    /// The run's complete configuration.
    pub cfg: RunConfig,
}

impl RunUnit {
    /// The effective model key (explicit override or dataset pairing).
    pub fn model_key(&self) -> String {
        self.cfg.model_spec().key().to_string()
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '-' {
                c.to_ascii_lowercase()
            } else {
                '_'
            }
        })
        .collect()
}

fn list_of_strings(key: &str, v: &TomlValue) -> Result<Vec<String>, String> {
    let one = |x: &TomlValue| {
        x.as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("sweep axis '{key}': expected string entries"))
    };
    match v {
        TomlValue::Arr(items) => items.iter().map(one).collect(),
        other => Ok(vec![one(other)?]),
    }
}

fn list_of_f64(key: &str, v: &TomlValue) -> Result<Vec<f64>, String> {
    let one = |x: &TomlValue| {
        x.as_f64()
            .ok_or_else(|| format!("sweep axis '{key}': expected numeric entries"))
    };
    match v {
        TomlValue::Arr(items) => items.iter().map(one).collect(),
        other => Ok(vec![one(other)?]),
    }
}

fn list_of_usize(key: &str, v: &TomlValue) -> Result<Vec<usize>, String> {
    let one = |x: &TomlValue| {
        x.as_usize()
            .ok_or_else(|| format!("sweep axis '{key}': expected non-negative integers"))
    };
    match v {
        TomlValue::Arr(items) => items.iter().map(one).collect(),
        other => Ok(vec![one(other)?]),
    }
}

impl GridBlock {
    fn from_table(table: &TomlTable) -> Result<GridBlock, String> {
        let mut block = GridBlock::default();
        for (key, value) in table {
            match key.as_str() {
                "preset" => {
                    let name = value
                        .as_str()
                        .ok_or_else(|| "grid 'preset' must be a string".to_string())?;
                    presets::by_name(name).ok_or_else(|| {
                        format!(
                            "unknown preset '{name}' (have: {})",
                            presets::names().join(", ")
                        )
                    })?;
                    block.preset = Some(name.to_string());
                }
                "algos" => block.algos = list_of_strings(key, value)?,
                "models" => block.models = list_of_strings(key, value)?,
                "datasets" => block.datasets = list_of_strings(key, value)?,
                "transports" => block.transports = list_of_strings(key, value)?,
                "scenarios" => block.scenarios = list_of_strings(key, value)?,
                "compress_up" => block.compress_up = list_of_strings(key, value)?,
                "compress_down" => block.compress_down = list_of_strings(key, value)?,
                "rounds" => block.rounds = list_of_usize(key, value)?,
                "local_iters" => block.local_iters = list_of_usize(key, value)?,
                "alphas" => block.alphas = list_of_f64(key, value)?,
                "gammas" => block.gammas = list_of_f64(key, value)?,
                "ps" => block.ps = list_of_f64(key, value)?,
                "seeds" => {
                    block.seeds = list_of_usize(key, value)?.into_iter().map(|s| s as u64).collect()
                }
                "clients" => block.clients = list_of_usize(key, value)?,
                "sampled" => block.sampled = list_of_usize(key, value)?,
                "faults" => block.faults = list_of_strings(key, value)?,
                "backends" => block.backends = list_of_strings(key, value)?,
                // Anything else is a fixed per-block run-config override;
                // config::apply_kv validates it at expansion time.
                _ => block.fixed.push((key.clone(), value.clone())),
            }
        }
        if block.algos.is_empty() {
            return Err("every [[grid]] block needs an 'algos' axis".to_string());
        }
        Ok(block)
    }

    /// Number of runs this block expands to.
    pub fn len(&self) -> usize {
        let axis = |n: usize| n.max(1);
        axis(self.datasets.len())
            * axis(self.models.len())
            * axis(self.transports.len())
            * axis(self.scenarios.len())
            * axis(self.compress_up.len())
            * axis(self.compress_down.len())
            * self.algos.len()
            * axis(self.rounds.len())
            * axis(self.local_iters.len())
            * axis(self.alphas.len())
            * axis(self.gammas.len())
            * axis(self.ps.len())
            * axis(self.seeds.len())
            * axis(self.clients.len())
            * axis(self.sampled.len())
            * axis(self.faults.len())
            * axis(self.backends.len())
    }

    /// True when the block expands to no runs (never, post-validation).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl SweepSpec {
    /// Parse a sweep document from TOML text.
    pub fn parse_str(text: &str) -> Result<SweepSpec, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let name = doc
            .get("", "name")
            .and_then(TomlValue::as_str)
            .ok_or_else(|| "sweep file needs a top-level string 'name'".to_string())?
            .to_string();
        if name.is_empty() || name != sanitize(&name) {
            return Err(format!(
                "sweep name '{name}' must be non-empty lowercase [a-z0-9.-_] (it names files)"
            ));
        }
        if let Some(v) = doc.get("", "schema") {
            match v.as_i64() {
                Some(SCHEMA_VERSION) => {}
                _ => {
                    return Err(format!(
                        "unsupported sweep schema {v:?} (this build reads schema = {SCHEMA_VERSION})"
                    ))
                }
            }
        }
        let title = doc
            .get("", "title")
            .and_then(TomlValue::as_str)
            .unwrap_or(&name)
            .to_string();
        let paper = doc
            .get("", "paper")
            .and_then(TomlValue::as_str)
            .unwrap_or("")
            .to_string();

        let mut preset = "scaled-mnist".to_string();
        let mut base = Vec::new();
        if let Some(table) = doc.tables.get("base") {
            for (key, value) in table {
                if key == "preset" {
                    let p = value
                        .as_str()
                        .ok_or_else(|| "base 'preset' must be a string".to_string())?;
                    presets::by_name(p).ok_or_else(|| {
                        format!("unknown preset '{p}' (have: {})", presets::names().join(", "))
                    })?;
                    preset = p.to_string();
                } else {
                    base.push((key.clone(), value.clone()));
                }
            }
        }

        // Strict schema: a stray key, table, or array (e.g. `alphas = […]`
        // at the top level instead of inside a [[grid]] block, or a
        // misspelled `[[gird]]`) must fail loudly, not silently shrink the
        // matrix the user believes they are sweeping.
        for key in doc.tables.get("").map(|t| t.keys()).into_iter().flatten() {
            if !matches!(key.as_str(), "name" | "title" | "paper" | "schema") {
                return Err(format!(
                    "unknown top-level key '{key}' (axes like '{key}' belong inside a [[grid]] block; \
                     top level takes name/title/paper/schema)"
                ));
            }
        }
        for table in doc.tables.keys() {
            if !matches!(table.as_str(), "" | "base") {
                return Err(format!("unknown table [{table}] (have: [base])"));
            }
        }
        for array in doc.arrays.keys() {
            if array != "grid" {
                return Err(format!("unknown array-of-tables [[{array}]] (have: [[grid]])"));
            }
        }

        let grid_tables = doc.array_of("grid");
        if grid_tables.is_empty() {
            return Err("sweep file needs at least one [[grid]] block".to_string());
        }
        let grids = grid_tables
            .iter()
            .enumerate()
            .map(|(i, t)| {
                GridBlock::from_table(t).map_err(|e| format!("[[grid]] block {}: {e}", i + 1))
            })
            .collect::<Result<Vec<_>, _>>()?;

        Ok(SweepSpec {
            name,
            title,
            paper,
            preset,
            base,
            grids,
        })
    }

    /// Load a sweep document from a file.
    pub fn load(path: &std::path::Path) -> Result<SweepSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        SweepSpec::parse_str(&text)
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Total number of runs across all grid blocks.
    pub fn num_runs(&self) -> usize {
        self.grids.iter().map(GridBlock::len).sum()
    }

    /// Expand every grid block into validated [`RunUnit`]s, in canonical
    /// order. `scale` multiplies rounds/dataset sizes exactly like the
    /// experiment presets' `--scale`; `seed_override` (the CLI `--seed`)
    /// replaces the base seed but loses to an explicit `seeds` axis.
    pub fn expand(&self, scale: f64, seed_override: Option<u64>) -> Result<Vec<RunUnit>, String> {
        let mut units = Vec::with_capacity(self.num_runs());
        for (bi, block) in self.grids.iter().enumerate() {
            self.expand_block(block, scale, seed_override, &mut units)
                .map_err(|e| format!("sweep '{}', [[grid]] block {}: {e}", self.name, bi + 1))?;
        }
        Ok(units)
    }

    fn base_cfg(&self, block: &GridBlock, scale: f64, seed_override: Option<u64>) -> Result<RunConfig, String> {
        let preset = block.preset.as_deref().unwrap_or(&self.preset);
        let mut cfg = presets::by_name(preset)
            .ok_or_else(|| format!("unknown preset '{preset}'"))?;
        for (key, value) in self.base.iter().chain(&block.fixed) {
            config::apply_kv(&mut cfg, key, value).map_err(|e| format!("key '{key}': {e}"))?;
        }
        if let Some(seed) = seed_override {
            cfg.seed = seed;
        }
        config::apply_scale(&mut cfg, scale);
        Ok(cfg)
    }

    fn expand_block(
        &self,
        block: &GridBlock,
        scale: f64,
        seed_override: Option<u64>,
        units: &mut Vec<RunUnit>,
    ) -> Result<(), String> {
        let base = self.base_cfg(block, scale, seed_override)?;

        // Pre-resolve the registry axes once per block.
        let datasets: Vec<Option<DatasetSpec>> = if block.datasets.is_empty() {
            vec![None]
        } else {
            block
                .datasets
                .iter()
                .map(|s| DatasetSpec::parse(s).map(Some))
                .collect::<Result<_, _>>()?
        };
        let models: Vec<Option<Option<ModelSpec>>> = if block.models.is_empty() {
            vec![None]
        } else {
            block
                .models
                .iter()
                .map(|s| {
                    if s == "default" {
                        Ok(Some(None))
                    } else {
                        ModelSpec::parse(s).map(|m| Some(Some(m)))
                    }
                })
                .collect::<Result<_, _>>()?
        };
        for algo in &block.algos {
            AlgorithmSpec::parse(algo)?;
        }
        let transports: Vec<Option<String>> = if block.transports.is_empty() {
            vec![None]
        } else {
            block.transports.iter().map(|t| Some(t.clone())).collect()
        };
        // Scenarios are stored canonicalized (staleness always explicit) so
        // summary keys and run ids are stable across equivalent spellings.
        let scenarios: Vec<Option<String>> = if block.scenarios.is_empty() {
            vec![None]
        } else {
            block
                .scenarios
                .iter()
                .map(|s| {
                    crate::fed::sim::Scenario::parse(s)
                        .map(|sc| Some(sc.key()))
                        .map_err(|e| format!("scenarios '{s}': {e}"))
                })
                .collect::<Result<_, _>>()?
        };
        let compress_axis = |axis: &[String], key: &str| -> Result<Vec<Option<String>>, String> {
            if axis.is_empty() {
                return Ok(vec![None]);
            }
            axis.iter()
                .map(|s| {
                    CompressorSpec::parse(s)
                        .map(|c| Some(c.key().to_string()))
                        .map_err(|e| format!("{key} '{s}': {e}"))
                })
                .collect()
        };
        let compress_up = compress_axis(&block.compress_up, "compress_up")?;
        let compress_down = compress_axis(&block.compress_down, "compress_down")?;
        // Fault plans are stored canonicalized (default retry/backoff knobs
        // elided) so summary keys and run ids are stable across equivalent
        // spellings.
        let faults: Vec<Option<String>> = if block.faults.is_empty() {
            vec![None]
        } else {
            block
                .faults
                .iter()
                .map(|s| {
                    crate::fed::faults::FaultSpec::parse(s)
                        .map(|f| Some(f.key()))
                        .map_err(|e| format!("faults '{s}': {e}"))
                })
                .collect::<Result<_, _>>()?
        };
        // Backend keys are validated against the registry and canonicalized
        // (`pjrt` → `xla`) up front, so a typo'd plane fails the whole
        // sweep before any run starts.
        let backends: Vec<Option<String>> = if block.backends.is_empty() {
            vec![None]
        } else {
            block
                .backends
                .iter()
                .map(|b| {
                    crate::backend::canonical_backend_key(b)
                        .map(Some)
                        .map_err(|e| format!("backends '{b}': {e}"))
                })
                .collect::<Result<_, _>>()?
        };

        let opt =
            |xs: &[usize]| -> Vec<Option<usize>> {
                if xs.is_empty() {
                    vec![None]
                } else {
                    xs.iter().map(|&x| Some(x)).collect()
                }
            };
        let optf = |xs: &[f64]| -> Vec<Option<f64>> {
            if xs.is_empty() {
                vec![None]
            } else {
                xs.iter().map(|&x| Some(x)).collect()
            }
        };
        let seeds: Vec<Option<u64>> = if block.seeds.is_empty() {
            vec![None]
        } else {
            block.seeds.iter().map(|&s| Some(s)).collect()
        };
        let (rounds, local_iters) = (opt(&block.rounds), opt(&block.local_iters));
        let (alphas, gammas, ps) = (optf(&block.alphas), optf(&block.gammas), optf(&block.ps));
        let (clients, sampled) = (opt(&block.clients), opt(&block.sampled));

        for dataset in &datasets {
            for model in &models {
                for transport in &transports {
                    for scenario in &scenarios {
                        for up in &compress_up {
                            for down in &compress_down {
                                for algo in &block.algos {
                                    for &r in &rounds {
                                        for &li in &local_iters {
                                            for &alpha in &alphas {
                                                for &gamma in &gammas {
                                                    for &p in &ps {
                                                        for &seed in &seeds {
                                                            for &nc in &clients {
                                                                for &mc in &sampled {
                                                                    let mut cfg = base.clone();
                                                                    if let Some(ds) = dataset {
                                                                        cfg.dataset = ds.clone();
                                                                    }
                                                                    if let Some(m) = model {
                                                                        cfg.model = m.clone();
                                                                    }
                                                                    if let Some(sc) = scenario {
                                                                        cfg.scenario = sc.clone();
                                                                    }
                                                                    if let Some(u) = up {
                                                                        cfg.compress_up = u.clone();
                                                                    }
                                                                    if let Some(dn) = down {
                                                                        cfg.compress_down = dn.clone();
                                                                    }
                                                                    if let Some(r) = r {
                                                                        cfg.rounds = r;
                                                                    }
                                                                    if let Some(li) = li {
                                                                        cfg.local_steps = li;
                                                                    }
                                                                    if let Some(a) = alpha {
                                                                        cfg.dirichlet_alpha = a;
                                                                    }
                                                                    if let Some(g) = gamma {
                                                                        cfg.gamma = g as f32;
                                                                    }
                                                                    if let Some(p) = p {
                                                                        cfg.p = p;
                                                                    }
                                                                    if let Some(s) = seed {
                                                                        cfg.seed = s;
                                                                    }
                                                                    if let Some(n) = nc {
                                                                        cfg.n_clients = n;
                                                                    }
                                                                    if let Some(m) = mc {
                                                                        cfg.clients_per_round = m;
                                                                    }
                                                                    let transport_spec = transport
                                                                        .clone()
                                                                        .unwrap_or_else(|| "inproc".to_string());
                                                                    for fault in &faults {
                                                                        for backend in &backends {
                                                                            let mut cfg = cfg.clone();
                                                                            if let Some(f) = fault {
                                                                                cfg.faults = f.clone();
                                                                            }
                                                                            if let Some(b) = backend {
                                                                                cfg.backend = b.clone();
                                                                            }
                                                                            validate_unit(&cfg, &transport_spec, algo)?;
                                                                            let index = units.len();
                                                                            // Scale axes suffix the id only when
                                                                            // actually swept, keeping legacy ids
                                                                            // byte-stable.
                                                                            let mut id = unit_id(index, algo, &cfg);
                                                                            if let Some(n) = nc {
                                                                                id.push_str(&format!("-n-{n}"));
                                                                            }
                                                                            if let Some(m) = mc {
                                                                                id.push_str(&format!("-m-{m}"));
                                                                            }
                                                                            units.push(RunUnit {
                                                                                index,
                                                                                id,
                                                                                algo: algo.clone(),
                                                                                transport: transport_spec.clone(),
                                                                                cfg,
                                                                            });
                                                                        }
                                                                    }
                                                                }
                                                            }
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Stable, filesystem-safe run id. Legacy shape (`r<idx>-<algo>`) when no
/// directional pipeline, scenario, fault plan, or backend pin is set; runs
/// that differ only in
/// `compress_up`/`compress_down`/`scenario`/`faults`/`backend` gain
/// `-u-<spec>` / `-d-<spec>` / `-s-<spec>` / `-f-<spec>` / `-b-<key>`
/// suffixes so ids stay unique (they key resume and the JSONL files).
fn unit_id(index: usize, algo: &str, cfg: &RunConfig) -> String {
    let mut id = format!("r{index:03}-{}", sanitize(algo));
    if cfg.scenario != "sync" {
        id.push_str(&format!("-s-{}", sanitize(&cfg.scenario)));
    }
    if cfg.faults != "none" {
        id.push_str(&format!("-f-{}", sanitize(&cfg.faults)));
    }
    if cfg.backend != "auto" {
        id.push_str(&format!("-b-{}", sanitize(&cfg.backend)));
    }
    if cfg.compress_up != "none" {
        id.push_str(&format!("-u-{}", sanitize(&cfg.compress_up)));
    }
    if cfg.compress_down != "none" {
        id.push_str(&format!("-d-{}", sanitize(&cfg.compress_down)));
    }
    id
}

/// The model/dataset/topology agreement checks `Federation::new` asserts,
/// surfaced as errors at expansion time so a bad combination fails the
/// sweep up front instead of panicking in a worker thread.
fn validate_unit(cfg: &RunConfig, transport: &str, algo: &str) -> Result<(), String> {
    parse_transport(transport, cfg.seed)?;
    crate::fed::faults::FaultSpec::parse(&cfg.faults)
        .map_err(|e| format!("faults '{}': {e}", cfg.faults))?;
    crate::backend::canonical_backend_key(&cfg.backend)?;
    let up = CompressorSpec::parse(&cfg.compress_up)
        .map_err(|e| format!("compress_up '{}': {e}", cfg.compress_up))?;
    let down = CompressorSpec::parse(&cfg.compress_down)
        .map_err(|e| format!("compress_down '{}': {e}", cfg.compress_down))?;
    // The same conflict `Federation::install_*_shim` panics on, as an
    // up-front error: an algorithm spec with an inline wire compressor
    // must not collide with an explicit directional pipeline.
    let (embed_up, embed_down) = embedded_wire_specs(algo)?;
    if let (Some(e), false) = (&embed_up, up.is_identity()) {
        return Err(format!(
            "uplink compressor conflict: algo '{algo}' embeds '{}' but compress_up='{}' is \
             also set; use a bare algo key with compress_up, or drop one",
            e.key(),
            cfg.compress_up
        ));
    }
    if let (Some(e), false) = (&embed_down, down.is_identity()) {
        return Err(format!(
            "downlink compressor conflict: algo '{algo}' embeds '{}' but compress_down='{}' \
             is also set; use a bare algo key with compress_down, or drop one",
            e.key(),
            cfg.compress_down
        ));
    }
    // Multi-stream algorithms (Scaffold's x/c, Δx/Δc pairs) reject
    // stateful pipelines: one ef(...) residual cannot serve interleaved
    // streams (the driver would also panic at setup — fail up front here).
    if crate::fed::multiplexes_streams(algo)? && (up.has_state() || down.has_state()) {
        return Err(format!(
            "algo '{algo}' ships multiple vectors per link; stateful ef(...) pipelines \
             are unsupported there (compress_up='{}', compress_down='{}')",
            cfg.compress_up, cfg.compress_down
        ));
    }
    if cfg.n_clients == 0 {
        return Err("n_clients must be at least 1".to_string());
    }
    if cfg.clients_per_round > cfg.n_clients {
        return Err(format!(
            "clients_per_round ({}) exceeds n_clients ({})",
            cfg.clients_per_round, cfg.n_clients
        ));
    }
    let scenario = crate::fed::sim::Scenario::parse(&cfg.scenario)
        .map_err(|e| format!("scenario '{}': {e}", cfg.scenario))?;
    if let crate::fed::sim::Scenario::Semisync { k, .. } = scenario {
        if k > cfg.clients_per_round {
            return Err(format!(
                "semisync K ({k}) exceeds clients_per_round ({}); the server cannot \
                 fold more arrivals than it samples",
                cfg.clients_per_round
            ));
        }
    }
    if cfg.rounds == 0 {
        return Err("rounds must be at least 1".to_string());
    }
    let model = cfg.model_spec();
    let built = model.build();
    if built.input_dim() != cfg.dataset.feature_dim() {
        return Err(format!(
            "model '{}' expects input dim {} but dataset '{}' provides {}",
            model.key(),
            built.input_dim(),
            cfg.dataset.key(),
            cfg.dataset.feature_dim()
        ));
    }
    if built.num_classes() != cfg.dataset.num_classes() {
        return Err(format!(
            "model '{}' emits {} classes but dataset '{}' has {}",
            model.key(),
            built.num_classes(),
            cfg.dataset.key(),
            cfg.dataset.num_classes()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
schema = 1
name = "tiny"
title = "tiny test sweep"

[base]
preset = "smoke"
train_n = 600
test_n = 150

[[grid]]
algos = ["fedavg", "scaffold"]
alphas = [0.1, 0.7]

[[grid]]
algos = ["fedcomloc-com:topk:0.5"]
rounds = 3
"#;

    #[test]
    fn parses_and_counts() {
        let spec = SweepSpec::parse_str(TINY).unwrap();
        assert_eq!(spec.name, "tiny");
        assert_eq!(spec.preset, "smoke");
        assert_eq!(spec.grids.len(), 2);
        assert_eq!(spec.grids[0].len(), 4);
        assert_eq!(spec.grids[1].len(), 1);
        assert_eq!(spec.num_runs(), 5);
    }

    #[test]
    fn expansion_is_canonical_and_validated() {
        let spec = SweepSpec::parse_str(TINY).unwrap();
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 5);
        // Canonical nesting: algo outside alpha.
        let got: Vec<(String, f64, usize)> = units
            .iter()
            .map(|u| (u.algo.clone(), u.cfg.dirichlet_alpha, u.cfg.rounds))
            .collect();
        assert_eq!(got[0], ("fedavg".to_string(), 0.1, 5));
        assert_eq!(got[1], ("fedavg".to_string(), 0.7, 5));
        assert_eq!(got[2], ("scaffold".to_string(), 0.1, 5));
        assert_eq!(got[3], ("scaffold".to_string(), 0.7, 5));
        assert_eq!(got[4], ("fedcomloc-com:topk:0.5".to_string(), 0.7, 3));
        // Base overrides land everywhere; ids are stable.
        assert!(units.iter().all(|u| u.cfg.train_n == 600));
        assert_eq!(units[0].id, "r000-fedavg");
        assert_eq!(units[4].id, "r004-fedcomloc-com_topk_0.5");
        // Index is the resume key: re-expansion reproduces it.
        let again = spec.expand(1.0, None).unwrap();
        assert!(units.iter().zip(&again).all(|(a, b)| a.id == b.id));
    }

    #[test]
    fn seed_override_loses_to_seed_axis() {
        let spec = SweepSpec::parse_str(
            "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nseeds = [7, 9]\n",
        )
        .unwrap();
        let units = spec.expand(1.0, Some(5)).unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[0].cfg.seed, 7);
        assert_eq!(units[1].cfg.seed, 9);
        let spec2 =
            SweepSpec::parse_str("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\n").unwrap();
        assert_eq!(spec2.expand(1.0, Some(5)).unwrap()[0].cfg.seed, 5);
    }

    #[test]
    fn scale_matches_experiment_semantics() {
        let spec =
            SweepSpec::parse_str("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\n").unwrap();
        let units = spec.expand(0.5, None).unwrap();
        // scaled-mnist default: rounds 60 -> 30, train 12000 -> 6000.
        assert_eq!(units[0].cfg.rounds, 30);
        assert_eq!(units[0].cfg.train_n, 6_000);
    }

    #[test]
    fn explicit_axis_wins_over_scale() {
        let spec = SweepSpec::parse_str(
            "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nrounds = [4]\n",
        )
        .unwrap();
        assert_eq!(spec.expand(0.5, None).unwrap()[0].cfg.rounds, 4);
    }

    #[test]
    fn bad_specs_fail_up_front() {
        for (toml, needle) in [
            ("[[grid]]\nalgos = [\"fedavg\"]\n", "name"),
            ("name = \"s\"\n", "[[grid]]"),
            ("name = \"s\"\nschema = 2\n[[grid]]\nalgos = [\"fedavg\"]\n", "schema"),
            ("name = \"s\"\n[[grid]]\nalphas = [0.1]\n", "algos"),
            ("name = \"s\"\n[[grid]]\nalgos = [\"wat\"]\n", "unknown algorithm"),
            ("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\ndatasets = [\"imagenet\"]\n", "unknown dataset"),
            ("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nmodels = [\"nope\"]\n", "unknown model"),
            ("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\ntransports = [\"pigeon\"]\n", "unknown transport"),
            ("name = \"s\"\n[base]\npreset = \"nope\"\n[[grid]]\nalgos = [\"fedavg\"]\n", "preset"),
            ("name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nwat = 1\n", "unknown key"),
            ("name = \"UPPER\"\n[[grid]]\nalgos = [\"fedavg\"]\n", "lowercase"),
            // Strays outside [base]/[[grid]] must fail loudly, not shrink
            // the matrix (top-level axis, misspelled table/array names).
            ("name = \"s\"\nseeds = [1, 2]\n[[grid]]\nalgos = [\"fedavg\"]\n", "top-level"),
            ("name = \"s\"\n[bass]\nrounds = 2\n[[grid]]\nalgos = [\"fedavg\"]\n", "unknown table"),
            ("name = \"s\"\n[[gird]]\nalgos = [\"x\"]\n[[grid]]\nalgos = [\"fedavg\"]\n", "unknown array"),
        ] {
            let err = SweepSpec::parse_str(toml)
                .and_then(|s| s.expand(1.0, None).map(|_| s))
                .map(|_| ())
                .unwrap_err();
            assert!(err.contains(needle), "toml: {toml}\nerr: {err}");
        }
    }

    #[test]
    fn compression_axes_grid_and_suffix_ids() {
        let spec = SweepSpec::parse_str(
            "name = \"c\"\n[[grid]]\nalgos = [\"fedcomloc-com\"]\n\
             compress_up = [\"none\", \"topk:0.1\", \"q8\", \"topk:0.1|q8\", \"ef(topk:0.1)\", \"sched:topk:0.3..0.05@cosine\"]\n\
             compress_down = [\"none\", \"q8\"]\n",
        )
        .unwrap();
        assert_eq!(spec.grids[0].len(), 12);
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 12);
        // Canonical nesting: up outer, down inner.
        assert_eq!(units[0].cfg.compress_up, "none");
        assert_eq!(units[0].cfg.compress_down, "none");
        assert_eq!(units[1].cfg.compress_down, "q8");
        assert_eq!(units[2].cfg.compress_up, "topk:0.1");
        // Ids stay unique and legacy-shaped when no pipeline is set.
        assert_eq!(units[0].id, "r000-fedcomloc-com");
        assert_eq!(units[1].id, "r001-fedcomloc-com-d-q8");
        assert_eq!(units[2].id, "r002-fedcomloc-com-u-topk_0.1");
        let mut ids: Vec<_> = units.iter().map(|u| u.id.clone()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 12);
    }

    #[test]
    fn compression_conflicts_and_bad_specs_fail_expansion() {
        for (toml, needle) in [
            // Axis colliding with an algorithm-embedded uplink compressor.
            (
                "name = \"c\"\n[[grid]]\nalgos = [\"fedcomloc-com:topk:0.3\"]\ncompress_up = [\"q8\"]\n",
                "uplink compressor conflict",
            ),
            (
                "name = \"c\"\n[[grid]]\nalgos = [\"fedcomloc-global:q8\"]\ncompress_down = [\"topk:0.3\"]\n",
                "downlink compressor conflict",
            ),
            (
                "name = \"c\"\n[[grid]]\nalgos = [\"sparsefedavg\"]\ncompress_up = [\"q8\"]\n",
                "uplink compressor conflict",
            ),
            (
                "name = \"c\"\n[[grid]]\nalgos = [\"fedavg\"]\ncompress_up = [\"wat\"]\n",
                "unknown compressor",
            ),
            // Multi-stream algorithms reject stateful pipelines up front.
            (
                "name = \"c\"\n[[grid]]\nalgos = [\"scaffold\"]\ncompress_up = [\"ef(topk:0.1)\"]\n",
                "multiple vectors per link",
            ),
        ] {
            let err = SweepSpec::parse_str(toml)
                .and_then(|s| s.expand(1.0, None).map(|_| ()))
                .unwrap_err();
            assert!(err.contains(needle), "toml: {toml}\nerr: {err}");
        }
        // Non-conflicting combinations pass: -Com embedded up + explicit down.
        let ok = SweepSpec::parse_str(
            "name = \"c\"\n[[grid]]\nalgos = [\"fedcomloc-com:topk:0.1\"]\ncompress_down = [\"q8\"]\n",
        )
        .unwrap();
        assert_eq!(ok.expand(1.0, None).unwrap().len(), 1);
    }

    #[test]
    fn compression_keys_as_fixed_overrides_still_work() {
        // Scalar (non-axis) usage routes through the same grid axis path.
        let spec = SweepSpec::parse_str(
            "name = \"c\"\n[base]\ncompress_down = \"q8\"\n[[grid]]\nalgos = [\"fedavg\"]\ncompress_up = \"topk:0.5\"\n",
        )
        .unwrap();
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].cfg.compress_up, "topk:0.5");
        assert_eq!(units[0].cfg.compress_down, "q8");
    }

    #[test]
    fn scenario_axis_expands_canonicalizes_and_suffixes_ids() {
        let spec = SweepSpec::parse_str(
            "name = \"s\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             scenarios = [\"sync\", \"semisync:2\", \"semisync:2@1\"]\n",
        )
        .unwrap();
        assert_eq!(spec.grids[0].len(), 3);
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 3);
        assert_eq!(units[0].cfg.scenario, "sync");
        // Omitted staleness canonicalizes to an explicit 0.5.
        assert_eq!(units[1].cfg.scenario, "semisync:2@0.5");
        assert_eq!(units[2].cfg.scenario, "semisync:2@1");
        // Sync keeps the legacy id shape; semisync runs gain -s- suffixes.
        assert_eq!(units[0].id, "r000-fedavg");
        assert_eq!(units[1].id, "r001-fedavg-s-semisync_2_0.5");
        assert_eq!(units[2].id, "r002-fedavg-s-semisync_2_1");
    }

    #[test]
    fn scenario_validation_fails_expansion_up_front() {
        for (toml, needle) in [
            (
                "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nscenarios = [\"async\"]\n",
                "unknown scenario",
            ),
            (
                "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nscenarios = [\"semisync:0\"]\n",
                "K must be",
            ),
            // smoke preset samples 3 of 10 clients: K = 5 cannot fold.
            (
                "name = \"s\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
                 scenarios = [\"semisync:5\"]\n",
                "exceeds clients_per_round",
            ),
        ] {
            let err = SweepSpec::parse_str(toml)
                .and_then(|s| s.expand(1.0, None).map(|_| ()))
                .unwrap_err();
            assert!(err.contains(needle), "toml: {toml}\nerr: {err}");
        }
    }

    #[test]
    fn faults_axis_expands_canonicalizes_and_suffixes_ids() {
        let spec = SweepSpec::parse_str(
            "name = \"f\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             faults = [\"none\", \"corrupt:0.02|retry:2|backoff:0.5\", \"crash:0.1|quorum:0.6\"]\n",
        )
        .unwrap();
        assert_eq!(spec.grids[0].len(), 3);
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 3);
        assert_eq!(units[0].cfg.faults, "none");
        // Default retry/backoff knobs are elided by canonicalization.
        assert_eq!(units[1].cfg.faults, "corrupt:0.02");
        assert_eq!(units[2].cfg.faults, "crash:0.1|quorum:0.6");
        // "none" keeps the legacy id shape; active plans gain -f- suffixes.
        assert_eq!(units[0].id, "r000-fedavg");
        assert_eq!(units[1].id, "r001-fedavg-f-corrupt_0.02");
        assert_eq!(units[2].id, "r002-fedavg-f-crash_0.1_quorum_0.6");
        // A malformed plan fails the whole sweep up front.
        let err = SweepSpec::parse_str(
            "name = \"f\"\n[[grid]]\nalgos = [\"fedavg\"]\nfaults = [\"jitter:0.5\"]\n",
        )
        .and_then(|s| s.expand(1.0, None).map(|_| ()))
        .unwrap_err();
        assert!(err.contains("unknown fault clause"), "{err}");
    }

    #[test]
    fn backends_axis_expands_canonicalizes_and_suffixes_ids() {
        let spec = SweepSpec::parse_str(
            "name = \"b\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             backends = [\"auto\", \"native-simd\", \"pjrt\"]\n",
        )
        .unwrap();
        assert_eq!(spec.grids[0].len(), 3);
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 3);
        assert_eq!(units[0].cfg.backend, "auto");
        assert_eq!(units[1].cfg.backend, "native-simd");
        // The pjrt alias canonicalizes to the registry key.
        assert_eq!(units[2].cfg.backend, "xla");
        // "auto" keeps the legacy id shape; pinned planes gain -b- suffixes.
        assert_eq!(units[0].id, "r000-fedavg");
        assert_eq!(units[1].id, "r001-fedavg-b-native-simd");
        assert_eq!(units[2].id, "r002-fedavg-b-xla");
        // An unknown plane fails the whole sweep up front.
        let err = SweepSpec::parse_str(
            "name = \"b\"\n[[grid]]\nalgos = [\"fedavg\"]\nbackends = [\"cuda\"]\n",
        )
        .and_then(|s| s.expand(1.0, None).map(|_| ()))
        .unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
    }

    #[test]
    fn scale_axes_expand_suffix_ids_and_validate() {
        // clients/sampled are real axes: they multiply out (innermost,
        // after seeds), land in the config, and suffix the unit id.
        let spec = SweepSpec::parse_str(
            "name = \"n\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             clients = [1000000, 10000000]\nsampled = [100]\n",
        )
        .unwrap();
        assert_eq!(spec.grids[0].len(), 2);
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[0].cfg.n_clients, 1_000_000);
        assert_eq!(units[1].cfg.n_clients, 10_000_000);
        assert!(units.iter().all(|u| u.cfg.clients_per_round == 100));
        assert_eq!(units[0].id, "r000-fedavg-n-1000000-m-100");
        assert_eq!(units[1].id, "r001-fedavg-n-10000000-m-100");
        // A scalar spelling works like a one-element axis.
        let scalar = SweepSpec::parse_str(
            "name = \"n\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             clients = 50\n",
        )
        .unwrap();
        let u = scalar.expand(1.0, None).unwrap();
        assert_eq!(u[0].cfg.n_clients, 50);
        assert_eq!(u[0].id, "r000-fedavg-n-50");
        // Sweeping only `sampled` keeps the base population and never
        // suffixes -n-.
        let only_m = SweepSpec::parse_str(
            "name = \"n\"\n[base]\npreset = \"smoke\"\n[[grid]]\nalgos = [\"fedavg\"]\n\
             sampled = [2, 3]\n",
        )
        .unwrap();
        let u = only_m.expand(1.0, None).unwrap();
        assert_eq!(u.len(), 2);
        assert_eq!(u[0].id, "r000-fedavg-m-2");
    }

    #[test]
    fn scale_axis_oversampling_fails_expansion_up_front() {
        for (toml, needle) in [
            // A cohort larger than the population must fail at expansion,
            // not panic inside Federation::new — including at the
            // million-client scale where only the axes make it plausible.
            (
                "name = \"n\"\n[[grid]]\nalgos = [\"fedavg\"]\nclients = [100]\nsampled = [101]\n",
                "exceeds n_clients",
            ),
            (
                "name = \"n\"\n[[grid]]\nalgos = [\"fedavg\"]\nclients = [1000000]\nsampled = [1000001]\n",
                "exceeds n_clients",
            ),
            (
                "name = \"n\"\n[[grid]]\nalgos = [\"fedavg\"]\nclients = [0]\n",
                "n_clients must be",
            ),
            (
                "name = \"n\"\n[[grid]]\nalgos = [\"fedavg\"]\nclients = [-5]\n",
                "non-negative",
            ),
        ] {
            let err = SweepSpec::parse_str(toml)
                .and_then(|s| s.expand(1.0, None).map(|_| ()))
                .unwrap_err();
            assert!(err.contains(needle), "toml: {toml}\nerr: {err}");
        }
    }

    #[test]
    fn model_dataset_mismatch_rejected_at_expansion() {
        let spec = SweepSpec::parse_str(
            "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nmodels = [\"linear:64\"]\n",
        )
        .unwrap();
        let err = spec.expand(1.0, None).unwrap_err();
        assert!(err.contains("input dim"), "{err}");
    }

    #[test]
    fn models_default_keyword_restores_pairing() {
        let spec = SweepSpec::parse_str(
            "name = \"s\"\n[[grid]]\nalgos = [\"fedavg\"]\nmodels = [\"default\", \"linear:784\"]\n",
        )
        .unwrap();
        let units = spec.expand(1.0, None).unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[0].model_key(), "mlp");
        assert_eq!(units[1].model_key(), "linear:784");
    }
}
