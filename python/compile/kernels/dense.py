"""L1 Pallas kernel: fused dense layer y = act(x @ W + b).

The MXU-shaped hot-spot of the MLP forward/backward. Blocking follows the
classic TPU schedule: grid over (M/bm, N/bn) output tiles; each grid step
loads an (bm, K) x-panel and a (K, bn) W-panel into VMEM, runs one MXU
matmul accumulating in f32, adds the bias row, applies the activation, and
writes the (bm, bn) tile. K stays unblocked — for this model family
K ≤ 1600, so the VMEM footprint per step is

    bm·K + K·bn + bm·bn floats ≤ 128·1600·2 + 128·128 ≈ 1.7 MiB ≪ 16 MiB,

leaving headroom for double-buffering (see DESIGN.md §8 for the MXU
utilization estimates). Ragged M/N are handled by padding to tile multiples
and slicing the result; zero-padding is exact for matmul+bias.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv

BM = 128
BN = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _dense_impl(x, w, b, activation: str):
    """The raw pallas_call (no AD) — see `dense` for the public entry."""
    assert activation in ("none", "relu")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    b = b.astype(jnp.float32)

    bm = min(BM, m) if m % BM else BM
    bn = min(BN, n) if n % BN else BN
    # Pad M and N up to tile multiples (K needs no padding: it is unblocked).
    mp = cdiv(m, bm) * bm
    np_ = cdiv(n, bn) * bn
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    if np_ != n:
        w = jnp.pad(w, ((0, 0), (0, np_ - n)))
        b = jnp.pad(b, (0, np_ - n))
    b2 = b.reshape(1, np_)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b2)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Custom VJP: pallas_call does not support reverse-mode AD, so the backward
# pass is written by hand — and itself runs through the same Pallas kernel,
# which is exactly what a production TPU stack does (fwd and bwd matmuls
# share one audited schedule):
#   dX = dY' @ Wᵀ,  dW = Xᵀ @ dY',  db = Σ_rows dY',
# with dY' = dY ⊙ 1[y > 0] when the activation is ReLU.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dense(x, w, b, activation):
    return _dense_impl(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    y = _dense_impl(x, w, b, activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dy = jnp.where(y > 0, dy, jnp.zeros_like(dy))
    zeros_k = jnp.zeros((x.shape[1],), jnp.float32)
    zeros_n = jnp.zeros((w.shape[1],), jnp.float32)
    dx = _dense_impl(dy, w.T, zeros_k, "none")
    dw = _dense_impl(x.T, dy, zeros_n, "none")
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


_dense.defvjp(_dense_fwd, _dense_bwd)


def dense(x, w, b, activation: str = "none"):
    """y = act(x @ w + b); x:[M,K] f32, w:[K,N], b:[N]; act ∈ {none, relu}.

    Differentiable (custom VJP above); both passes run the Pallas kernel.
    """
    return _dense(x, w, b, activation)
