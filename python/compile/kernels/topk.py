"""L1 Pallas kernel: TopK magnitude masking (paper Definition 3.1).

The selection itself (finding the K-th largest magnitude) is a global sort —
left to XLA's optimized `sort` on the full vector. What Pallas owns is the
bandwidth-bound piece: the elementwise threshold mask over the d-vector,
streamed through VMEM one block at a time. `topk(x, density)` composes the
two, so FedComLoc-Local's in-graph C(x) lowers into the same HLO module as
the training step.

Ties at the threshold keep ≥K entries (Definition 3.1 allows any
minimizer); the Rust wire codec breaks ties deterministically instead.
"""

import jax.numpy as jnp

from . import common


def _mask_kernel(x_ref, t_ref, o_ref):
    t = t_ref[0, 0]
    x = x_ref[...]
    o_ref[...] = jnp.where(jnp.abs(x) >= t, x, jnp.zeros_like(x))


def mask(x, threshold):
    """Zero entries with |x| < threshold (flat f32 vector)."""
    assert x.ndim == 1
    return common.elementwise_call(
        _mask_kernel, jnp.float32, x.astype(jnp.float32), scalars=(threshold,)
    )


def threshold_for_density(x, density):
    """|value| of the K-th largest-magnitude entry, K = clip(⌈density·d⌉,1,d).

    Density may be a traced scalar (it is a runtime input of the
    `*_train_step_local` artifacts). density ≥ 1 selects the global min
    magnitude, i.e. the mask keeps everything.

    Implementation: *exact* selection by binary search over the f32 bit
    space — for non-negative floats the IEEE-754 bit pattern is monotone in
    value, so building the threshold MSB-first with 32 count-reductions
    finds the largest t with |{i : |x_i| ≥ t}| ≥ K, which is exactly the
    K-th largest magnitude. This replaced a full jnp.sort (d log d with a
    large constant: 290 ms for the CNN's d=744k on this testbed vs ~15 ms
    for the 32 passes — EXPERIMENTS.md §Perf).
    """
    from jax import lax

    flat = x.reshape(-1)
    d = flat.shape[0]
    k = jnp.clip(
        jnp.ceil(jnp.asarray(density, jnp.float32) * d).astype(jnp.int32), 1, d
    )
    mags = lax.bitcast_convert_type(jnp.abs(flat), jnp.uint32)

    def body(i, t):
        bit = jnp.uint32(1) << (jnp.uint32(31) - jnp.uint32(i))
        cand = t | bit
        count = jnp.sum((mags >= cand).astype(jnp.int32))
        return jnp.where(count >= k, cand, t)

    t_bits = lax.fori_loop(0, 32, body, jnp.uint32(0))
    return lax.bitcast_convert_type(t_bits, jnp.float32)


def topk(x, density):
    """TopK by density ratio: mask(x, threshold_for_density(x, density))."""
    return mask(x, threshold_for_density(x, density))
