//! Minimal TOML parser for experiment and sweep config files.
//!
//! Supports the subset the config and sweep systems use: `[table]` headers
//! (one level, dotted keys inside a table are not needed), `[[array]]`
//! array-of-tables headers (one level — the `[[grid]]` blocks of sweep
//! specs), `key = value` pairs with strings, integers, floats, booleans,
//! and flat arrays of scalars, plus `#` comments. Values are surfaced as
//! [`TomlValue`]; the typed layers above (`config/`, `sweep/`) do schema
//! validation and defaulting.

use std::collections::BTreeMap;

/// A parsed TOML scalar or flat array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer (no `.`/exponent in the literal).
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// An array of values.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// The string slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value (floats as-is, integers widened), if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    /// The integer, if this is an integer literal.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    /// The integer as usize, if integral and non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    /// The boolean, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// One `key = value` table (used both for `[name]` tables and for each
/// element of a `[[name]]` array of tables).
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed document: top-level keys live in table "" (empty string);
/// `[[name]]` blocks accumulate, in file order, under `arrays`.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    /// `[name]` tables (and the implicit top-level table "").
    pub tables: BTreeMap<String, TomlTable>,
    /// `[[name]]` arrays of tables, in file order.
    pub arrays: BTreeMap<String, Vec<TomlTable>>,
}

impl TomlDoc {
    /// Look up `key` in `[table]` ("" = top level).
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    /// All `[[name]]` blocks, in file order (empty slice if none).
    pub fn array_of(&self, name: &str) -> &[TomlTable] {
        self.arrays.get(name).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// TOML parse failure with 1-based line number.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line of the failure.
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TOML parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Where subsequent `key = value` lines land.
enum Target {
    /// A `[name]` table ("" = top level).
    Table(String),
    /// The latest element of a `[[name]]` array of tables.
    Array(String),
}

/// Parse a TOML document (see module docs for the supported subset).
pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut current = Target::Table(String::new());
    doc.tables.entry(String::new()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| err("unterminated array-of-tables header"))?
                .trim();
            if name.is_empty() {
                return Err(err("empty array-of-tables name"));
            }
            doc.arrays.entry(name.to_string()).or_default().push(TomlTable::new());
            current = Target::Array(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated table header"))?
                .trim();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            doc.tables.entry(name.to_string()).or_default();
            current = Target::Table(name.to_string());
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        let table = match &current {
            Target::Table(name) => doc.tables.get_mut(name).unwrap(),
            Target::Array(name) => doc.arrays.get_mut(name).unwrap().last_mut().unwrap(),
        };
        table.insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)?;
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            out.push(parse_value(&item)?);
        }
        return Ok(TomlValue::Arr(out));
    }
    // Number: int unless it contains '.', 'e', or 'E'.
    let numeric = s.replace('_', "");
    if numeric.contains('.') || numeric.contains('e') || numeric.contains('E') {
        numeric
            .parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| format!("invalid float '{s}'"))
    } else {
        numeric
            .parse::<i64>()
            .map(TomlValue::Int)
            .map_err(|_| format!("invalid value '{s}'"))
    }
}

fn split_top_level(s: &str) -> Result<Vec<String>, String> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1).ok_or("unbalanced ']'")?,
            ',' if !in_str && depth == 0 => {
                items.push(s[start..i].trim().to_string());
                start = i + 1;
            }
            _ => {}
        }
    }
    let last = s[start..].trim();
    if !last.is_empty() {
        items.push(last.to_string());
    }
    Ok(items)
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let text = r#"
# experiment config
name = "table1"   # inline comment
seed = 42
lr = 0.05

[data]
dataset = "fedmnist"
alpha = 0.7
clients = 100

[compress]
kind = "topk"
densities = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0]
enabled = true
"#;
        let doc = parse(text).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str().unwrap(), "table1");
        assert_eq!(doc.get("", "seed").unwrap().as_i64().unwrap(), 42);
        assert_eq!(doc.get("", "lr").unwrap().as_f64().unwrap(), 0.05);
        assert_eq!(doc.get("data", "clients").unwrap().as_usize().unwrap(), 100);
        assert!(doc.get("compress", "enabled").unwrap().as_bool().unwrap());
        let arr = doc.get("compress", "densities").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 6);
        assert_eq!(arr[0].as_f64().unwrap(), 0.1);
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let doc = parse("s = \"a#b\\nc\"").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str().unwrap(), "a#b\nc");
    }

    #[test]
    fn int_underscores_and_negatives() {
        let doc = parse("a = 1_000_000\nb = -3\nc = -2.5e-1").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64().unwrap(), 1_000_000);
        assert_eq!(doc.get("", "b").unwrap().as_i64().unwrap(), -3);
        assert_eq!(doc.get("", "c").unwrap().as_f64().unwrap(), -0.25);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[table\nx = 1").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn array_of_tables_accumulate_in_order() {
        let text = r#"
name = "sweep"

[base]
rounds = 5

[[grid]]
algos = ["fedavg"]
alphas = [0.1, 0.7]

[[grid]]
algos = ["scaffold"]
rounds = 9
"#;
        let doc = parse(text).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str().unwrap(), "sweep");
        assert_eq!(doc.get("base", "rounds").unwrap().as_usize().unwrap(), 5);
        let grids = doc.array_of("grid");
        assert_eq!(grids.len(), 2);
        assert_eq!(
            grids[0].get("algos").unwrap().as_arr().unwrap()[0]
                .as_str()
                .unwrap(),
            "fedavg"
        );
        assert_eq!(grids[0].get("alphas").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(grids[1].get("rounds").unwrap().as_usize().unwrap(), 9);
        assert!(doc.array_of("nope").is_empty());
    }

    #[test]
    fn array_of_tables_header_errors() {
        assert_eq!(parse("[[grid]\nx = 1").unwrap_err().line, 1);
        assert_eq!(parse("[[ ]]").unwrap_err().line, 1);
    }

    #[test]
    fn empty_and_nested_arrays() {
        let doc = parse("a = []\nb = [[1, 2], [3]]").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_arr().unwrap().len(), 0);
        let b = doc.get("", "b").unwrap().as_arr().unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].as_arr().unwrap()[1].as_i64().unwrap(), 2);
    }
}
