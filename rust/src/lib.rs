//! # FedComLoc
//!
//! Communication-efficient federated training of sparse and quantized
//! models — a production-grade reproduction of Yi, Meinhardt, Condat &
//! Richtárik, *FedComLoc* (2024), as a three-layer Rust + JAX + Pallas
//! stack (AOT via XLA/PJRT).
//!
//! ## Layer map
//! * **L3 — this crate**: the federated runtime ([`fed`]): the
//!   [`fed::FedAlgorithm`] trait with Scaffnew/FedComLoc and all baselines,
//!   self-describing wire messages ([`fed::message`]) over pluggable
//!   transports ([`fed::transport`]) with exact bit accounting
//!   ([`compress`]), Dirichlet-partitioned data ([`data`]), metrics
//!   ([`metrics`]) and the experiment registry ([`experiments`]).
//!   Algorithms ([`fed::AlgorithmSpec`]), models ([`model::ModelSpec`]
//!   over the composable [`model::Layer`] API), datasets
//!   ([`data::DatasetSpec`]), and compression pipelines
//!   ([`compress::CompressorSpec`] — chains, error feedback, schedules,
//!   per-direction via `compress_up`/`compress_down`) are all string-keyed
//!   open registries. ARCHITECTURE.md documents the fed-layer APIs and
//!   both substrates.
//! * **L2 — `python/compile`**: JAX models (MLP/CNN over flat parameter
//!   vectors) AOT-lowered to HLO text, executed via [`runtime`] (PJRT).
//! * **L1 — `python/compile/kernels`**: Pallas kernels (fused dense layer,
//!   Scaffnew update, TopK mask, stochastic quantizer) with jnp oracles.
//!
//! Python never runs at training time; see DESIGN.md for the system
//! inventory and README.md for a quickstart.
//!
//! The paper's empirical section is driven by the declarative [`sweep`]
//! engine: every figure/table is a TOML under `experiments/` expanded over
//! the three registries (EXPERIMENTS.md maps figures to commands).

// Public API documentation is enforced for the domain layers (fed, sweep,
// compress, model, data, metrics, config, experiments) and, since the
// workspace/perf pass, for the substrate layers `util` and `runtime` the
// compute core borders on; `cli` and `tensor` still opt out below until
// their own documentation pass.
#![warn(missing_docs)]

pub mod backend;
pub mod ckpt;
#[allow(missing_docs)]
pub mod cli;
pub mod compress;
pub mod config;
pub mod data;
pub mod experiments;
pub mod fed;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sweep;
#[allow(missing_docs)]
pub mod tensor;
pub mod util;
