//! CNN for FedCIFAR10 (paper Appendix A.1; the FedLab reference net):
//! conv5×5(3→32) → ReLU → maxpool2 → conv5×5(32→64) → ReLU → maxpool2 →
//! fc 1600→384 → ReLU → fc 384→192 → ReLU → fc 192→10; softmax CE loss.
//!
//! Flat layout (must match `python/compile/models/cnn.py`):
//! `[Wc1 32×75 | bc1 32 | Wc2 64×800 | bc2 64 | W3 1600×384 | b3 384 |
//!   W4 384×192 | b4 192 | W5 192×10 | b5 10]`
//! — conv weights OIHW flattened to [out_ch × in_ch·k·k], dense weights
//! row-major [in][out]. Activations are NCHW; the conv output is flattened
//! channel-major to feed fc1.

use super::ops::{self, ConvShape};
use crate::util::rng::Rng;

pub const IN_CH: usize = 3;
pub const SIDE: usize = 32;
pub const C1: usize = 32;
pub const C2: usize = 64;
pub const K: usize = 5;
pub const FC_IN: usize = C2 * 5 * 5; // 1600 after two conv+pool stages
pub const F1: usize = 384;
pub const F2: usize = 192;
pub const OUT: usize = 10;

pub const DIM: usize = C1 * IN_CH * K * K
    + C1
    + C2 * C1 * K * K
    + C2
    + FC_IN * F1
    + F1
    + F1 * F2
    + F2
    + F2 * OUT
    + OUT;

pub const CONV1: ConvShape = ConvShape {
    in_ch: IN_CH,
    out_ch: C1,
    in_h: SIDE,
    in_w: SIDE,
    k: K,
};
// After conv1 (28×28) and pool (14×14):
pub const CONV2: ConvShape = ConvShape {
    in_ch: C1,
    out_ch: C2,
    in_h: 14,
    in_w: 14,
    k: K,
};

#[derive(Debug, Clone, Copy)]
pub struct Slices {
    pub wc1: (usize, usize),
    pub bc1: (usize, usize),
    pub wc2: (usize, usize),
    pub bc2: (usize, usize),
    pub w3: (usize, usize),
    pub b3: (usize, usize),
    pub w4: (usize, usize),
    pub b4: (usize, usize),
    pub w5: (usize, usize),
    pub b5: (usize, usize),
}

pub const fn slices() -> Slices {
    let wc1 = (0, C1 * IN_CH * K * K);
    let bc1 = (wc1.1, wc1.1 + C1);
    let wc2 = (bc1.1, bc1.1 + C2 * C1 * K * K);
    let bc2 = (wc2.1, wc2.1 + C2);
    let w3 = (bc2.1, bc2.1 + FC_IN * F1);
    let b3 = (w3.1, w3.1 + F1);
    let w4 = (b3.1, b3.1 + F1 * F2);
    let b4 = (w4.1, w4.1 + F2);
    let w5 = (b4.1, b4.1 + F2 * OUT);
    let b5 = (w5.1, w5.1 + OUT);
    Slices {
        wc1,
        bc1,
        wc2,
        bc2,
        w3,
        b3,
        w4,
        b4,
        w5,
        b5,
    }
}

pub fn init(rng: &mut Rng) -> Vec<f32> {
    let s = slices();
    let mut p = vec![0.0f32; DIM];
    let fan_c1 = (IN_CH * K * K) as f32;
    let fan_c2 = (C1 * K * K) as f32;
    rng.fill_normal_f32(&mut p[s.wc1.0..s.wc1.1], 0.0, (2.0 / fan_c1).sqrt());
    rng.fill_normal_f32(&mut p[s.wc2.0..s.wc2.1], 0.0, (2.0 / fan_c2).sqrt());
    rng.fill_normal_f32(&mut p[s.w3.0..s.w3.1], 0.0, (2.0f32 / FC_IN as f32).sqrt());
    rng.fill_normal_f32(&mut p[s.w4.0..s.w4.1], 0.0, (2.0f32 / F1 as f32).sqrt());
    rng.fill_normal_f32(&mut p[s.w5.0..s.w5.1], 0.0, (2.0f32 / F2 as f32).sqrt());
    p
}

/// Forward activations cached for backward.
pub struct Cache {
    pub y1: Vec<f32>,     // conv1+relu out  [b, 32, 28, 28]
    pub p1: Vec<f32>,     // pool1 out       [b, 32, 14, 14]
    pub arg1: Vec<u32>,   // pool1 argmax
    pub y2: Vec<f32>,     // conv2+relu out  [b, 64, 10, 10]
    pub p2: Vec<f32>,     // pool2 out       [b, 64, 5, 5] == fc input
    pub arg2: Vec<u32>,   // pool2 argmax
    pub a3: Vec<f32>,     // fc1+relu        [b, 384]
    pub a4: Vec<f32>,     // fc2+relu        [b, 192]
    pub logits: Vec<f32>, // [b, 10]
}

pub fn forward(params: &[f32], x: &[f32], batch: usize) -> Cache {
    debug_assert_eq!(params.len(), DIM);
    debug_assert_eq!(x.len(), batch * IN_CH * SIDE * SIDE);
    let s = slices();

    let mut y1 = vec![0.0f32; batch * C1 * 28 * 28];
    let mut col1 = vec![0.0f32; CONV1.col_rows() * CONV1.col_cols()];
    ops::conv2d_forward(
        x,
        &params[s.wc1.0..s.wc1.1],
        &params[s.bc1.0..s.bc1.1],
        &CONV1,
        batch,
        &mut y1,
        &mut col1,
    );
    ops::relu_inplace(&mut y1);
    let mut p1 = vec![0.0f32; batch * C1 * 14 * 14];
    let mut arg1 = vec![0u32; p1.len()];
    ops::maxpool2_forward(&y1, batch * C1, 28, 28, &mut p1, &mut arg1);

    let mut y2 = vec![0.0f32; batch * C2 * 10 * 10];
    let mut col2 = vec![0.0f32; CONV2.col_rows() * CONV2.col_cols()];
    ops::conv2d_forward(
        &p1,
        &params[s.wc2.0..s.wc2.1],
        &params[s.bc2.0..s.bc2.1],
        &CONV2,
        batch,
        &mut y2,
        &mut col2,
    );
    ops::relu_inplace(&mut y2);
    let mut p2 = vec![0.0f32; batch * C2 * 5 * 5];
    let mut arg2 = vec![0u32; p2.len()];
    ops::maxpool2_forward(&y2, batch * C2, 10, 10, &mut p2, &mut arg2);

    // p2 viewed as [batch × FC_IN] (channel-major flatten).
    let mut a3 = vec![0.0f32; batch * F1];
    ops::matmul(&p2, &params[s.w3.0..s.w3.1], &mut a3, batch, FC_IN, F1);
    ops::add_bias(&mut a3, &params[s.b3.0..s.b3.1], batch, F1);
    ops::relu_inplace(&mut a3);

    let mut a4 = vec![0.0f32; batch * F2];
    ops::matmul(&a3, &params[s.w4.0..s.w4.1], &mut a4, batch, F1, F2);
    ops::add_bias(&mut a4, &params[s.b4.0..s.b4.1], batch, F2);
    ops::relu_inplace(&mut a4);

    let mut logits = vec![0.0f32; batch * OUT];
    ops::matmul(&a4, &params[s.w5.0..s.w5.1], &mut logits, batch, F2, OUT);
    ops::add_bias(&mut logits, &params[s.b5.0..s.b5.1], batch, OUT);

    Cache {
        y1,
        p1,
        arg1,
        y2,
        p2,
        arg2,
        a3,
        a4,
        logits,
    }
}

pub fn grad(params: &[f32], x: &[f32], y: &[i32]) -> (Vec<f32>, f32) {
    let batch = y.len();
    let s = slices();
    let cache = forward(params, x, batch);
    let (loss, dz5) = ops::softmax_cross_entropy(&cache.logits, y, OUT);

    let mut g = vec![0.0f32; DIM];
    // fc3
    ops::matmul_at_b(&cache.a4, &dz5, &mut g[s.w5.0..s.w5.1], F2, batch, OUT);
    ops::bias_grad(&dz5, &mut g[s.b5.0..s.b5.1], batch, OUT);
    let mut da4 = vec![0.0f32; batch * F2];
    ops::matmul_a_bt(&dz5, &params[s.w5.0..s.w5.1], &mut da4, batch, OUT, F2);
    ops::relu_backward_inplace(&mut da4, &cache.a4);

    // fc2
    ops::matmul_at_b(&cache.a3, &da4, &mut g[s.w4.0..s.w4.1], F1, batch, F2);
    ops::bias_grad(&da4, &mut g[s.b4.0..s.b4.1], batch, F2);
    let mut da3 = vec![0.0f32; batch * F1];
    ops::matmul_a_bt(&da4, &params[s.w4.0..s.w4.1], &mut da3, batch, F2, F1);
    ops::relu_backward_inplace(&mut da3, &cache.a3);

    // fc1
    ops::matmul_at_b(&cache.p2, &da3, &mut g[s.w3.0..s.w3.1], FC_IN, batch, F1);
    ops::bias_grad(&da3, &mut g[s.b3.0..s.b3.1], batch, F1);
    let mut dp2 = vec![0.0f32; batch * FC_IN];
    ops::matmul_a_bt(&da3, &params[s.w3.0..s.w3.1], &mut dp2, batch, F1, FC_IN);

    // pool2 -> conv2
    let mut dy2 = vec![0.0f32; batch * C2 * 10 * 10];
    ops::maxpool2_backward(&dp2, &cache.arg2, &mut dy2);
    ops::relu_backward_inplace(&mut dy2, &cache.y2);
    let mut dp1 = vec![0.0f32; batch * C1 * 14 * 14];
    {
        let mut col = vec![0.0f32; CONV2.col_rows() * CONV2.col_cols()];
        let mut dcol = vec![0.0f32; col.len()];
        let (gw, rest) = g[s.wc2.0..s.bc2.1].split_at_mut(s.wc2.1 - s.wc2.0);
        ops::conv2d_backward(
            &cache.p1,
            &params[s.wc2.0..s.wc2.1],
            &dy2,
            &CONV2,
            batch,
            gw,
            rest,
            Some(&mut dp1),
            &mut col,
            &mut dcol,
        );
    }

    // pool1 -> conv1 (no dx needed at the input)
    let mut dy1 = vec![0.0f32; batch * C1 * 28 * 28];
    ops::maxpool2_backward(&dp1, &cache.arg1, &mut dy1);
    ops::relu_backward_inplace(&mut dy1, &cache.y1);
    {
        let mut col = vec![0.0f32; CONV1.col_rows() * CONV1.col_cols()];
        let mut dcol = vec![0.0f32; col.len()];
        let (gw, rest) = g[s.wc1.0..s.bc1.1].split_at_mut(s.wc1.1 - s.wc1.0);
        ops::conv2d_backward(
            x,
            &params[s.wc1.0..s.wc1.1],
            &dy1,
            &CONV1,
            batch,
            gw,
            rest,
            None,
            &mut col,
            &mut dcol,
        );
    }

    (g, loss)
}

pub fn eval_batch(params: &[f32], x: &[f32], y: &[i32], valid: usize) -> (f64, usize) {
    let batch = y.len();
    let cache = forward(params, x, batch);
    (
        ops::cross_entropy_sum(&cache.logits, y, OUT, valid),
        ops::count_correct(&cache.logits, y, OUT, valid),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(batch: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let x: Vec<f32> = (0..batch * IN_CH * SIDE * SIDE)
            .map(|_| rng.uniform_f32())
            .collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();
        (x, y)
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        let p = init(&mut rng);
        let (x, _) = toy(2, &mut rng);
        let c = forward(&p, &x, 2);
        assert_eq!(c.logits.len(), 20);
        assert_eq!(c.p2.len(), 2 * FC_IN);
    }

    #[test]
    fn gradient_matches_numeric_spot_check() {
        let mut rng = Rng::seed_from_u64(2);
        let p = init(&mut rng);
        let (x, y) = toy(2, &mut rng);
        let (g, loss) = grad(&p, &x, &y);
        assert!(loss > 0.0);
        let s = slices();
        let eps = 5e-3f32;
        let picks = [
            s.wc1.0 + 11,
            s.bc1.0 + 3,
            s.wc2.0 + 101,
            s.bc2.0 + 5,
            s.w3.0 + 1234,
            s.b3.0 + 17,
            s.w4.0 + 99,
            s.w5.0 + 42,
            s.b5.0 + 1,
        ];
        for &i in &picks {
            let mut pp = p.clone();
            pp[i] += eps;
            let (_, lp) = grad(&pp, &x, &y);
            let mut pm = p.clone();
            pm[i] -= eps;
            let (_, lm) = grad(&pm, &x, &y);
            let num = (lp - lm) / (2.0 * eps);
            // Finite differences cross ReLU/maxpool kinks for the early conv
            // layers, so the tolerance is looser than for the smooth blocks.
            let tol = 0.15 * num.abs().max(0.05);
            assert!(
                (num - g[i]).abs() < tol,
                "param {i}: numeric {num} analytic {}",
                g[i]
            );
        }
    }

    #[test]
    fn sgd_reduces_loss_on_fixed_batch() {
        let mut rng = Rng::seed_from_u64(3);
        let mut p = init(&mut rng);
        let (x, y) = toy(8, &mut rng);
        let (_, first) = grad(&p, &x, &y);
        let mut last = first;
        for _ in 0..15 {
            let (g, l) = grad(&p, &x, &y);
            crate::tensor::axpy(-0.05, &g, &mut p);
            last = l;
        }
        assert!(last < first * 0.7, "loss did not drop: {first} -> {last}");
    }
}
