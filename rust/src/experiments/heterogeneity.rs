//! Table 2 + Figures 2/12: Dirichlet heterogeneity × sparsity grid.
//!
//! α ∈ {0.1, 0.3, 0.5, 0.7, 0.9, 1.0} × K ∈ {10%, 50%, 100%} on FedMNIST
//! with FedComLoc-Com; prints the paper's accuracy grid and the per-α drop
//! from unsparsified to K=10% (observation (a) of §4.2).

use super::{fedcomloc_topk_spec, ExpOptions};
use crate::fed::{run as fed_run, RunConfig};

pub const ALPHAS: [f64; 6] = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0];
pub const DENSITIES: [f64; 3] = [1.0, 0.10, 0.50];

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let base = opts.scale_cfg(RunConfig::default_mnist());
    let trainer = opts.trainer_for(&base);
    let mut grid: Vec<(f64, Vec<Option<f64>>)> = Vec::new();

    for &density in &DENSITIES {
        let mut row = Vec::new();
        for &alpha in &ALPHAS {
            let cfg = RunConfig {
                dirichlet_alpha: alpha,
                ..opts.scale_cfg(RunConfig::default_mnist())
            };
            let spec = super::algo(&fedcomloc_topk_spec(density))?;
            log::info!("table2: alpha {alpha} density {density}");
            let log = fed_run(&cfg, trainer.clone(), &spec);
            let acc = log.best_accuracy().unwrap_or(0.0);
            opts.save("table2", &log);
            row.push(Some(acc));
        }
        grid.push((density, row));
    }

    let header: Vec<String> = ALPHAS.iter().map(|a| format!("α={a}")).collect();
    let rows: Vec<(String, Vec<Option<f64>>)> = grid
        .iter()
        .map(|(d, row)| (format!("K={:.0}%", d * 100.0), row.clone()))
        .collect();
    super::print_accuracy_table(
        "Table 2: test accuracy across Dirichlet α and sparsity K (FedMNIST)",
        &header,
        &rows,
    );

    // Observation (a): relative drop unsparsified -> K=10% per α.
    if let (Some((_, full)), Some((_, sparse))) = (
        grid.iter().find(|(d, _)| *d >= 1.0),
        grid.iter().find(|(d, _)| (*d - 0.10).abs() < 1e-9),
    ) {
        println!("\nRelative drop (K=100% → K=10%) per α:");
        for (i, &alpha) in ALPHAS.iter().enumerate() {
            if let (Some(f), Some(s)) = (full[i], sparse[i]) {
                println!("  α={alpha}: {:.2}%", (f - s) / f.max(1e-9) * 100.0);
            }
        }
    }
    let _ = base;
    Ok(())
}
