//! Deterministic synthetic stand-ins for MNIST / CIFAR-10.
//!
//! The paper's experiments need a 10-class image dataset whose federated
//! partitions produce heterogeneous, learnable local objectives. We build
//! class-conditional generative models with enough intra-class variation
//! that the tasks are non-trivial (a linear model does not saturate them)
//! yet cheap to generate:
//!
//! * each class has `MODES` sub-prototypes, smooth low-frequency random
//!   fields (sums of 2-D cosines with class-specific spectra) — this gives
//!   images local spatial correlation like natural digits/photos;
//! * a sample picks a mode, scales it by a random amplitude, applies a
//!   small random translation (±2 px), and adds pixel noise;
//! * CIFAR-like data correlates the three channels through a class hue.
//!
//! Pixel range is [0, 1] after the same normalization the real loaders use,
//! so model code is agnostic to which source produced the data.

use super::{Dataset, DatasetKind, TrainTest};
use crate::util::rng::Rng;

const MODES: usize = 3;

/// Class-conditional generator parameters for one (class, mode) pair.
struct Prototype {
    /// Full-resolution single-channel field in [0,1].
    field: Vec<f32>,
    side: usize,
}

fn make_prototype(side: usize, rng: &mut Rng) -> Prototype {
    // Sum of random low-frequency cosines: smooth blobs, distinct per draw.
    let waves = 6;
    let params: Vec<(f32, f32, f32, f32)> = (0..waves)
        .map(|_| {
            (
                rng.uniform_range(0.5, 3.5) as f32,                    // fx
                rng.uniform_range(0.5, 3.5) as f32,                    // fy
                rng.uniform_range(0.0, std::f64::consts::TAU) as f32,  // phase
                rng.uniform_range(0.4, 1.0) as f32,                    // amplitude
            )
        })
        .collect();
    let mut field = vec![0.0f32; side * side];
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for y in 0..side {
        for x in 0..side {
            let (u, v) = (x as f32 / side as f32, y as f32 / side as f32);
            let mut s = 0.0;
            for &(fx, fy, ph, amp) in &params {
                s += amp * (std::f32::consts::TAU * (fx * u + fy * v) + ph).cos();
            }
            field[y * side + x] = s;
            lo = lo.min(s);
            hi = hi.max(s);
        }
    }
    let span = (hi - lo).max(1e-6);
    for p in &mut field {
        *p = (*p - lo) / span;
    }
    Prototype { field, side }
}

impl Prototype {
    /// Sample the field at (x, y) with an integer translation, clamped.
    #[inline]
    fn at(&self, x: i32, y: i32) -> f32 {
        let cx = x.clamp(0, self.side as i32 - 1) as usize;
        let cy = y.clamp(0, self.side as i32 - 1) as usize;
        self.field[cy * self.side + cx]
    }
}

/// Generate a train/test pair. Labels are balanced (round-robin) before
/// shuffling so Dirichlet partitions see the full class palette.
pub fn generate(kind: DatasetKind, train_n: usize, test_n: usize, rng: &mut Rng) -> TrainTest {
    let classes = kind.num_classes();
    let (side, channels) = match kind {
        DatasetKind::Mnist => (28usize, 1usize),
        DatasetKind::Cifar10 => (32usize, 3usize),
    };
    // Build the generator bank once from a derived stream so train and test
    // come from the *same* distribution.
    let mut proto_rng = rng.derive(0xB10B);
    let protos: Vec<Vec<Prototype>> = (0..classes)
        .map(|_| (0..MODES).map(|_| make_prototype(side, &mut proto_rng)).collect())
        .collect();
    // Class hue rotation for multi-channel data.
    let hues: Vec<[f32; 3]> = (0..classes)
        .map(|c| {
            let theta = c as f32 / classes as f32 * std::f32::consts::TAU;
            [
                0.6 + 0.4 * theta.cos(),
                0.6 + 0.4 * (theta + 2.1).cos(),
                0.6 + 0.4 * (theta + 4.2).cos(),
            ]
        })
        .collect();

    let make_split = |n: usize, rng: &mut Rng| -> Dataset {
        let dim = kind.feature_dim();
        let mut features = vec![0.0f32; n * dim];
        let mut labels = vec![0u8; n];
        // Balanced labels, then shuffle example order.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for (slot, &i) in order.iter().enumerate() {
            let class = slot % classes;
            labels[i] = class as u8;
            let proto = &protos[class][rng.below_usize(MODES)];
            let amp = rng.uniform_range(0.7, 1.3) as f32;
            let (dx, dy) = (
                rng.below(5) as i32 - 2, // ±2 px translation
                rng.below(5) as i32 - 2,
            );
            let noise_std = 0.12f32;
            let base = i * dim;
            for ch in 0..channels {
                let gain = if channels == 1 { 1.0 } else { hues[class][ch] };
                for y in 0..side {
                    for x in 0..side {
                        let v = proto.at(x as i32 + dx, y as i32 + dy) * amp * gain
                            + rng.normal_f32(0.0, noise_std);
                        features[base + ch * side * side + y * side + x] = v.clamp(0.0, 1.0);
                    }
                }
            }
        }
        Dataset {
            kind,
            features,
            labels,
            feature_dim: dim,
            num_classes: classes,
        }
    };

    let mut train_rng = rng.derive(0x7124);
    let mut test_rng = rng.derive(0x7E57);
    TrainTest {
        train: make_split(train_n, &mut train_rng),
        test: make_split(test_n, &mut test_rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(kind: DatasetKind, n: usize) -> TrainTest {
        let mut rng = Rng::seed_from_u64(42);
        generate(kind, n, n / 4, &mut rng)
    }

    #[test]
    fn shapes_and_ranges() {
        let tt = gen(DatasetKind::Mnist, 400);
        assert_eq!(tt.train.len(), 400);
        assert_eq!(tt.train.features.len(), 400 * 784);
        assert!(tt.train.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(tt.train.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn labels_balanced() {
        let tt = gen(DatasetKind::Mnist, 1000);
        let counts = tt.train.class_counts();
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen(DatasetKind::Mnist, 100);
        let b = gen(DatasetKind::Mnist, 100);
        assert_eq!(a.train.features, b.train.features);
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn classes_are_separable_by_centroid() {
        // A nearest-class-centroid classifier on train centroids must beat
        // chance by a wide margin on test — i.e. the task is learnable.
        let tt = gen(DatasetKind::Mnist, 2000);
        let d = tt.train.feature_dim;
        let mut centroids = vec![vec![0.0f64; d]; 10];
        let mut counts = [0usize; 10];
        for i in 0..tt.train.len() {
            let (x, y) = tt.train.example(i);
            counts[y as usize] += 1;
            for (c, &v) in centroids[y as usize].iter_mut().zip(x) {
                *c += v as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            c.iter_mut().for_each(|v| *v /= n as f64);
        }
        let mut correct = 0;
        for i in 0..tt.test.len() {
            let (x, y) = tt.test.example(i);
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = centroids[a]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    let db: f64 = centroids[b]
                        .iter()
                        .zip(x)
                        .map(|(c, &v)| (c - v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / tt.test.len() as f64;
        assert!(acc > 0.5, "centroid accuracy too low: {acc}");
    }

    #[test]
    fn not_trivially_constant_within_class() {
        // Within-class variance must be non-negligible (modes + noise),
        // otherwise the FL dynamics would be unrealistically easy.
        let tt = gen(DatasetKind::Mnist, 500);
        let (x0, y0) = tt.train.example(0);
        let mut max_dist = 0.0f32;
        for i in 1..tt.train.len() {
            let (xi, yi) = tt.train.example(i);
            if yi == y0 {
                let dist = crate::tensor::l2_distance(x0, xi);
                max_dist = max_dist.max(dist);
            }
        }
        assert!(max_dist > 1.0, "within-class spread too small: {max_dist}");
    }

    #[test]
    fn cifar_has_three_correlated_channels() {
        let tt = gen(DatasetKind::Cifar10, 100);
        assert_eq!(tt.train.feature_dim, 3072);
        let (x, _) = tt.train.example(0);
        let (r, g) = (&x[0..1024], &x[1024..2048]);
        // channels share the spatial field -> strongly correlated
        let corr = correlation(r, g);
        assert!(corr > 0.3, "channel correlation {corr}");
    }

    fn correlation(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            cov += (x as f64 - ma) * (y as f64 - mb);
            va += (x as f64 - ma).powi(2);
            vb += (y as f64 - mb).powi(2);
        }
        cov / (va.sqrt() * vb.sqrt()).max(1e-12)
    }
}
