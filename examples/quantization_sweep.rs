//! Quantization scenario (paper §4.4): Q_r sweep on FedMNIST with exact
//! wire accounting, plus a double-compression configuration (Appendix B.3).
//!
//!     cargo run --release --example quantization_sweep

use fedcomloc::compress::parse_spec;
use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
use fedcomloc::model::native::NativeTrainer;
use fedcomloc::model::LocalTrainer;
use std::sync::Arc;

fn main() {
    let cfg = RunConfig {
        rounds: 40,
        train_n: 8_000,
        test_n: 1_500,
        eval_every: 5,
        ..RunConfig::default_mnist()
    };
    let trainer = Arc::new(NativeTrainer::from_spec("mlp").unwrap());
    let dim = trainer.dim();

    let cases: Vec<(&str, &str)> = vec![
        ("fp32 baseline", "none"),
        ("Q_16", "q:16"),
        ("Q_8", "q:8"),
        ("Q_4", "q:4"),
        ("TopK25% + Q_8", "topk:0.25+q:8"),
    ];

    println!(
        "{:<16}{:>10}{:>14}{:>14}{:>18}",
        "compressor", "best_acc", "final_loss", "uplink_MB", "bits/coord (wire)"
    );
    for (label, comp_spec) in cases {
        let compressor = parse_spec(comp_spec).unwrap();
        let bits_per_coord = compressor.nominal_bits(dim) as f64 / dim as f64;
        let spec = AlgorithmSpec::parse(&format!("fedcomloc-com:{comp_spec}")).unwrap();
        let log = run(&cfg, trainer.clone(), &spec);
        println!(
            "{label:<16}{:>10.4}{:>14.4}{:>14.2}{:>18.2}",
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits() as f64 / 8e6,
            bits_per_coord,
        );
        let _ = log.save(std::path::Path::new("results/example_quant"));
    }
    println!("\npaper reading (Fig 5): 16-bit ≈ free; 8-bit minor loss; 4-bit visible degradation.");
}
