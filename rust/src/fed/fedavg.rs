//! FedAvg (McMahan et al., 2016/2017) and sparseFedAvg (paper §4.7) as a
//! [`FedAlgorithm`].
//!
//! Round shape: the drive loop samples S_r; the server broadcasts x over
//! the transport — through the federation's downlink
//! [`crate::compress::Pipeline`] when one is configured, so participants
//! train from the decoded (lossy) model and `downlink_bits` reflects the
//! actual codec; each participant runs E local SGD steps (no control
//! variates — h is ignored by passing zeros); clients upload their model
//! through their uplink pipeline (TopK for sparseFedAvg, exactly mirroring
//! FedComLoc-Com's wire format so the Fig. 9 bits-axis comparison is
//! apples-to-apples); the server averages the delivered updates.

use super::algorithm::{AlgoState, FedAlgorithm, RoundCtx, RoundOutcome};
use super::message::{Message, SERVER};
use super::{Federation, RunConfig};
use crate::compress::CompressorSpec;
use crate::util::rng::Rng;

/// FedAvg; an `identity` compressor gives vanilla FedAvg, TopK gives the
/// paper's sparseFedAvg.
pub struct FedAvg {
    /// Inline uplink compressor spec (the sparseFedAvg shim).
    spec: CompressorSpec,
    zeros: Vec<f32>,
    /// Server-side randomness for a stochastic downlink codec.
    server_rng: Rng,
    /// Per-round decoded-uplink buffers, reused across rounds.
    delivery: Vec<Vec<f32>>,
}

impl FedAvg {
    /// FedAvg whose uplinks cross the wire through `spec`.
    pub fn new(spec: CompressorSpec) -> FedAvg {
        FedAvg {
            spec,
            zeros: Vec::new(),
            server_rng: Rng::seed_from_u64(0),
            delivery: Vec::new(),
        }
    }

    fn algo_name(&self) -> String {
        if self.spec.is_identity() {
            "fedavg".to_string()
        } else {
            format!("sparsefedavg[{}]", self.spec.name())
        }
    }
}

impl FedAlgorithm for FedAvg {
    fn name(&self) -> String {
        self.algo_name()
    }

    fn log_name(&self, fed: &Federation, cfg: &RunConfig) -> String {
        format!("{}-{}-a{}", self.algo_name(), fed.model.name(), cfg.dirichlet_alpha)
    }

    fn log_meta(&self, cfg: &RunConfig) -> Vec<(String, String)> {
        vec![
            ("algorithm".into(), self.algo_name()),
            ("gamma".into(), cfg.gamma.to_string()),
            ("local_steps".into(), cfg.local_steps.to_string()),
            ("alpha".into(), cfg.dirichlet_alpha.to_string()),
        ]
    }

    fn setup(&mut self, fed: &mut Federation, cfg: &RunConfig) {
        fed.install_uplink_shim(&self.spec, cfg);
        self.zeros = vec![0.0f32; fed.x.len()];
        self.server_rng = fed.rng.derive(0x0D01_1AF5);
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundOutcome {
        let cfg = ctx.cfg;
        let round = ctx.round;
        let msg = Message::through(
            round,
            SERVER,
            &ctx.fed.x,
            &mut ctx.fed.downlink,
            &mut self.server_rng,
        );
        let participants = ctx.transport.broadcast(&ctx.sampled, &msg);
        let x = msg.to_dense();

        let trainer = ctx.fed.trainer.clone();
        let gamma = cfg.gamma;
        let local_steps = cfg.local_steps;
        let zeros = &self.zeros;
        let d = x.len();
        let results: Vec<(Message, f64)> = ctx.map_clients_ws(&participants, |ci, state, ws| {
            let mut xi = ws.take_xi_primed(&x);
            let mut loss_sum = 0.0f64;
            // Empty shards (million-client populations smaller than the
            // dataset leave most clients without examples) skip local
            // training: the client echoes the broadcast model back.
            if !state.loader.is_empty() {
                for _ in 0..local_steps {
                    let batch = state.loader.next_batch();
                    let loss = trainer.train_step_into(&xi[..d], zeros, &batch, gamma, ws);
                    std::mem::swap(&mut xi, &mut ws.step);
                    loss_sum += loss as f64;
                }
            }
            let upload =
                Message::through(round, ci as u32, &xi[..d], &mut state.up, &mut state.rng);
            ws.put_xi(xi);
            (upload, loss_sum)
        });

        let loss_sum: f64 = results.iter().map(|(_, l)| l).sum();
        let n_trained = results.len();
        let mut used = 0usize;
        for ((upload, _), &ci) in results.into_iter().zip(&participants) {
            if let Some(received) = ctx.transport.uplink(ci, upload) {
                if self.delivery.len() == used {
                    self.delivery.push(Vec::new());
                }
                received.to_dense_into(&mut self.delivery[used]);
                used += 1;
            }
        }
        if used > 0 {
            let rows: Vec<&[f32]> = self.delivery[..used].iter().map(|v| v.as_slice()).collect();
            crate::tensor::mean_into(&rows, &mut ctx.fed.x);
        }

        RoundOutcome {
            local_steps: cfg.local_steps,
            train_loss: loss_sum / (n_trained * cfg.local_steps).max(1) as f64,
        }
    }

    fn save_state(&self) -> AlgoState {
        // The downlink codec stream is the only cross-round server state
        // (`zeros` is shape-only and rebuilt by `setup`).
        let mut state = AlgoState::new();
        state.push_rng("server_rng", &self.server_rng);
        state
    }

    fn restore_state(&mut self, mut state: AlgoState) -> Result<(), String> {
        self.server_rng = state.take_rng("server_rng")?;
        state.finish()
    }
}
