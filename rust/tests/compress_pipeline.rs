//! End-to-end pins for the directional compression pipeline API (ISSUE 5):
//!
//! * the legacy algorithm-embedded compressor shim (`fedcomloc-com:<spec>`,
//!   `fedcomloc-global:<spec>`) is **bit-identical** to the same pipeline
//!   configured through `compress_up`/`compress_down`;
//! * `downlink_bits` flows from the actual downlink codec's `CodecMeta`:
//!   uncompressed broadcasts report exactly the seed's dense accounting,
//!   compressed broadcasts exactly the codec's wire bits;
//! * stateful (`ef`) and scheduled pipelines run end-to-end through every
//!   driver shape, with `compress_into` twins byte-identical to the owned
//!   forms even through dirty reused buffers;
//! * an annealing sparsity schedule shows up in the per-round bit series.

use fedcomloc::compress::{dense_bits, CompressorSpec};
use fedcomloc::data::DatasetSpec;
use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
use fedcomloc::metrics::MetricsLog;
use fedcomloc::util::rng::Rng;
use std::path::Path;

/// Fast convex workload (softmax on flat synthetic Gaussians, d = 132).
fn tiny_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetSpec::parse("synthetic:32-c4").unwrap(),
        train_n: 400,
        test_n: 100,
        n_clients: 6,
        clients_per_round: 3,
        rounds: 4,
        eval_every: 4,
        batch_size: 16,
        eval_batch: 32,
        ..RunConfig::default_mnist()
    }
}

fn run_cfg(cfg: &RunConfig, algo: &str) -> MetricsLog {
    let trainer = fedcomloc::runtime::build_trainer(
        "native",
        Path::new("artifacts"),
        &cfg.model_spec(),
    );
    run(cfg, trainer, &AlgorithmSpec::parse(algo).unwrap())
}

/// Every deterministic RoundRecord field (wall_secs is real time).
fn assert_records_identical(a: &MetricsLog, b: &MetricsLog, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: round count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.local_steps, rb.local_steps, "{what} round {}", ra.round);
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{what} round {}",
            ra.round
        );
        assert_eq!(
            ra.test_loss.map(f64::to_bits),
            rb.test_loss.map(f64::to_bits),
            "{what} round {}",
            ra.round
        );
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "{what} round {}", ra.round);
        assert_eq!(ra.downlink_bits, rb.downlink_bits, "{what} round {}", ra.round);
        assert_eq!(ra.cum_uplink_bits, rb.cum_uplink_bits, "{what} round {}", ra.round);
        assert_eq!(ra.cum_downlink_bits, rb.cum_downlink_bits, "{what} round {}", ra.round);
    }
}

#[test]
fn uplink_shim_is_bit_identical_to_compress_up_config() {
    let cfg = tiny_cfg();
    let legacy = run_cfg(&cfg, "fedcomloc-com:topk:0.3");
    let mut directional = cfg.clone();
    directional.compress_up = "topk:0.3".to_string();
    let via_config = run_cfg(&directional, "fedcomloc-com");
    assert_records_identical(&legacy, &via_config, "uplink shim vs compress_up");
    // The chained spelling, both grammars.
    let legacy_chain = run_cfg(&cfg, "fedcomloc-com:topk:0.25+q:4");
    let mut chain_cfg = cfg.clone();
    chain_cfg.compress_up = "topk:0.25|q4".to_string();
    let via_chain = run_cfg(&chain_cfg, "fedcomloc");
    assert_records_identical(&legacy_chain, &via_chain, "chain shim vs compress_up");
}

#[test]
fn downlink_shim_is_bit_identical_to_compress_down_config() {
    let cfg = tiny_cfg();
    let legacy = run_cfg(&cfg, "fedcomloc-global:q:8");
    let mut directional = cfg.clone();
    directional.compress_down = "q:8".to_string();
    let via_config = run_cfg(&directional, "fedcomloc-com");
    assert_records_identical(&legacy, &via_config, "downlink shim vs compress_down");
}

#[test]
fn uncompressed_downlink_reports_exactly_the_seed_dense_bits() {
    // The "dense broadcast" regression pin: with no downlink codec every
    // driver must report exactly sampled × 32·d downlink bits per round
    // (Scaffold 2×), the seed's accounting.
    let cfg = tiny_cfg();
    let d = cfg.model_spec().build().dim();
    for (algo, per_client_msgs) in
        [("fedcomloc-com:topk:0.3", 1u64), ("fedavg", 1), ("feddyn:0.01", 1), ("scaffold", 2)]
    {
        let log = run_cfg(&cfg, algo);
        for r in &log.records {
            assert_eq!(
                r.downlink_bits,
                cfg.clients_per_round as u64 * per_client_msgs * dense_bits(d),
                "{algo} round {}",
                r.round
            );
        }
    }
}

#[test]
fn compressed_downlink_bits_equal_the_codec_meta_exactly() {
    // q8's wire size is input-independent for a nonzero model
    // (32·⌈d/B⌉ + d·(r+2) bits), so the per-round downlink accounting can
    // be pinned in closed form: participants × codec wire bits.
    let mut cfg = tiny_cfg();
    cfg.compress_down = "q8".to_string();
    let d = cfg.model_spec().build().dim() as u64;
    let q8_bits = 32 * d.div_ceil(1024) + d * 10;
    for algo in ["fedavg", "feddyn:0.01"] {
        let log = run_cfg(&cfg, algo);
        for r in &log.records {
            assert_eq!(
                r.downlink_bits,
                cfg.clients_per_round as u64 * q8_bits,
                "{algo} round {}",
                r.round
            );
        }
    }
    // Scaffold ships two compressed vectors per direction... but c starts
    // at zero: the zero vector's q8 payload is the bucket-norm header
    // alone, and the accounting must follow the *actual* per-message meta,
    // not a nominal estimate.
    let log = run_cfg(&cfg, "scaffold");
    let zero_vec_bits = 32 * d.div_ceil(1024);
    let r0 = &log.records[0];
    assert_eq!(
        r0.downlink_bits,
        cfg.clients_per_round as u64 * (q8_bits + zero_vec_bits),
        "scaffold round 0: x compressed + zero c header only"
    );
}

#[test]
fn ef_and_scheduled_pipelines_run_through_every_driver_shape() {
    let mut cfg = tiny_cfg();
    cfg.compress_up = "ef(topk:0.2)".to_string();
    cfg.compress_down = "sched:q:8..4@linear".to_string();
    // Scaffold multiplexes two vectors per link, so it takes a *stateless*
    // uplink instead (EF rejection is pinned separately below).
    let mut scaffold_cfg = cfg.clone();
    scaffold_cfg.compress_up = "topk:0.2|q8".to_string();
    for algo in ["fedcomloc-com", "fedavg", "scaffold", "feddyn:0.01"] {
        let cfg = if algo == "scaffold" { &scaffold_cfg } else { &cfg };
        let log = run_cfg(cfg, algo);
        assert_eq!(log.records.len(), cfg.rounds, "{algo}");
        for r in &log.records {
            assert!(r.train_loss.is_finite(), "{algo} round {}", r.round);
            assert!(r.uplink_bits > 0 && r.downlink_bits > 0, "{algo}");
            // EF'd TopK uplink stays under dense.
            let d = cfg.model_spec().build().dim();
            assert!(
                r.uplink_bits
                    < cfg.clients_per_round as u64 * 2 * dense_bits(d),
                "{algo} round {}",
                r.round
            );
        }
        // Determinism: the same config reproduces the same records.
        let again = run_cfg(cfg, algo);
        assert_records_identical(&log, &again, algo);
    }
}

#[test]
#[should_panic(expected = "two vectors per direction")]
fn scaffold_rejects_stateful_ef_pipelines() {
    // One EF residual cannot serve Scaffold's interleaved x/c (or Δx/Δc)
    // streams — the algorithm must refuse rather than cross-contaminate.
    let mut cfg = tiny_cfg();
    cfg.compress_up = "ef(topk:0.2)".to_string();
    let _ = run_cfg(&cfg, "scaffold");
}

#[test]
fn scheduled_sparsity_anneals_the_uplink_bit_series() {
    let mut cfg = tiny_cfg();
    cfg.rounds = 6;
    cfg.compress_up = "sched:topk:0.5..0.05@linear".to_string();
    let log = run_cfg(&cfg, "fedcomloc-com");
    let bits: Vec<u64> = log.records.iter().map(|r| r.uplink_bits).collect();
    assert!(
        bits.first().unwrap() > bits.last().unwrap(),
        "annealing schedule must shrink uplink bits: {bits:?}"
    );
    assert!(
        bits.windows(2).all(|w| w[1] <= w[0]),
        "monotone schedule, fixed participants: {bits:?}"
    );
}

#[test]
fn stateful_pipeline_compress_into_matches_owned_through_dirty_buffers() {
    let mut sample = Rng::seed_from_u64(41);
    let x: Vec<f32> = (0..1500).map(|_| sample.normal_f32(0.0, 0.5)).collect();
    let mut payload = vec![0xA5u8; 99];
    for spec in [
        "ef(topk:0.1)",
        "ef(topk:0.1|q8)",
        "sched:topk:0.4..0.1@cosine",
        "sched:q:8..2@linear",
        "ef(sched:randk:0.5..0.2@linear)",
    ] {
        let parsed = CompressorSpec::parse(spec).unwrap();
        let (mut owned, mut reused) = (parsed.build(5), parsed.build(5));
        for round in 0..5 {
            let mut rng_a = Rng::seed_from_u64(round as u64);
            let mut rng_b = Rng::seed_from_u64(round as u64);
            let want = owned.compress(&x, round, &mut rng_a);
            let meta = reused.compress_into(&x, round, &mut rng_b, &mut payload);
            assert_eq!(want.payload, payload, "{spec} round {round}: payload bytes");
            assert_eq!(want.wire_bits, meta.wire_bits, "{spec} round {round}");
            assert_eq!(want.codec, meta.codec, "{spec} round {round}");
        }
    }
}

#[test]
fn ef_pipelines_are_client_state_not_worker_state() {
    // Two federations differing only in thread count must produce the same
    // messages: EF residuals live in ClientState (keyed by client id), so
    // worker scheduling cannot perturb them. Driven end-to-end here; the
    // sweep engine's threads-1 ≡ threads-4 file pin covers the same
    // property at the sink level.
    let mut cfg = tiny_cfg();
    cfg.compress_up = "ef(topk:0.2|q8)".to_string();
    cfg.threads = 1;
    let one = run_cfg(&cfg, "fedcomloc-com");
    cfg.threads = 4;
    let four = run_cfg(&cfg, "fedcomloc-com");
    assert_records_identical(&one, &four, "threads-1 vs threads-4");
}

#[test]
fn legacy_metrics_meta_untouched_but_pipelines_recorded_when_set() {
    let cfg = tiny_cfg();
    let legacy = run_cfg(&cfg, "fedcomloc-com:topk:0.3");
    assert!(
        !legacy.meta.iter().any(|(k, _)| k == "compress_up" || k == "compress_down"),
        "default runs must not grow meta keys"
    );
    let mut cfg2 = tiny_cfg();
    cfg2.compress_up = "ef(topk:0.2)".to_string();
    let piped = run_cfg(&cfg2, "fedcomloc-com");
    assert!(
        piped.meta.iter().any(|(k, v)| k == "compress_up" && v == "ef(topk:0.2)"),
        "{:?}",
        piped.meta
    );
}
