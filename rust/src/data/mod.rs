//! Dataset substrate: in-memory classification datasets, federated
//! Dirichlet partitioning, and per-client batch loading.
//!
//! Datasets are selected through the string-keyed, open [`DatasetSpec`]
//! registry (mirroring `fed::AlgorithmSpec` and `model::ModelSpec`):
//!
//! * `mnist` (alias `fedmnist`) — 1×28×28 grayscale, 10 classes; loads
//!   real MNIST IDX files from `data/` when present ([`idx`]), otherwise a
//!   deterministic synthetic equivalent ([`synthetic`]).
//! * `cifar10` (aliases `cifar`, `fedcifar10`) — 3×32×32 color, 10
//!   classes; real CIFAR-10 binary batches or synthetic.
//! * `synthetic:<ch>x<side>x<side>[-c<classes>]` — synthetic image data of
//!   any square shape (the generator behind the MNIST/CIFAR stand-ins).
//! * `synthetic:<d>[-c<classes>]` — flat Gaussian-mixture features of
//!   dimension `d`: a linearly separable-ish convex workload for the
//!   `linear:<d>` / `softmax:<d>x<c>` models.
//!
//! The paper evaluates on FedMNIST (MLP) and FedCIFAR10 (CNN) distributed
//! over 100 clients by a Dirichlet label-skew model (§4, Appendix A/B.1);
//! this environment has no network access, so synthetic is the default
//! (see DESIGN.md §5).

pub mod dirichlet;
pub mod idx;
pub mod loader;
pub mod synthetic;

use crate::util::rng::Rng;

/// Feature geometry of a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataShape {
    /// NCHW image planes, square side.
    Image {
        /// Channel count.
        channels: usize,
        /// Plane side length.
        side: usize,
    },
    /// Flat feature vectors.
    Flat {
        /// Feature dimension.
        dim: usize,
    },
}

/// Where examples come from when the spec is materialized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum DataSource {
    /// Real MNIST IDX files if present, else synthetic images.
    MnistIdx,
    /// Real CIFAR-10 binary batches if present, else synthetic images.
    CifarBin,
    /// Always synthetic.
    Synthetic,
}

/// A validated, string-keyed dataset selector (see module docs for the
/// grammar). Replaces the closed `DatasetKind` enum: new shapes are a
/// parse call, not a core-enum edit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetSpec {
    key: String,
    shape: DataShape,
    classes: usize,
    source: DataSource,
}

/// One entry in the dataset registry: listing metadata plus the parser the
/// spec string resolves through — `DatasetSpec::parse` dispatches over this
/// table, so `list-datasets` and `--dataset` cannot drift apart.
pub struct DatasetFamily {
    /// Registry key, e.g. `mnist`.
    pub key: &'static str,
    /// Accepted alternate spellings (the paper's names).
    pub aliases: &'static [&'static str],
    /// Help text for the argument after the key, if any.
    pub arg_help: &'static str,
    /// One-line description shown by `list-datasets`.
    pub summary: &'static str,
    /// A small loadable spec (smoke tests, docs).
    pub example: &'static str,
    parse: fn(&str) -> Result<DatasetSpec, String>,
}

fn no_arg(name: &str, arg: &str) -> Result<(), String> {
    if arg.is_empty() {
        Ok(())
    } else {
        Err(format!("dataset '{name}' takes no argument, got '{arg}'"))
    }
}

fn parse_mnist(arg: &str) -> Result<DatasetSpec, String> {
    no_arg("mnist", arg)?;
    Ok(DatasetSpec::mnist())
}

fn parse_cifar10(arg: &str) -> Result<DatasetSpec, String> {
    no_arg("cifar10", arg)?;
    Ok(DatasetSpec::cifar10())
}

static DATASET_REGISTRY: [DatasetFamily; 3] = [
    DatasetFamily {
        key: "mnist",
        aliases: &["fedmnist"],
        arg_help: "-",
        summary: "FedMNIST: 1x28x28, 10 classes (real IDX files under data/, else synthetic)",
        example: "mnist",
        parse: parse_mnist,
    },
    DatasetFamily {
        key: "cifar10",
        aliases: &["cifar", "fedcifar10"],
        arg_help: "-",
        summary: "FedCIFAR10: 3x32x32, 10 classes (real binary batches under data/, else synthetic)",
        example: "cifar10",
        parse: parse_cifar10,
    },
    DatasetFamily {
        key: "synthetic",
        aliases: &[],
        arg_help: "<ch>x<side>x<side>[-c<classes>] images, or <d>[-c<classes>] flat features",
        summary: "deterministic synthetic data of any shape (flat = convex-workload features)",
        example: "synthetic:3x16x16",
        parse: parse_synthetic,
    },
];

/// The dataset registry: every loadable family, keyed by the spec prefix.
pub fn dataset_registry() -> &'static [DatasetFamily] {
    &DATASET_REGISTRY
}

impl DatasetSpec {
    /// The MNIST-shaped preset (`mnist`).
    pub fn mnist() -> DatasetSpec {
        DatasetSpec {
            key: "mnist".to_string(),
            shape: DataShape::Image {
                channels: 1,
                side: 28,
            },
            classes: 10,
            source: DataSource::MnistIdx,
        }
    }

    /// The CIFAR10-shaped preset (`cifar10`).
    pub fn cifar10() -> DatasetSpec {
        DatasetSpec {
            key: "cifar10".to_string(),
            shape: DataShape::Image {
                channels: 3,
                side: 32,
            },
            classes: 10,
            source: DataSource::CifarBin,
        }
    }

    /// Parse a spec string (`<family>[:<argument>]`) against the registry.
    pub fn parse(spec: &str) -> Result<DatasetSpec, String> {
        let spec = spec.trim();
        let (family, arg) = match spec.split_once(':') {
            Some((f, a)) => (f, a.trim()),
            None => (spec, ""),
        };
        let family = family.trim().to_ascii_lowercase();
        for fam in dataset_registry() {
            if fam.key == family || fam.aliases.contains(&family.as_str()) {
                return (fam.parse)(arg);
            }
        }
        let keys: Vec<&str> = dataset_registry().iter().map(|f| f.key).collect();
        Err(format!(
            "unknown dataset '{family}' (have: {})",
            keys.join(", ")
        ))
    }

    /// Canonical spec string, e.g. `mnist` or `synthetic:3x16x16-c5`.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Display name (same as the canonical key).
    pub fn name(&self) -> &str {
        &self.key
    }

    /// Feature geometry (image planes or flat vectors).
    pub fn shape(&self) -> DataShape {
        self.shape
    }

    /// Flattened per-example feature count.
    pub fn feature_dim(&self) -> usize {
        match self.shape {
            DataShape::Image { channels, side } => channels * side * side,
            DataShape::Flat { dim } => dim,
        }
    }

    /// Label classes.
    pub fn num_classes(&self) -> usize {
        self.classes
    }

    pub(crate) fn source(&self) -> DataSource {
        self.source
    }

    /// The default model spec for this dataset (the paper's MLP↔FedMNIST
    /// and CNN↔FedCIFAR10 pairing, extended to the open registries).
    pub fn default_model_spec(&self) -> String {
        match self.source {
            DataSource::MnistIdx => "mlp".to_string(),
            DataSource::CifarBin => "cnn".to_string(),
            DataSource::Synthetic => match self.shape {
                DataShape::Flat { dim } => format!("softmax:{dim}x{}", self.classes),
                DataShape::Image { .. } => {
                    format!("mlp:{}x128x64x{}", self.feature_dim(), self.classes)
                }
            },
        }
    }
}

fn parse_synthetic(arg: &str) -> Result<DatasetSpec, String> {
    if arg.is_empty() {
        return Err("synthetic needs a shape: <ch>x<side>x<side> or <d> (e.g. synthetic:1x28x28, synthetic:3072)".to_string());
    }
    let (dims_str, classes) = match arg.split_once("-c") {
        Some((d, c)) => (
            d.trim(),
            c.trim()
                .parse::<usize>()
                .ok()
                // Labels are stored as u8, so at most 256 classes.
                .filter(|&c| (2..=256).contains(&c))
                .ok_or_else(|| format!("bad class count '-c{c}' (want an integer in 2..=256)"))?,
        ),
        None => (arg, 10usize),
    };
    let dims = crate::util::parse_dims(dims_str, "synthetic shape dimension")?;
    let (shape, canonical) = match dims.as_slice() {
        [dim] => (DataShape::Flat { dim: *dim }, format!("{dim}")),
        [ch, h, w] if h == w => (
            DataShape::Image {
                channels: *ch,
                side: *h,
            },
            format!("{ch}x{h}x{w}"),
        ),
        [_, h, w] => {
            return Err(format!(
                "synthetic images must be square, got {h}x{w}"
            ))
        }
        _ => {
            return Err(format!(
                "synthetic shape '{dims_str}' must be <d> or <ch>x<side>x<side>"
            ))
        }
    };
    let key = if classes == 10 {
        format!("synthetic:{canonical}")
    } else {
        format!("synthetic:{canonical}-c{classes}")
    };
    Ok(DatasetSpec {
        key,
        shape,
        classes,
        source: DataSource::Synthetic,
    })
}

impl std::str::FromStr for DatasetSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        DatasetSpec::parse(s)
    }
}

/// A dense in-memory labelled dataset (row-major features).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The spec this data materializes.
    pub spec: DatasetSpec,
    /// Row-major example features, `len() × feature_dim`.
    pub features: Vec<f32>,
    /// One label per example.
    pub labels: Vec<u8>,
    /// Per-example feature count.
    pub feature_dim: usize,
    /// Label classes.
    pub num_classes: usize,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Features and label of example `i`.
    pub fn example(&self, i: usize) -> (&[f32], u8) {
        let lo = i * self.feature_dim;
        (&self.features[lo..lo + self.feature_dim], self.labels[i])
    }

    /// Per-class counts (used by `data-stats` / Figure 11 reproduction).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Train/test pair.
#[derive(Debug, Clone)]
pub struct TrainTest {
    /// Training split.
    pub train: Dataset,
    /// Held-out test split.
    pub test: Dataset,
}

/// Load real data from `data_dir` if the well-known files exist, otherwise
/// synthesize (the default in this offline environment). `train_n`/`test_n`
/// bound the sizes (real data is truncated; synthetic is generated at
/// exactly these sizes).
pub fn load_or_synthesize(
    spec: &DatasetSpec,
    data_dir: &std::path::Path,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> TrainTest {
    if let Some(real) = idx::try_load(spec, data_dir, train_n, test_n) {
        log::info!("loaded real {} from {}", spec.key(), data_dir.display());
        return real;
    }
    let mut rng = Rng::seed_from_u64(seed);
    synthetic::generate(spec, train_n, test_n, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_shapes() {
        assert_eq!(DatasetSpec::mnist().feature_dim(), 784);
        assert_eq!(DatasetSpec::cifar10().feature_dim(), 3072);
        assert_eq!(DatasetSpec::parse("FedMNIST").unwrap(), DatasetSpec::mnist());
        assert_eq!(DatasetSpec::parse("cifar10").unwrap(), DatasetSpec::cifar10());
        assert_eq!(DatasetSpec::parse("cifar").unwrap().key(), "cifar10");
        assert!(DatasetSpec::parse("imagenet").is_err());
    }

    #[test]
    fn synthetic_specs_parse_and_canonicalize() {
        let s = DatasetSpec::parse("synthetic:3x16x16").unwrap();
        assert_eq!(s.key(), "synthetic:3x16x16");
        assert_eq!(s.feature_dim(), 768);
        assert_eq!(s.num_classes(), 10);
        let s = DatasetSpec::parse("synthetic:64-c5").unwrap();
        assert_eq!(s.key(), "synthetic:64-c5");
        assert_eq!(s.feature_dim(), 64);
        assert_eq!(s.num_classes(), 5);
        assert_eq!(s.shape(), DataShape::Flat { dim: 64 });
        // Default class count folds out of the canonical key.
        assert_eq!(
            DatasetSpec::parse("synthetic:100-c10").unwrap().key(),
            "synthetic:100"
        );
        for bad in [
            "synthetic",
            "synthetic:3x16x8",  // non-square
            "synthetic:3x16",    // 2-D shape
            "synthetic:0",
            "synthetic:64-c1",
            "synthetic:64-c300", // labels are u8: at most 256 classes
            "synthetic:axb",
            "mnist:28",          // preset takes no argument
        ] {
            assert!(DatasetSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn registry_examples_parse_and_aliases_resolve() {
        for fam in dataset_registry() {
            let spec = DatasetSpec::parse(fam.example)
                .unwrap_or_else(|e| panic!("{}: {e}", fam.example));
            assert!(spec.key().starts_with(fam.key), "{}", fam.key);
            for alias in fam.aliases {
                assert_eq!(DatasetSpec::parse(alias).unwrap().key(), fam.key, "{alias}");
            }
        }
    }

    #[test]
    fn default_model_pairing() {
        assert_eq!(DatasetSpec::mnist().default_model_spec(), "mlp");
        assert_eq!(DatasetSpec::cifar10().default_model_spec(), "cnn");
        assert_eq!(
            DatasetSpec::parse("synthetic:64-c5").unwrap().default_model_spec(),
            "softmax:64x5"
        );
    }

    #[test]
    fn load_or_synthesize_falls_back() {
        let tt = load_or_synthesize(
            &DatasetSpec::mnist(),
            std::path::Path::new("/nonexistent"),
            200,
            50,
            1,
        );
        assert_eq!(tt.train.len(), 200);
        assert_eq!(tt.test.len(), 50);
        assert_eq!(tt.train.feature_dim, 784);
    }
}
