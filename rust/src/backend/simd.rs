//! Explicit AVX2 vectorizations of the compute-plane hot loops.
//!
//! Every function here is a **bit-identical** reimplementation of a scalar
//! loop elsewhere in the crate: same accumulation order, same rounding,
//! same NaN/signed-zero behaviour. That invariant is what lets the
//! `native-simd` backend share the seed-level reproducibility pins
//! (`api_regression.rs`, `workspace_identity.rs`, threads-1 ≡ threads-N)
//! with the scalar `native` plane, and what lets the codec scans below be
//! enabled unconditionally (there is no numerical difference to opt into).
//!
//! How identity is preserved, kernel by kernel:
//!
//! * **4×16 matmul tiles** (`ops::acc_rows4` and friends): the scalar
//!   kernel already keeps 16 independent f32 accumulators per row and adds
//!   one `a·b` product into each per k step. Two 8-lane vectors hold those
//!   16 accumulators; a broadcast-multiply-add performs the same 16
//!   lanewise `t[l] += x * b[l]` operations. Addition and multiplication
//!   are IEEE-exact per lane, so each accumulator sees the identical
//!   sequence of rounded operations. We deliberately do **not** use FMA:
//!   fused multiply-add skips the intermediate rounding and would change
//!   bits.
//! * **Lane-split dot products** (`ops::dot_lanes`): the scalar code
//!   accumulates into 8 lanes (`acc[l] += a[i+l] * b[i+l]`) and combines
//!   with a fixed tree. The vector version keeps one 8-lane accumulator,
//!   spills it to an array, and applies the *same* combine tree in scalar
//!   code.
//! * **Fused bias+ReLU epilogues**: scalar computes `s = v + bias` then
//!   `if s < 0.0 { 0.0 } else { s }`. Vector: lanewise add, then
//!   `andnot(s < 0, s)`. The comparison `_CMP_LT_OQ` is false for NaN and
//!   for `-0.0 < 0.0`, so NaN and −0.0 pass through unchanged — exactly
//!   the scalar branch's behaviour.
//! * **TopK key pack** (`compress::topk::select_topk_into`): the packed
//!   sort key `(|x|.to_bits() << 32) | !i` is pure bit manipulation; the
//!   vector path ANDs out the sign bit, XORs the index, and interleaves
//!   32-bit halves into the same little-endian u64 layout.
//! * **Quantization grid** (`compress::quantize`): `min(|x|/norm, 1.0)` is
//!   elementwise; division and `min` are IEEE-exact per lane
//!   (`_mm256_min_ps(y, 1.0)` returns `1.0` for NaN `y`, matching
//!   `f32::min`'s NaN fallback to the other operand).
//!
//! All wide paths fall back to the scalar formulation when AVX2 is absent
//! at runtime (detected once, cached), on non-x86_64 targets, or for
//! remainder elements — the fallbacks *are* the reference loops, restated,
//! and the unit tests below pin vector ≡ scalar across remainder-heavy
//! shapes.

#![allow(unsafe_code)]

/// Whether the wide (AVX2) paths are usable on this machine.
///
/// Detected once per process and cached; the answer never changes at
/// runtime. On non-x86_64 builds this is always `false` and every entry
/// point below runs its scalar reference loop.
#[inline]
pub fn wide_lanes_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human-readable description of the active wide path, for logs and docs.
pub fn lane_description() -> &'static str {
    if wide_lanes_available() {
        "avx2 (8 × f32 lanes)"
    } else {
        "scalar fallback (no avx2)"
    }
}

// ---------------------------------------------------------------------------
// Matmul micro-kernels (the 4×16 register-blocked tiles from model/ops).
// ---------------------------------------------------------------------------

/// C[m×n] += A[m×k]·B[k×n], vectorized tile walk. Bit-identical to
/// `ops::matmul_acc`.
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe { avx2::matmul_acc(a, b, c, m, k, n) };
        return;
    }
    crate::model::ops::matmul_acc(a, b, c, m, k, n);
}

/// C = A·B then fused `c = relu(c + bias[col])` epilogue (bias length n,
/// broadcast down rows). Bit-identical to `ops::matmul_bias_act`.
pub fn matmul_bias_act(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        for v in c.iter_mut() {
            *v = 0.0;
        }
        unsafe {
            avx2::matmul_acc(a, b, c, m, k, n);
            avx2::bias_act_cols(c, bias, m, n, relu);
        }
        return;
    }
    crate::model::ops::matmul_bias_act(a, b, bias, c, m, k, n, relu);
}

/// C[m×n] = Aᵀ[m×k]·B[k×n] where A is stored k×m. Bit-identical to
/// `ops::matmul_at_b`.
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe { avx2::matmul_at_b(a, b, c, m, k, n) };
        return;
    }
    crate::model::ops::matmul_at_b(a, b, c, m, k, n);
}

/// C[m×n] = A[m×k]·Bᵀ where B is stored n×k (row-major rows of length k).
/// Bit-identical to `ops::matmul_a_bt`.
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe { avx2::matmul_a_bt(a, b, c, m, k, n) };
        return;
    }
    crate::model::ops::matmul_a_bt(a, b, c, m, k, n);
}

/// `matmul_a_bt` with fused per-row `relu(c + bias[row])` epilogue (bias
/// length m). Bit-identical to `ops::matmul_a_bt_bias_act`.
pub fn matmul_a_bt_bias_act(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe {
            avx2::matmul_a_bt(a, b, c, m, k, n);
            avx2::bias_act_rows(c, bias, m, n, relu);
        }
        return;
    }
    crate::model::ops::matmul_a_bt_bias_act(a, b, bias, c, m, k, n, relu);
}

/// `out = x − γ·(g − h)`, the Scaffnew control-variate step. Elementwise,
/// so lanewise IEEE arithmetic is bit-identical to
/// `tensor::sgd_control_variate_step`.
pub fn sgd_control_variate_step(x: &[f32], g: &[f32], h: &[f32], gamma: f32, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe { avx2::sgd_control_variate_step(x, g, h, gamma, out) };
        return;
    }
    crate::tensor::sgd_control_variate_step(x, g, h, gamma, out);
}

// ---------------------------------------------------------------------------
// Codec scans (TopK threshold keys, quantization grid).
// ---------------------------------------------------------------------------

/// Fill `keys` with the packed TopK sort keys
/// `(|x[i]|.to_bits() << 32) | !(i as u32)` for every coordinate.
///
/// This is the O(d) scan in front of `select_nth_unstable_by`; key order in
/// the vector is irrelevant downstream (selection has set semantics), but
/// we produce ascending order anyway so the scalar and wide paths are
/// byte-identical. Clears `keys` first; capacity is reused.
pub fn pack_topk_keys(x: &[f32], keys: &mut Vec<u64>) {
    keys.clear();
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() && x.len() <= i32::MAX as usize {
        keys.resize(x.len(), 0);
        unsafe { avx2::pack_topk_keys(x, keys) };
        return;
    }
    keys.extend(
        x.iter()
            .enumerate()
            .map(|(i, &v)| ((v.abs().to_bits() as u64) << 32) | (!(i as u32)) as u64),
    );
}

/// `out[i] = min(|src[i]| / norm, 1.0)` — the normalized-magnitude grid the
/// stochastic quantizer snaps onto. `out.len()` must equal `src.len()`.
pub fn quantize_grid(src: &[f32], norm: f32, out: &mut [f32]) {
    assert_eq!(src.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if wide_lanes_available() {
        unsafe { avx2::quantize_grid(src, norm, out) };
        return;
    }
    for (o, &v) in out.iter_mut().zip(src.iter()) {
        *o = (v.abs() / norm).min(1.0);
    }
}

// ---------------------------------------------------------------------------
// The AVX2 bodies. Each function mirrors one scalar loop; comments point at
// the reference. `#[target_feature]` keeps them safe to compile everywhere
// and gated behind the runtime check above.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Tile width of the register block (matches `ops::NR`).
    const NR: usize = 16;
    /// Lane-split width of the dot-product kernels (matches `ops::DL`).
    const DL: usize = 8;

    /// Lanewise `t += x * b` without FMA (two roundings, like scalar code).
    #[inline(always)]
    unsafe fn mul_add(t: __m256, x: __m256, b: __m256) -> __m256 {
        _mm256_add_ps(t, _mm256_mul_ps(x, b))
    }

    /// Lanewise `relu(s)` that keeps NaN and −0.0, matching the scalar
    /// branch `if s < 0.0 { 0.0 } else { s }`.
    #[inline(always)]
    unsafe fn relu_lanes(s: __m256) -> __m256 {
        let neg = _mm256_cmp_ps::<_CMP_LT_OQ>(s, _mm256_setzero_ps());
        _mm256_andnot_ps(neg, s)
    }

    /// See `ops::matmul_acc` / `ops::acc_rows4`: 4-row × 16-column tiles,
    /// ascending-k accumulation, with the same scalar tail handling for
    /// row remainders (m % 4) and column remainders (n % 16).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mut i = 0;
        while i + 4 <= m {
            acc_rows4(&a[i * k..], b, c, i, k, n);
            i += 4;
        }
        while i < m {
            acc_row1(&a[i * k..(i + 1) * k], b, &mut c[i * n..(i + 1) * n], k, n);
            i += 1;
        }
    }

    /// Four rows at once over 16-wide column tiles (two __m256 per row).
    #[target_feature(enable = "avx2")]
    unsafe fn acc_rows4(a4: &[f32], b: &[f32], c: &mut [f32], i0: usize, k: usize, n: usize) {
        let mut j = 0;
        while j + NR <= n {
            // 4 rows × 2 vectors of accumulators, loaded from C.
            let mut t: [[__m256; 2]; 4] = [[_mm256_setzero_ps(); 2]; 4];
            for (r, tr) in t.iter_mut().enumerate() {
                let row = &c[(i0 + r) * n + j..];
                tr[0] = _mm256_loadu_ps(row.as_ptr());
                tr[1] = _mm256_loadu_ps(row.as_ptr().add(8));
            }
            for kk in 0..k {
                let br = &b[kk * n + j..];
                let b0 = _mm256_loadu_ps(br.as_ptr());
                let b1 = _mm256_loadu_ps(br.as_ptr().add(8));
                for (r, tr) in t.iter_mut().enumerate() {
                    let x = _mm256_set1_ps(a4[r * k + kk]);
                    tr[0] = mul_add(tr[0], x, b0);
                    tr[1] = mul_add(tr[1], x, b1);
                }
            }
            for (r, tr) in t.iter().enumerate() {
                let row = &mut c[(i0 + r) * n + j..];
                _mm256_storeu_ps(row.as_mut_ptr(), tr[0]);
                _mm256_storeu_ps(row.as_mut_ptr().add(8), tr[1]);
            }
            j += NR;
        }
        if j < n {
            // Column tail: exact copy of the scalar tail in ops::acc_rows4.
            let w = n - j;
            let mut t = [[0.0f32; NR]; 4];
            for r in 0..4 {
                t[r][..w].copy_from_slice(&c[(i0 + r) * n + j..(i0 + r) * n + j + w]);
            }
            for kk in 0..k {
                let br = &b[kk * n + j..kk * n + j + w];
                for r in 0..4 {
                    let x = a4[r * k + kk];
                    for (l, &bv) in br.iter().enumerate() {
                        t[r][l] += x * bv;
                    }
                }
            }
            for r in 0..4 {
                c[(i0 + r) * n + j..(i0 + r) * n + j + w].copy_from_slice(&t[r][..w]);
            }
        }
    }

    /// Single-row remainder of `matmul_acc` (mirrors `ops::acc_row1`).
    #[target_feature(enable = "avx2")]
    unsafe fn acc_row1(a1: &[f32], b: &[f32], crow: &mut [f32], k: usize, n: usize) {
        let mut j = 0;
        while j + NR <= n {
            let mut t0 = _mm256_loadu_ps(crow.as_ptr().add(j));
            let mut t1 = _mm256_loadu_ps(crow.as_ptr().add(j + 8));
            for (kk, &av) in a1.iter().enumerate().take(k) {
                let br = &b[kk * n + j..];
                let x = _mm256_set1_ps(av);
                t0 = mul_add(t0, x, _mm256_loadu_ps(br.as_ptr()));
                t1 = mul_add(t1, x, _mm256_loadu_ps(br.as_ptr().add(8)));
            }
            _mm256_storeu_ps(crow.as_mut_ptr().add(j), t0);
            _mm256_storeu_ps(crow.as_mut_ptr().add(j + 8), t1);
            j += NR;
        }
        if j < n {
            let w = n - j;
            let mut t = [0.0f32; NR];
            t[..w].copy_from_slice(&crow[j..j + w]);
            for (kk, &x) in a1.iter().enumerate().take(k) {
                let br = &b[kk * n + j..kk * n + j + w];
                for (l, &bv) in br.iter().enumerate() {
                    t[l] += x * bv;
                }
            }
            crow[j..j + w].copy_from_slice(&t[..w]);
        }
    }

    /// See `ops::matmul_at_b`: A is stored k×m (strided reads down a
    /// column become broadcasts of `a[kk*m + i + r]`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        for v in c.iter_mut() {
            *v = 0.0;
        }
        let mut i = 0;
        while i + 4 <= m {
            at_b_rows4(a, b, c, i, m, k, n);
            i += 4;
        }
        while i < m {
            at_b_row1(a, b, c, i, m, k, n);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn at_b_rows4(a: &[f32], b: &[f32], c: &mut [f32], i0: usize, m: usize, k: usize, n: usize) {
        let mut j = 0;
        while j + NR <= n {
            let mut t: [[__m256; 2]; 4] = [[_mm256_setzero_ps(); 2]; 4];
            for kk in 0..k {
                let ar = &a[kk * m + i0..kk * m + i0 + 4];
                let br = &b[kk * n + j..];
                let b0 = _mm256_loadu_ps(br.as_ptr());
                let b1 = _mm256_loadu_ps(br.as_ptr().add(8));
                for (r, tr) in t.iter_mut().enumerate() {
                    let x = _mm256_set1_ps(ar[r]);
                    tr[0] = mul_add(tr[0], x, b0);
                    tr[1] = mul_add(tr[1], x, b1);
                }
            }
            for (r, tr) in t.iter().enumerate() {
                let row = &mut c[(i0 + r) * n + j..];
                _mm256_storeu_ps(row.as_mut_ptr(), tr[0]);
                _mm256_storeu_ps(row.as_mut_ptr().add(8), tr[1]);
            }
            j += NR;
        }
        if j < n {
            let w = n - j;
            let mut t = [[0.0f32; NR]; 4];
            for kk in 0..k {
                let ar = &a[kk * m + i0..kk * m + i0 + 4];
                let br = &b[kk * n + j..kk * n + j + w];
                for r in 0..4 {
                    for (l, &bv) in br.iter().enumerate() {
                        t[r][l] += ar[r] * bv;
                    }
                }
            }
            for r in 0..4 {
                c[(i0 + r) * n + j..(i0 + r) * n + j + w].copy_from_slice(&t[r][..w]);
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn at_b_row1(a: &[f32], b: &[f32], c: &mut [f32], i: usize, m: usize, k: usize, n: usize) {
        let mut j = 0;
        while j + NR <= n {
            let mut t0 = _mm256_setzero_ps();
            let mut t1 = _mm256_setzero_ps();
            for kk in 0..k {
                let x = _mm256_set1_ps(a[kk * m + i]);
                let br = &b[kk * n + j..];
                t0 = mul_add(t0, x, _mm256_loadu_ps(br.as_ptr()));
                t1 = mul_add(t1, x, _mm256_loadu_ps(br.as_ptr().add(8)));
            }
            _mm256_storeu_ps(c.as_mut_ptr().add(i * n + j), t0);
            _mm256_storeu_ps(c.as_mut_ptr().add(i * n + j + 8), t1);
            j += NR;
        }
        if j < n {
            let w = n - j;
            let mut t = [0.0f32; NR];
            for kk in 0..k {
                let x = a[kk * m + i];
                let br = &b[kk * n + j..kk * n + j + w];
                for (l, &bv) in br.iter().enumerate() {
                    t[l] += x * bv;
                }
            }
            c[i * n + j..i * n + j + w].copy_from_slice(&t[..w]);
        }
    }

    /// The 8-way lane-split dot product of `ops::dot_lanes`, with the same
    /// fixed combine tree. One vector accumulator replaces the 8 scalar
    /// lanes; the spill + tree reduction reproduces the scalar combine
    /// bit-for-bit.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_lanes(a: &[f32], b: &[f32], k: usize) -> f32 {
        let mut accv = _mm256_setzero_ps();
        let mut i = 0;
        while i + DL <= k {
            accv = mul_add(
                accv,
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            i += DL;
        }
        let mut acc = [0.0f32; DL];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        // Same combine tree as ops::dot_lanes.
        let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
        while i < k {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// See `ops::matmul_a_bt`: B stored n×k, each output is a dot of two
    /// contiguous length-k rows. Walks 4 A-rows at a time like the scalar
    /// `dot_lanes4` grouping (the per-output arithmetic is independent, so
    /// row grouping affects only locality, not bits).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        let mut i = 0;
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &a[i * k..(i + 1) * k],
                &a[(i + 1) * k..(i + 2) * k],
                &a[(i + 2) * k..(i + 3) * k],
                &a[(i + 3) * k..(i + 4) * k],
            );
            for j in 0..n {
                let br = &b[j * k..(j + 1) * k];
                c[i * n + j] = dot_lanes(a0, br, k);
                c[(i + 1) * n + j] = dot_lanes(a1, br, k);
                c[(i + 2) * n + j] = dot_lanes(a2, br, k);
                c[(i + 3) * n + j] = dot_lanes(a3, br, k);
            }
            i += 4;
        }
        while i < m {
            let ar = &a[i * k..(i + 1) * k];
            for j in 0..n {
                c[i * n + j] = dot_lanes(ar, &b[j * k..(j + 1) * k], k);
            }
            i += 1;
        }
    }

    /// Column-broadcast epilogue: `c[i][j] = relu(c[i][j] + bias[j])`
    /// (bias length n). Mirrors the loop in `ops::matmul_bias_act`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bias_act_cols(c: &mut [f32], bias: &[f32], m: usize, n: usize, relu: bool) {
        debug_assert_eq!(bias.len(), n);
        for i in 0..m {
            let row = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 8 <= n {
                let s = _mm256_add_ps(
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                    _mm256_loadu_ps(bias.as_ptr().add(j)),
                );
                let s = if relu { relu_lanes(s) } else { s };
                _mm256_storeu_ps(row.as_mut_ptr().add(j), s);
                j += 8;
            }
            while j < n {
                let s = row[j] + bias[j];
                row[j] = if relu && s < 0.0 { 0.0 } else { s };
                j += 1;
            }
        }
    }

    /// Row-broadcast epilogue: `c[i][j] = relu(c[i][j] + bias[i])`
    /// (bias length m). Mirrors the loop in `ops::matmul_a_bt_bias_act`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bias_act_rows(c: &mut [f32], bias: &[f32], m: usize, n: usize, relu: bool) {
        debug_assert_eq!(bias.len(), m);
        for i in 0..m {
            let bv = bias[i];
            let bvv = _mm256_set1_ps(bv);
            let row = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 8 <= n {
                let s = _mm256_add_ps(_mm256_loadu_ps(row.as_ptr().add(j)), bvv);
                let s = if relu { relu_lanes(s) } else { s };
                _mm256_storeu_ps(row.as_mut_ptr().add(j), s);
                j += 8;
            }
            while j < n {
                let s = row[j] + bv;
                row[j] = if relu && s < 0.0 { 0.0 } else { s };
                j += 1;
            }
        }
    }

    /// Elementwise `out = x − γ·(g − h)` (see
    /// `tensor::sgd_control_variate_step`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_control_variate_step(
        x: &[f32],
        g: &[f32],
        h: &[f32],
        gamma: f32,
        out: &mut [f32],
    ) {
        let d = out.len();
        debug_assert!(x.len() == d && g.len() == d && h.len() == d);
        let gv = _mm256_set1_ps(gamma);
        let mut i = 0;
        while i + 8 <= d {
            let diff = _mm256_sub_ps(
                _mm256_loadu_ps(g.as_ptr().add(i)),
                _mm256_loadu_ps(h.as_ptr().add(i)),
            );
            let step = _mm256_mul_ps(gv, diff);
            let r = _mm256_sub_ps(_mm256_loadu_ps(x.as_ptr().add(i)), step);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < d {
            out[i] = x[i] - gamma * (g[i] - h[i]);
            i += 1;
        }
    }

    /// Packed TopK sort keys (see `pack_topk_keys` above): per coordinate,
    /// `(|x|.to_bits() << 32) | !i`, stored in index order.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_topk_keys(x: &[f32], keys: &mut [u64]) {
        debug_assert_eq!(x.len(), keys.len());
        let abs_mask = _mm256_set1_epi32(0x7FFF_FFFF);
        let all_ones = _mm256_set1_epi32(-1);
        let iota = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let d = x.len();
        let mut i = 0;
        while i + 8 <= d {
            let v = _mm256_castps_si256(_mm256_loadu_ps(x.as_ptr().add(i)));
            let mag = _mm256_and_si256(v, abs_mask);
            let idx = _mm256_add_epi32(iota, _mm256_set1_epi32(i as i32));
            let ninv = _mm256_xor_si256(idx, all_ones);
            // Interleave (¬idx, mag) pairs: little-endian u64 = ¬idx | mag<<32.
            let lo = _mm256_unpacklo_epi32(ninv, mag); // pairs 0,1 | 4,5
            let hi = _mm256_unpackhi_epi32(ninv, mag); // pairs 2,3 | 6,7
            let k0 = _mm256_permute2x128_si256::<0x20>(lo, hi); // keys 0..4
            let k1 = _mm256_permute2x128_si256::<0x31>(lo, hi); // keys 4..8
            _mm256_storeu_si256(keys.as_mut_ptr().add(i) as *mut __m256i, k0);
            _mm256_storeu_si256(keys.as_mut_ptr().add(i + 4) as *mut __m256i, k1);
            i += 8;
        }
        while i < d {
            keys[i] = ((x[i].abs().to_bits() as u64) << 32) | (!(i as u32)) as u64;
            i += 1;
        }
    }

    /// `out[i] = min(|src[i]| / norm, 1.0)` (see `quantize_grid` above).
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_grid(src: &[f32], norm: f32, out: &mut [f32]) {
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let nv = _mm256_set1_ps(norm);
        let one = _mm256_set1_ps(1.0);
        let d = src.len();
        let mut i = 0;
        while i + 8 <= d {
            let v = _mm256_and_ps(_mm256_loadu_ps(src.as_ptr().add(i)), abs_mask);
            let y = _mm256_div_ps(v, nv);
            // min_ps(y, 1) returns 1 when y is NaN — same as f32::min.
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_min_ps(y, one));
            i += 8;
        }
        while i < d {
            out[i] = (src[i].abs() / norm).min(1.0);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    // Shapes chosen to exercise full tiles, column tails (n % 16), row
    // remainders (m % 4) and lane tails (k % 8) in every combination.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (4, 8, 16),
        (5, 9, 17),
        (3, 7, 15),
        (8, 40, 33),
        (6, 13, 31),
        (9, 24, 16),
    ];

    #[test]
    fn matmul_acc_matches_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(11);
        for &(m, k, n) in SHAPES {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let seed = fill(&mut rng, m * n);
            let mut c_s = seed.clone();
            let mut c_v = seed.clone();
            ops::matmul_acc(&a, &b, &mut c_s, m, k, n);
            matmul_acc(&a, &b, &mut c_v, m, k, n);
            assert!(
                c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()),
                "matmul_acc diverged at shape {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn matmul_bias_act_matches_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(12);
        for &(m, k, n) in SHAPES {
            for relu in [false, true] {
                let a = fill(&mut rng, m * k);
                let b = fill(&mut rng, k * n);
                let bias = fill(&mut rng, n);
                let mut c_s = vec![0.0; m * n];
                let mut c_v = vec![0.0; m * n];
                ops::matmul_bias_act(&a, &b, &bias, &mut c_s, m, k, n, relu);
                matmul_bias_act(&a, &b, &bias, &mut c_v, m, k, n, relu);
                assert!(
                    c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul_bias_act diverged at {m}x{k}x{n} relu={relu}"
                );
            }
        }
    }

    #[test]
    fn matmul_at_b_matches_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(13);
        for &(m, k, n) in SHAPES {
            let a = fill(&mut rng, k * m);
            let b = fill(&mut rng, k * n);
            let mut c_s = vec![1.0; m * n]; // pre-poisoned: both paths overwrite
            let mut c_v = vec![2.0; m * n];
            ops::matmul_at_b(&a, &b, &mut c_s, m, k, n);
            matmul_at_b(&a, &b, &mut c_v, m, k, n);
            assert!(
                c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()),
                "matmul_at_b diverged at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn matmul_a_bt_matches_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(14);
        for &(m, k, n) in SHAPES {
            for relu in [false, true] {
                let a = fill(&mut rng, m * k);
                let b = fill(&mut rng, n * k);
                let bias = fill(&mut rng, m);
                let mut c_s = vec![0.0; m * n];
                let mut c_v = vec![0.0; m * n];
                ops::matmul_a_bt(&a, &b, &mut c_s, m, k, n);
                matmul_a_bt(&a, &b, &mut c_v, m, k, n);
                assert!(
                    c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul_a_bt diverged at {m}x{k}x{n}"
                );
                ops::matmul_a_bt_bias_act(&a, &b, &bias, &mut c_s, m, k, n, relu);
                matmul_a_bt_bias_act(&a, &b, &bias, &mut c_v, m, k, n, relu);
                assert!(
                    c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul_a_bt_bias_act diverged at {m}x{k}x{n} relu={relu}"
                );
            }
        }
    }

    #[test]
    fn relu_edge_cases_match_scalar() {
        // −0.0 and exact zeros must survive the vector ReLU exactly like
        // the scalar branch (neither is `< 0.0`, hence both are kept).
        // m=1, k=1, n=16 with A=[1] makes C a copy of B plus bias.
        let xs: Vec<f32> = vec![
            -0.0, 0.0, 1.0, -1.0, f32::MIN_POSITIVE, -f32::MIN_POSITIVE, 0.5, -0.5, 2.0, -2.0,
            3.0, -3.0, 4.0, -4.0, 5.0, -5.0,
        ];
        let ident = [1.0f32];
        let bias = vec![0.0f32; 16];
        let mut c_s = vec![0.0; 16];
        let mut c_v = vec![0.0; 16];
        ops::matmul_bias_act(&ident, &xs, &bias, &mut c_s, 1, 1, 16, true);
        matmul_bias_act(&ident, &xs, &bias, &mut c_v, 1, 1, 16, true);
        assert!(c_s.iter().zip(&c_v).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn sgd_step_matches_scalar_bitwise() {
        let mut rng = Rng::seed_from_u64(15);
        for d in [1, 7, 8, 9, 64, 1001] {
            let x = fill(&mut rng, d);
            let g = fill(&mut rng, d);
            let h = fill(&mut rng, d);
            let mut o_s = vec![0.0; d];
            let mut o_v = vec![0.0; d];
            crate::tensor::sgd_control_variate_step(&x, &g, &h, 0.37, &mut o_s);
            sgd_control_variate_step(&x, &g, &h, 0.37, &mut o_v);
            assert!(o_s.iter().zip(&o_v).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn topk_keys_match_scalar_reference() {
        let mut rng = Rng::seed_from_u64(16);
        for d in [0, 1, 7, 8, 9, 16, 100, 1000] {
            let x = fill(&mut rng, d);
            let reference: Vec<u64> = x
                .iter()
                .enumerate()
                .map(|(i, &v)| ((v.abs().to_bits() as u64) << 32) | (!(i as u32)) as u64)
                .collect();
            let mut keys = Vec::new();
            pack_topk_keys(&x, &mut keys);
            assert_eq!(keys, reference, "key pack diverged at d={d}");
        }
    }

    #[test]
    fn quantize_grid_matches_scalar_reference() {
        let mut rng = Rng::seed_from_u64(17);
        for d in [1, 7, 8, 9, 100, 1025] {
            let x = fill(&mut rng, d);
            let norm = crate::tensor::norm2(&x);
            let reference: Vec<f32> = x.iter().map(|&v| (v.abs() / norm).min(1.0)).collect();
            let mut out = vec![0.0; d];
            quantize_grid(&x, norm, &mut out);
            assert!(
                reference.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits()),
                "grid diverged at d={d}"
            );
        }
    }

    #[test]
    fn lane_description_is_stable() {
        // Smoke: the description reflects the cached runtime probe.
        let d = lane_description();
        assert!(d.contains("avx2") || d.contains("scalar"));
        assert_eq!(wide_lanes_available(), wide_lanes_available());
    }
}
