//! Figure 16 (Appendix B.3): double compression TopK ∘ Q_r.

mod common;

use fedcomloc::compress::{Compressor, DoubleCompress, Identity, QuantizeR, TopK};
use fedcomloc::fed::{run, AlgorithmSpec, Variant};

fn main() {
    println!("== Figure 16: double compression (bench scale) ==");
    let trainer = common::mlp_trainer();
    let cases: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("K=25% + 4bit", Box::new(DoubleCompress::new(0.25, 4))),
        ("K=50% + 16bit", Box::new(DoubleCompress::new(0.50, 16))),
        ("K=25% + 32bit", Box::new(TopK::with_density(0.25))),
        ("K=100% + 4bit", Box::new(QuantizeR::new(4))),
        ("K=100% + 32bit", Box::new(Identity)),
    ];
    for (label, compressor) in cases {
        let cfg = common::mnist_cfg();
        let spec = AlgorithmSpec::FedComLoc {
            variant: Variant::Com,
            compressor,
        };
        let log = run(&cfg, trainer.clone(), &spec);
        common::row(
            label,
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits(),
        );
    }
    println!("\n  paper shape: per communicated bit, stronger double compression");
    println!("  wins; at matched compression levels no clear winner.");
}
