//! The scenario engine: a discrete-event round runtime that replays any
//! [`FedAlgorithm`] on a simulated wall clock.
//!
//! The synchronous drive loop ([`crate::fed::algorithm::drive`]) treats a
//! round as instantaneous: every sampled client trains, every surviving
//! uplink aggregates, and `sim_secs` only measures link time when the
//! transport is a [`SimNet`]. Real federated deployments are dominated by
//! *stragglers* — heterogeneous compute means the round is as slow as its
//! slowest participant. This module models that regime without touching
//! any algorithm:
//!
//! * [`queue`] — a deterministic virtual-clock event queue keyed by
//!   `(time, seq)`, so event order is identical across seeds, threads and
//!   platforms.
//! * [`scheduler`] — [`ScenarioNet`], a [`Transport`] decorator that
//!   assigns each client a seeded compute-speed multiplier, charges
//!   per-link down/compute/up time, accepts the first K arrivals each
//!   round (FedBuff-style semi-synchrony), and buffers stragglers' updates
//!   to fold staleness-weighted — `(1+s)^(−α) / K` — into a later round.
//! * [`drive_scenario`] — the drive loop variant that owns the
//!   fold-arrivals / sample / round / settle sequence and emits the same
//!   [`MetricsLog`] schema, with `sim_secs` now meaning simulated
//!   wall-clock (link *and* compute) and the new `stale_updates` /
//!   `churned_clients` columns populated.
//!
//! A scenario is selected by the `scenario` axis in
//! [`RunConfig`](crate::fed::RunConfig) / TOML / CLI:
//!
//! ```text
//! sync                      # the legacy loop, bit-identical (degenerate case)
//! semisync:<K>              # fold first K arrivals, staleness α = 0.5
//! semisync:<K>@<staleness>  # explicit staleness exponent α
//! ```
//!
//! `sync` routes through the untouched [`drive`] path, so existing runs
//! stay byte-identical. Dropout stays owned by the transport layer; churn
//! (an in-flight straggler update discarded because its client was
//! re-sampled) is owned here — see [`scheduler`] for the full contract.
//!
//! [`SimNet`]: crate::fed::transport::SimNet
//! [`drive`]: crate::fed::algorithm::drive

pub mod queue;
pub mod scheduler;

pub use queue::EventQueue;
pub use scheduler::ScenarioNet;

use super::algorithm::{
    drive_federation_observed, DriveObserver, FedAlgorithm, NoopObserver, RoundCtx,
};
use super::transport::Transport;
use super::{Federation, RoundLogger, RunConfig};
use crate::metrics::MetricsLog;
use crate::model::LocalTrainer;
use std::sync::Arc;

/// A parsed round-runtime scenario (the `scenario` config axis).
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    /// The legacy synchronous loop — every sampled client's update folds
    /// this round. Degenerate case; bit-identical to the pre-scenario
    /// drive path.
    Sync,
    /// Semi-synchronous (FedBuff-style): the server folds the first `k`
    /// arrivals per round; stragglers' updates land `(1+s)^(−staleness)`
    /// weighted in the round after their simulated arrival time.
    Semisync {
        /// Arrivals folded synchronously per round (clamped to the number
        /// delivered).
        k: usize,
        /// Staleness exponent α ≥ 0; 0 weights stale updates like fresh
        /// ones (modulo the 1/K divisor).
        staleness: f64,
    },
}

impl Scenario {
    /// Parse a scenario spec: `sync` | `semisync:<K>[@<staleness>]`.
    /// Omitted staleness defaults to `0.5` (the FedBuff paper's choice).
    pub fn parse(spec: &str) -> Result<Scenario, String> {
        if spec == "sync" {
            return Ok(Scenario::Sync);
        }
        if let Some(rest) = spec.strip_prefix("semisync:") {
            let (k_str, alpha_str) = match rest.split_once('@') {
                Some((k, a)) => (k, Some(a)),
                None => (rest, None),
            };
            let k: usize = k_str
                .parse()
                .map_err(|_| format!("semisync K must be a positive integer, got '{k_str}'"))?;
            if k == 0 {
                return Err("semisync K must be >= 1".to_string());
            }
            let staleness = match alpha_str {
                None => 0.5,
                Some(a) => {
                    let v: f64 = a
                        .parse()
                        .map_err(|_| format!("semisync staleness must be a number, got '{a}'"))?;
                    if !v.is_finite() || v < 0.0 {
                        return Err(format!(
                            "semisync staleness must be finite and >= 0, got '{a}'"
                        ));
                    }
                    v
                }
            };
            return Ok(Scenario::Semisync { k, staleness });
        }
        Err(format!(
            "unknown scenario '{spec}' (expected 'sync' or 'semisync:<K>[@<staleness>]')"
        ))
    }

    /// The canonical spec string (staleness always explicit), stable for
    /// log metadata and sweep summary keys.
    pub fn key(&self) -> String {
        match self {
            Scenario::Sync => "sync".to_string(),
            Scenario::Semisync { k, staleness } => format!("semisync:{k}@{staleness}"),
        }
    }
}

/// Run `algo` to completion under `scenario` on a fresh
/// [`Federation`] — the scenario-engine counterpart of
/// [`crate::fed::algorithm::drive`].
pub fn drive_scenario(
    cfg: &RunConfig,
    trainer: Arc<dyn LocalTrainer>,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn Transport,
    scenario: &Scenario,
) -> MetricsLog {
    let mut fed = Federation::new(cfg, trainer);
    drive_scenario_federation(cfg, &mut fed, algo, transport, scenario)
}

/// Run `algo` under `scenario` on an existing [`Federation`].
///
/// Mirrors [`crate::fed::drive_federation`]'s loop with three scenario
/// hooks per round, in this order:
///
/// 1. **fold** — arrived straggler updates fold into `fed.x` *before*
///    sampling, so the round's broadcast carries them;
/// 2. **churn** — [`ScenarioNet::begin_round`] discards in-flight updates
///    from re-sampled clients;
/// 3. **settle** — after the algorithm's round,
///    [`ScenarioNet::note_local_steps`] records the actual segment length
///    and `end_round` advances the virtual clock to the slowest accepted
///    arrival.
///
/// `Scenario::Sync` delegates straight to [`crate::fed::drive_federation`]:
/// the synchronous path stays bit-identical with no decorator in the loop.
pub fn drive_scenario_federation(
    cfg: &RunConfig,
    fed: &mut Federation,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn Transport,
    scenario: &Scenario,
) -> MetricsLog {
    drive_scenario_federation_observed(cfg, fed, algo, transport, scenario, &mut NoopObserver)
        .expect("noop observer cannot fail")
}

/// [`drive_scenario_federation`] with a [`DriveObserver`] in the loop — the
/// checkpoint-aware entry point. The observer sees the [`ScenarioNet`]
/// decorator as its transport, so its save/restore hooks reach the virtual
/// clock and pending straggler buffer as well as the inner channel.
pub fn drive_scenario_federation_observed(
    cfg: &RunConfig,
    fed: &mut Federation,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn Transport,
    scenario: &Scenario,
    observer: &mut dyn DriveObserver,
) -> Result<MetricsLog, String> {
    let (k, staleness) = match *scenario {
        Scenario::Sync => {
            return drive_federation_observed(cfg, fed, algo, transport, observer);
        }
        Scenario::Semisync { k, staleness } => (k, staleness),
    };
    let name = algo.log_name(fed, cfg);
    let mut log = MetricsLog::new(&name);
    for (key, value) in algo.log_meta(cfg) {
        log = log.with_meta(&key, value);
    }
    if cfg.compress_up != "none" {
        log = log.with_meta("compress_up", &cfg.compress_up);
    }
    if cfg.compress_down != "none" {
        log = log.with_meta("compress_down", &cfg.compress_down);
    }
    log = log.with_meta("scenario", scenario.key());
    if cfg.faults != "none" {
        log = log.with_meta("faults", &cfg.faults);
    }
    algo.setup(fed, cfg);
    let kind = algo.uplink_kind();
    // See `drive_federation_observed`: a quorum-gated fault plane can
    // abort a round, carrying the model over unchanged. The snapshot is
    // taken after `fold_arrivals`, so straggler folds survive an abort.
    let quorum_gated = cfg.faults != "none" && cfg.faults_spec().quorum > 0.0;
    let mut logger = RoundLogger::new(cfg, log);
    let mut net = ScenarioNet::new(transport, k, staleness, kind, cfg);
    let start = observer.on_start(fed, algo, &mut net, &mut logger)?;
    let mut finalize = true;
    for round in start..cfg.rounds {
        logger.begin_round();
        net.fold_arrivals(round, &mut fed.x);
        let sampled = fed.sample_clients(cfg.clients_per_round);
        net.begin_round(round, &sampled);
        let pre_round_x = quorum_gated.then(|| fed.x.clone());
        let outcome = {
            let mut ctx = RoundCtx {
                cfg,
                fed: &mut *fed,
                transport: &mut net,
                round,
                sampled,
            };
            algo.round(&mut ctx)
        };
        net.note_local_steps(outcome.local_steps);
        let report = net.end_round();
        if report.aborted {
            if let Some(x0) = &pre_round_x {
                fed.x.copy_from_slice(x0);
            }
        }
        let eval = if (round + 1) % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            Some(fed.evaluate())
        } else {
            None
        };
        if let Some(e) = &eval {
            log::info!(
                "[{name}] round {round}: loss {:.4} acc {:.4} up {} bits (sim {:.1}s)",
                outcome.train_loss,
                e.accuracy,
                report.usage.uplink_bits,
                report.sim_secs
            );
        }
        logger.end_round(round, outcome.local_steps, outcome.train_loss, &report, eval);
        if !observer.on_round_end(round, fed, algo, &mut net, &mut logger)? {
            finalize = false;
            break;
        }
    }
    if finalize {
        algo.finalize(fed, cfg);
    }
    Ok(logger.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sync() {
        assert_eq!(Scenario::parse("sync"), Ok(Scenario::Sync));
        assert_eq!(Scenario::Sync.key(), "sync");
    }

    #[test]
    fn parse_semisync_defaults_staleness() {
        let s = Scenario::parse("semisync:4").unwrap();
        assert_eq!(
            s,
            Scenario::Semisync {
                k: 4,
                staleness: 0.5
            }
        );
        assert_eq!(s.key(), "semisync:4@0.5");
    }

    #[test]
    fn parse_semisync_explicit_staleness_roundtrips() {
        for spec in ["semisync:1@0", "semisync:8@0.5", "semisync:2@1", "semisync:3@1.25"] {
            let s = Scenario::parse(spec).unwrap();
            let key = s.key();
            assert_eq!(Scenario::parse(&key).unwrap(), s, "canonical key must reparse");
            assert_eq!(Scenario::parse(&key).unwrap().key(), key, "key is a fixpoint");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "async",
            "semisync",
            "semisync:",
            "semisync:0",
            "semisync:-1",
            "semisync:2@",
            "semisync:2@nan",
            "semisync:2@-0.5",
            "semisync:2@inf",
            "SYNC",
        ] {
            assert!(Scenario::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }
}
