//! Micro-bench: the compression hot path (encode + decode) at model sizes,
//! on both the owned-payload API and the buffer-reusing
//! `compress_into`/`decode_payload_into` fast path.
//!
//! This is the L3 cost FedComLoc adds per communication round; the TopK
//! selection (select_nth_unstable) and the quantizer bit-packing dominate.
//! Exports `BENCH_compress.json` (ns/op plus bytes-per-round metrics); CI's
//! `perf-smoke` job gates it against `benches/baseline/BENCH_compress.json`.

use fedcomloc::compress::{
    decode_payload_into, Compressor, DoubleCompress, Identity, QuantizeR, TopK,
};
use fedcomloc::util::benchkit::{self, bb, Bench};
use fedcomloc::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(42);
    for &(label, d) in &[("mlp d=109k", 109_386usize), ("cnn d=744k", 744_330)] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let mut b = Bench::new(&format!("compress_{}", label.split(' ').next().unwrap()));
        let cases: Vec<(String, Box<dyn Compressor>)> = vec![
            ("identity".into(), Box::new(Identity)),
            ("topk 10%".into(), Box::new(TopK::with_density(0.10))),
            ("topk 30%".into(), Box::new(TopK::with_density(0.30))),
            ("topk 90%".into(), Box::new(TopK::with_density(0.90))),
            ("q4".into(), Box::new(QuantizeR::new(4))),
            ("q8".into(), Box::new(QuantizeR::new(8))),
            ("q16".into(), Box::new(QuantizeR::new(16))),
            ("topk25+q8".into(), Box::new(DoubleCompress::new(0.25, 8))),
        ];
        for (name, comp) in cases {
            let mut enc_rng = Rng::seed_from_u64(7);
            b.case(&format!("{label} encode {name}"), || {
                bb(comp.compress(bb(&x), &mut enc_rng));
            });
            // Buffer-reusing encode: steady-state zero allocation.
            let mut enc_rng = Rng::seed_from_u64(7);
            let mut payload = Vec::new();
            b.case(&format!("{label} encode_into {name}"), || {
                bb(comp.compress_into(bb(&x), &mut enc_rng, &mut payload));
            });
            let mut dec_rng = Rng::seed_from_u64(7);
            let encoded = comp.compress(&x, &mut dec_rng);
            b.case(&format!("{label} decode {name}"), || {
                bb(comp.decompress(bb(&encoded)));
            });
            let mut dense = vec![0.0f32; d];
            b.case(&format!("{label} decode_into {name}"), || {
                decode_payload_into(encoded.codec, encoded.dim, bb(&encoded.payload), &mut dense);
                bb(&dense);
            });
            // Bytes one uplink of this codec puts on the wire per round.
            b.record_metric(
                &format!("{label} wire bytes {name}"),
                encoded.wire_bits.div_ceil(8) as f64,
                "bytes/round",
            );
        }
        b.finish();
    }

    std::process::exit(benchkit::finalize("compress"));
}
