//! Per-client minibatch loading with static shapes.
//!
//! The AOT-compiled train-step executables have fixed batch dimensions, so
//! the loader always emits exactly `batch_size` examples: each client cycles
//! through a reshuffled permutation of its shard (wrap-around sampling),
//! which matches how FedLab's samplers feed fixed-size batches.

use super::Dataset;
use crate::util::rng::Rng;
use std::sync::Arc;

/// A fixed-size minibatch ready for the runtime: row-major features and
/// i32 labels (the HLO programs take i32 label inputs).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Row-major features, `batch_size × feature_dim`.
    pub x: Vec<f32>,
    /// One i32 label per row.
    pub y: Vec<i32>,
    /// Rows in this batch (always the configured size).
    pub batch_size: usize,
    /// Features per row.
    pub feature_dim: usize,
}

/// One client's shard view plus its batch cursor state.
#[derive(Debug, Clone)]
pub struct ClientLoader {
    data: Arc<Dataset>,
    indices: Vec<usize>,
    cursor: usize,
    batch_size: usize,
    rng: Rng,
}

impl ClientLoader {
    /// A loader over `indices` into `data`, with its own shuffle stream.
    ///
    /// An empty shard is allowed at million-client scale (populations far
    /// larger than the dataset necessarily leave most clients without
    /// examples); such a loader reports [`ClientLoader::is_empty`] and
    /// panics only if a batch is actually requested. The initial reshuffle
    /// of an empty or single-element shard consumes no RNG draws.
    pub fn new(data: Arc<Dataset>, indices: Vec<usize>, batch_size: usize, rng: Rng) -> Self {
        assert!(batch_size > 0);
        let mut loader = Self {
            data,
            indices,
            cursor: 0,
            batch_size,
            rng,
        };
        loader.reshuffle();
        loader
    }

    /// Number of examples in this client's shard.
    pub fn shard_len(&self) -> usize {
        self.indices.len()
    }

    /// True when this client holds no examples (its local training loop
    /// must be skipped — there is nothing to draw a batch from).
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Snapshot the loader's mutable state — the current shard permutation,
    /// batch cursor, and shuffle stream — for checkpointing (the `data`
    /// reference and `batch_size` are rebuilt from config on resume).
    pub fn cursor_state(&self) -> (&[usize], usize, &Rng) {
        (&self.indices, self.cursor, &self.rng)
    }

    /// Restore a [`ClientLoader::cursor_state`] snapshot onto a loader
    /// rebuilt over the same shard. Errors if the permutation is not a
    /// same-length reordering of this loader's indices or the cursor is out
    /// of range, so a checkpoint from a different partition cannot be
    /// silently applied.
    pub fn restore_cursor_state(
        &mut self,
        indices: Vec<usize>,
        cursor: usize,
        rng: Rng,
    ) -> Result<(), String> {
        if indices.len() != self.indices.len() {
            return Err(format!(
                "loader shard mismatch: checkpoint has {} indices, partition has {}",
                indices.len(),
                self.indices.len()
            ));
        }
        let mut a = indices.clone();
        let mut b = self.indices.clone();
        a.sort_unstable();
        b.sort_unstable();
        if a != b {
            return Err("loader shard mismatch: checkpoint permutes a different index set".into());
        }
        if cursor > indices.len() {
            return Err(format!("loader cursor {cursor} out of range"));
        }
        self.indices = indices;
        self.cursor = cursor;
        self.rng = rng;
        Ok(())
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.indices);
        self.cursor = 0;
    }

    /// Next minibatch (always exactly `batch_size` rows; wraps with a
    /// reshuffle at epoch boundaries). Panics on an empty shard — callers
    /// must guard with [`ClientLoader::is_empty`].
    pub fn next_batch(&mut self) -> Batch {
        assert!(
            !self.indices.is_empty(),
            "next_batch on an empty client shard (guard with is_empty)"
        );
        let d = self.data.feature_dim;
        let mut x = Vec::with_capacity(self.batch_size * d);
        let mut y = Vec::with_capacity(self.batch_size);
        for _ in 0..self.batch_size {
            if self.cursor >= self.indices.len() {
                self.reshuffle();
            }
            let i = self.indices[self.cursor];
            self.cursor += 1;
            let (feat, label) = self.data.example(i);
            x.extend_from_slice(feat);
            y.push(label as i32);
        }
        Batch {
            x,
            y,
            batch_size: self.batch_size,
            feature_dim: d,
        }
    }
}

/// Chunk an evaluation set into fixed-size batches, padding the tail by
/// repeating the final example; `valid` reports how many rows of the last
/// chunk are real so accuracy aggregation can ignore the padding.
pub struct EvalBatches {
    /// The fixed-size chunks, padded at the tail.
    pub batches: Vec<Batch>,
    /// Valid row count per batch (== batch_size except possibly the last).
    pub valid: Vec<usize>,
}

/// Pre-batch an evaluation set (see [`EvalBatches`] for the padding rule).
pub fn eval_batches(data: &Dataset, batch_size: usize) -> EvalBatches {
    assert!(batch_size > 0);
    assert!(!data.is_empty());
    let d = data.feature_dim;
    let mut batches = Vec::new();
    let mut valid = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let real = (data.len() - i).min(batch_size);
        let mut x = Vec::with_capacity(batch_size * d);
        let mut y = Vec::with_capacity(batch_size);
        for j in 0..batch_size {
            let idx = if j < real { i + j } else { i + real - 1 };
            let (feat, label) = data.example(idx);
            x.extend_from_slice(feat);
            y.push(label as i32);
        }
        batches.push(Batch {
            x,
            y,
            batch_size,
            feature_dim: d,
        });
        valid.push(real);
        i += real;
    }
    EvalBatches { batches, valid }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, DatasetSpec};

    fn dataset(n: usize) -> Arc<Dataset> {
        let mut rng = Rng::seed_from_u64(10);
        Arc::new(synthetic::generate(&DatasetSpec::mnist(), n, 10, &mut rng).train)
    }

    #[test]
    fn batches_have_static_shape() {
        let data = dataset(100);
        let mut loader = ClientLoader::new(
            Arc::clone(&data),
            (0..37).collect(),
            16,
            Rng::seed_from_u64(1),
        );
        for _ in 0..10 {
            let b = loader.next_batch();
            assert_eq!(b.x.len(), 16 * 784);
            assert_eq!(b.y.len(), 16);
        }
    }

    #[test]
    fn epoch_covers_whole_shard() {
        let data = dataset(64);
        let shard: Vec<usize> = (0..32).collect();
        let mut loader = ClientLoader::new(Arc::clone(&data), shard.clone(), 8, Rng::seed_from_u64(2));
        // 4 batches = 1 epoch: every shard example appears exactly once.
        let mut seen = Vec::new();
        for _ in 0..4 {
            let b = loader.next_batch();
            for (row, &label) in b.y.iter().enumerate() {
                // Match example back by content (labels alone are ambiguous,
                // so check feature rows).
                let x_row = &b.x[row * 784..(row + 1) * 784];
                let found = shard
                    .iter()
                    .find(|&&i| data.example(i).0 == x_row && data.labels[i] as i32 == label)
                    .copied()
                    .expect("batch row not from shard");
                seen.push(found);
            }
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn wraparound_reshuffles() {
        let data = dataset(20);
        let mut loader = ClientLoader::new(Arc::clone(&data), (0..5).collect(), 4, Rng::seed_from_u64(3));
        // More batches than shard size — must keep producing.
        for _ in 0..10 {
            let b = loader.next_batch();
            assert_eq!(b.y.len(), 4);
        }
    }

    #[test]
    fn eval_batches_cover_and_pad() {
        let data = dataset(103);
        let eb = eval_batches(&data, 25);
        assert_eq!(eb.batches.len(), 5); // 25*4 + 3
        assert_eq!(eb.valid, vec![25, 25, 25, 25, 3]);
        assert!(eb.batches.iter().all(|b| b.y.len() == 25));
        let total_valid: usize = eb.valid.iter().sum();
        assert_eq!(total_valid, 103);
        // Padded rows repeat the last real example.
        let last = &eb.batches[4];
        let real_last_row = &last.x[2 * 784..3 * 784];
        let padded_row = &last.x[3 * 784..4 * 784];
        assert_eq!(real_last_row, padded_row);
    }

    #[test]
    fn empty_shard_constructs_but_rejects_batches() {
        let data = dataset(10);
        let rng = Rng::seed_from_u64(4);
        // Construction draws nothing (len < 2 shuffles are no-ops), so an
        // empty loader's stream equals the untouched seed stream.
        let loader = ClientLoader::new(data, vec![], 4, rng.clone());
        assert!(loader.is_empty());
        assert_eq!(loader.shard_len(), 0);
        assert_eq!(loader.cursor_state().2.state(), rng.state());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut l = loader;
            l.next_batch()
        }));
        assert!(result.is_err(), "next_batch on an empty shard must panic");
    }
}
