#![allow(dead_code)]
//! Shared setup for the paper-figure benches: a scaled-down RunConfig and
//! a native-plane trainer (benches must run on a fresh checkout without
//! artifacts; the PJRT plane is covered by bench_micro_runtime).
//!
//! Scale: FEDCOMLOC_BENCH_ROUNDS overrides the default 15 communication
//! rounds; paper-scale reproduction goes through `fedcomloc experiment`.

use fedcomloc::fed::RunConfig;
use fedcomloc::model::native::NativeTrainer;
use std::sync::Arc;

pub fn bench_rounds() -> usize {
    std::env::var("FEDCOMLOC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15)
}

pub fn mnist_cfg() -> RunConfig {
    RunConfig {
        rounds: bench_rounds(),
        train_n: 4_000,
        test_n: 800,
        n_clients: 50,
        clients_per_round: 10,
        eval_every: 5,
        ..RunConfig::default_mnist()
    }
}

pub fn cifar_cfg() -> RunConfig {
    RunConfig {
        rounds: bench_rounds().min(8),
        train_n: 1_200,
        test_n: 300,
        n_clients: 10,
        clients_per_round: 5,
        eval_every: 4,
        ..RunConfig::default_cifar()
    }
}

/// Resolve a registry spec string, panicking on typos (benches use static
/// specs).
pub fn algo(spec: &str) -> fedcomloc::fed::AlgorithmSpec {
    fedcomloc::fed::AlgorithmSpec::parse(spec)
        .unwrap_or_else(|e| panic!("bad bench spec '{spec}': {e}"))
}

/// FedComLoc-Com at a TopK density (identity at K=100%) — the sweep axis
/// the table/figure benches share (mirrors `experiments::fedcomloc_topk_spec`).
pub fn fedcomloc_topk(density: f64) -> fedcomloc::fed::AlgorithmSpec {
    algo(&fedcomloc::experiments::fedcomloc_topk_spec(density))
}

pub fn mlp_trainer() -> Arc<NativeTrainer> {
    Arc::new(NativeTrainer::from_spec("mlp").unwrap())
}

pub fn cnn_trainer() -> Arc<NativeTrainer> {
    Arc::new(NativeTrainer::from_spec("cnn").unwrap())
}

/// Print one experiment data row in a uniform format.
pub fn row(label: &str, acc: f64, loss: f64, uplink_bits: u64) {
    println!(
        "  {label:<28} best_acc={acc:<8.4} final_loss={loss:<8.4} uplink={:.2} MB",
        uplink_bits as f64 / 8e6
    );
}
