//! Micro-bench: the compression hot path (encode + decode) at model sizes,
//! on both the owned-payload API and the buffer-reusing
//! `compress_into`/`decode_payload_into` fast path.
//!
//! This is the L3 cost FedComLoc adds per communication round; the TopK
//! selection (select_nth_unstable) and the quantizer bit-packing dominate.
//! Exports `BENCH_compress.json` (ns/op plus bytes-per-round metrics); CI's
//! `perf-smoke` job gates it against `benches/baseline/BENCH_compress.json`.

use fedcomloc::compress::{
    decode_payload_into, parse_spec, Compressor, CompressorSpec, Identity, QuantizeR, RandK, TopK,
};
use fedcomloc::util::benchkit::{self, bb, Bench};
use fedcomloc::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(42);
    for &(label, d) in &[("mlp d=109k", 109_386usize), ("cnn d=744k", 744_330)] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let mut b = Bench::new(&format!("compress_{}", label.split(' ').next().unwrap()));
        let cases: Vec<(String, Box<dyn Compressor>)> = vec![
            ("identity".into(), Box::new(Identity)),
            ("topk 10%".into(), Box::new(TopK::with_density(0.10))),
            ("topk 30%".into(), Box::new(TopK::with_density(0.30))),
            ("topk 90%".into(), Box::new(TopK::with_density(0.90))),
            ("randk 10%".into(), Box::new(RandK::with_density(0.10))),
            ("q4".into(), Box::new(QuantizeR::new(4))),
            ("q8".into(), Box::new(QuantizeR::new(8))),
            ("q16".into(), Box::new(QuantizeR::new(16))),
            // The fused sparsifier->quantizer chain (the retired
            // DoubleCompress layout) and a generic (non-fused) chain.
            ("topk25+q8".into(), parse_spec("topk:0.25|q8").unwrap()),
            ("q8|topk10".into(), parse_spec("q8|topk:0.1").unwrap()),
        ];
        for (name, comp) in cases {
            let mut enc_rng = Rng::seed_from_u64(7);
            b.case(&format!("{label} encode {name}"), || {
                bb(comp.compress(bb(&x), &mut enc_rng));
            });
            // Buffer-reusing encode: steady-state zero allocation.
            let mut enc_rng = Rng::seed_from_u64(7);
            let mut payload = Vec::new();
            b.case(&format!("{label} encode_into {name}"), || {
                bb(comp.compress_into(bb(&x), &mut enc_rng, &mut payload));
            });
            let mut dec_rng = Rng::seed_from_u64(7);
            let encoded = comp.compress(&x, &mut dec_rng);
            b.case(&format!("{label} decode {name}"), || {
                bb(comp.decompress(bb(&encoded)));
            });
            let mut dense = vec![0.0f32; d];
            b.case(&format!("{label} decode_into {name}"), || {
                decode_payload_into(encoded.codec, encoded.dim, bb(&encoded.payload), &mut dense);
                bb(&dense);
            });
            // Bytes one uplink of this codec puts on the wire per round.
            b.record_metric(
                &format!("{label} wire bytes {name}"),
                encoded.wire_bits.div_ceil(8) as f64,
                "bytes/round",
            );
        }

        // Stateful error-feedback pipeline: the per-round cost of the
        // shift + encode + decode-absorb cycle (EF pays one decode per
        // encode by construction).
        let ef_spec = CompressorSpec::parse("ef(topk:0.1)").unwrap();
        let mut ef_owned = ef_spec.build(1000);
        let mut ef_rng = Rng::seed_from_u64(7);
        let mut round = 0usize;
        b.case(&format!("{label} encode ef(topk10)"), || {
            bb(ef_owned.compress(bb(&x), round, &mut ef_rng));
            round += 1;
        });
        let mut ef_reuse = ef_spec.build(1000);
        let mut ef_rng = Rng::seed_from_u64(7);
        let mut payload = Vec::new();
        let mut round = 0usize;
        b.case(&format!("{label} encode_into ef(topk10)"), || {
            bb(ef_reuse.compress_into(bb(&x), round, &mut ef_rng, &mut payload));
            round += 1;
        });
        let enc = ef_spec.build(1000).compress(&x, 0, &mut Rng::seed_from_u64(7));
        b.record_metric(
            &format!("{label} wire bytes ef(topk10)"),
            enc.wire_bits.div_ceil(8) as f64,
            "bytes/round",
        );
        b.finish();
    }

    std::process::exit(benchkit::finalize("compress"));
}
