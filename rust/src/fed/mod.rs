//! The federated runtime (Layer 3): FedComLoc and every baseline, behind
//! three public APIs.
//!
//! * [`algorithm`] — the [`FedAlgorithm`] trait plus the single generic
//!   [`algorithm::drive`] loop that owns client sampling, the evaluation
//!   cadence, [`RoundLogger`] bookkeeping, and the worker pool. The four
//!   shipped algorithms (FedComLoc, FedAvg/sparseFedAvg, Scaffold, FedDyn)
//!   are ordinary implementations; adding a LoCoDL- or SoteriaFL-style
//!   variant is one new file, no coordinator changes.
//! * [`message`] — the self-describing wire format: a [`message::Message`]
//!   carries a codec tag with every decode parameter, so the receiving side
//!   reconstructs vectors from the serialized bytes alone (no compressor
//!   instance), exactly as a remote peer would.
//! * [`transport`] — the pluggable [`transport::Transport`] channel:
//!   [`transport::InProc`] reproduces the seed's in-process semantics bit
//!   for bit, [`transport::SimNet`] simulates per-link bandwidth, latency,
//!   and client dropout for straggler scenarios.
//!
//! [`Federation`] owns the process topology — partitioned client shards,
//! per-client persistent state (loaders, control variates), the worker
//! pool, and the model — and [`AlgorithmSpec`] is the string-keyed registry
//! (`"fedcomloc-com:topk:0.3"`, `"fedavg"`, `"feddyn:0.01"`, …) the CLI,
//! experiments, and benches all resolve algorithms through.
//!
//! All algorithms are generic over [`LocalTrainer`], so they run identically
//! on the native Rust compute plane and the AOT-compiled PJRT plane.

pub mod algorithm;
pub mod cost;
pub mod faults;
pub mod fedavg;
pub mod feddyn;
pub mod message;
pub mod scaffold;
pub mod scaffnew;
pub mod sim;
pub mod state_store;
pub mod transport;

pub use algorithm::{
    drive, drive_federation, drive_federation_observed, AlgoState, DriveObserver, FedAlgorithm,
    NoopObserver, RoundCtx, RoundOutcome, StateItem,
};
pub use state_store::{ClientStore, StateTemplate};

use crate::compress::{CompressorSpec, Pipeline};
use crate::data::dirichlet::{partition_streaming, SparsePartition};
use crate::data::loader::{eval_batches, EvalBatches};
use crate::data::{load_or_synthesize, DatasetSpec, TrainTest};
use crate::metrics::{MetricsLog, RoundRecord};
use crate::model::{LocalTrainer, Model, ModelSpec, Workspace};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex};

/// FedComLoc variant (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Compress client→server uplink (default in the paper).
    Com,
    /// Compress the model inside each local training step.
    Local,
    /// Compress server→client downlink.
    Global,
}

impl Variant {
    /// Canonical lowercase name (`com` / `local` / `global`).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Com => "com",
            Variant::Local => "local",
            Variant::Global => "global",
        }
    }

    /// Parse a variant name (case-insensitive; `uplink`/`downlink` are
    /// accepted aliases for `com`/`global`).
    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "com" | "uplink" => Some(Variant::Com),
            "local" => Some(Variant::Local),
            "global" | "downlink" => Some(Variant::Global),
            _ => None,
        }
    }
}

/// Which wire direction an algorithm family's inline compressor argument
/// shims into (the legacy `--algo fedcomloc-com:<spec>` grammar). The
/// shimmed direction and the corresponding `RunConfig`
/// `compress_up`/`compress_down` key are mutually exclusive — setting
/// both is a configuration conflict, detected at sweep expansion
/// ([`crate::sweep`]) and again at [`Federation`] setup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireShim {
    /// The argument never reaches the wire (e.g. `fedcomloc-local`'s
    /// in-graph mask density, `feddyn`'s regularizer α).
    None,
    /// The argument becomes the per-client uplink pipeline.
    Uplink,
    /// The argument becomes the server broadcast (downlink) pipeline.
    Downlink,
}

/// One entry in the string-keyed algorithm registry.
pub struct AlgorithmFamily {
    /// Registry key, e.g. `fedcomloc-com`.
    pub key: &'static str,
    /// Help text for the argument after the key, if any.
    pub arg_help: &'static str,
    /// One-line description shown by `list-algorithms`.
    pub summary: &'static str,
    /// Wire direction the family's compressor argument shims into.
    pub shim: WireShim,
    /// True when the algorithm sends more than one logical vector stream
    /// over each link per round (Scaffold's x/c and Δx/Δc pairs) — such
    /// families reject stateful `ef(...)` pipelines, whose single residual
    /// memory cannot serve interleaved streams.
    pub multi_stream: bool,
    build: fn(&str) -> Result<Box<dyn FedAlgorithm>, String>,
}

fn arg_compressor(arg: &str) -> Result<CompressorSpec, String> {
    CompressorSpec::parse(if arg.is_empty() { "none" } else { arg })
}

fn build_fedcomloc_com(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    Ok(Box::new(scaffnew::FedComLoc::new(Variant::Com, arg_compressor(arg)?)))
}

fn build_fedcomloc_local(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    let spec = arg_compressor(arg)?;
    // -Local applies C(x) in-graph via the TopK mask: a spec that carries
    // no extractable density would silently train (and transmit) dense
    // while the run name advertises a compressor — reject it up front.
    if !spec.is_identity() && scaffnew::local_mask_density(&spec).is_none() {
        return Err(format!(
            "fedcomloc-local masks in-graph and needs a leading topk:<density> spec \
             (got '{}'); use fedcomloc-com or compress_up for wire-only compression",
            spec.key()
        ));
    }
    Ok(Box::new(scaffnew::FedComLoc::new(Variant::Local, spec)))
}

fn build_fedcomloc_global(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    Ok(Box::new(scaffnew::FedComLoc::new(Variant::Global, arg_compressor(arg)?)))
}

fn build_fedavg(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    Ok(Box::new(fedavg::FedAvg::new(arg_compressor(arg)?)))
}

fn build_sparsefedavg(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    let spec = if arg.is_empty() { "topk:0.3" } else { arg };
    Ok(Box::new(fedavg::FedAvg::new(CompressorSpec::parse(spec)?)))
}

fn build_scaffold(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    if !arg.is_empty() {
        return Err(format!("scaffold takes no argument, got '{arg}'"));
    }
    Ok(Box::new(scaffold::Scaffold::new()))
}

fn build_feddyn(arg: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    let alpha = if arg.is_empty() {
        0.01
    } else {
        arg.parse::<f64>().map_err(|_| format!("bad feddyn alpha '{arg}'"))?
    };
    if !alpha.is_finite() || alpha <= 0.0 {
        return Err(format!("feddyn alpha must be positive, got {alpha}"));
    }
    Ok(Box::new(feddyn::FedDyn::new(alpha)))
}

static ALGORITHM_REGISTRY: [AlgorithmFamily; 8] = [
    AlgorithmFamily {
        key: "fedcomloc-com",
        arg_help: "compressor spec (default: none)",
        summary: "FedComLoc, client->server uplink compression (paper default)",
        shim: WireShim::Uplink,
        multi_stream: false,
        build: build_fedcomloc_com,
    },
    AlgorithmFamily {
        key: "fedcomloc-local",
        arg_help: "compressor spec (default: none)",
        summary: "FedComLoc, in-graph model compression during local steps",
        shim: WireShim::None,
        multi_stream: false,
        build: build_fedcomloc_local,
    },
    AlgorithmFamily {
        key: "fedcomloc-global",
        arg_help: "compressor spec (default: none)",
        summary: "FedComLoc, server->client downlink compression",
        shim: WireShim::Downlink,
        multi_stream: false,
        build: build_fedcomloc_global,
    },
    AlgorithmFamily {
        key: "fedcomloc",
        arg_help: "compressor spec (default: none)",
        summary: "alias for fedcomloc-com",
        shim: WireShim::Uplink,
        multi_stream: false,
        build: build_fedcomloc_com,
    },
    AlgorithmFamily {
        key: "fedavg",
        arg_help: "optional compressor spec (identity = vanilla FedAvg)",
        summary: "FedAvg (McMahan et al.); with a compressor it becomes sparseFedAvg",
        shim: WireShim::Uplink,
        multi_stream: false,
        build: build_fedavg,
    },
    AlgorithmFamily {
        key: "sparsefedavg",
        arg_help: "compressor spec (default: topk:0.3)",
        summary: "sparseFedAvg (paper §4.7): FedAvg with compressed uplink",
        shim: WireShim::Uplink,
        multi_stream: false,
        build: build_sparsefedavg,
    },
    AlgorithmFamily {
        key: "scaffold",
        arg_help: "",
        summary: "Scaffold (Karimireddy et al.): control variates, 2x dense traffic",
        shim: WireShim::None,
        multi_stream: true,
        build: build_scaffold,
    },
    AlgorithmFamily {
        key: "feddyn",
        arg_help: "regularizer alpha (default: 0.01)",
        summary: "FedDyn (Acar et al.): dynamic regularization baseline",
        shim: WireShim::None,
        multi_stream: false,
        build: build_feddyn,
    },
];

/// The algorithm registry: every runnable algorithm family, keyed by the
/// spec prefix consumed uniformly by the CLI, experiments, and benches.
pub fn algorithm_registry() -> &'static [AlgorithmFamily] {
    &ALGORITHM_REGISTRY
}

/// Resolve a spec string's `<family>[:<arg>]` head against the registry —
/// the single parse point [`build_algorithm`] and [`embedded_wire_specs`]
/// share, so they can never disagree on the grammar.
fn resolve_family(spec: &str) -> Result<(&'static AlgorithmFamily, &str), String> {
    let spec = spec.trim();
    let (family, arg) = match spec.split_once(':') {
        Some((f, a)) => (f, a),
        None => (spec, ""),
    };
    let family = family.to_ascii_lowercase();
    for fam in algorithm_registry() {
        if fam.key == family {
            return Ok((fam, arg));
        }
    }
    let keys: Vec<&str> = algorithm_registry().iter().map(|f| f.key).collect();
    Err(format!("unknown algorithm '{family}' (have: {})", keys.join(", ")))
}

/// Resolve a spec string (`<family>[:<arg>]`) against the registry.
pub fn build_algorithm(spec: &str) -> Result<Box<dyn FedAlgorithm>, String> {
    let (fam, arg) = resolve_family(spec)?;
    (fam.build)(arg)
}

/// The wire pipelines a legacy algorithm spec embeds inline (the
/// back-compat shim): `(uplink, downlink)`, each `Some` only when the
/// family's argument shims into that direction *and* is not the identity.
/// `fedcomloc-com:topk:0.1` ⇒ `(Some(topk:0.1), None)`;
/// `fedcomloc-global:q8` ⇒ `(None, Some(q8))`; `sparsefedavg` ⇒ its
/// `topk:0.3` default uplink. The sweep expander and `Federation` both
/// use this to reject a spec that collides with an explicit
/// `compress_up`/`compress_down` key.
pub fn embedded_wire_specs(
    spec: &str,
) -> Result<(Option<CompressorSpec>, Option<CompressorSpec>), String> {
    let (fam, arg) = resolve_family(spec)?;
    if fam.shim == WireShim::None {
        return Ok((None, None));
    }
    let arg = if arg.is_empty() && fam.key == "sparsefedavg" {
        "topk:0.3"
    } else {
        arg
    };
    let parsed = arg_compressor(arg)?;
    let embedded = (!parsed.is_identity()).then_some(parsed);
    Ok(match fam.shim {
        WireShim::Uplink => (embedded, None),
        WireShim::Downlink => (None, embedded),
        WireShim::None => unreachable!("handled above"),
    })
}

/// True when the algorithm family behind `spec` multiplexes several vector
/// streams per link per round (see [`AlgorithmFamily::multi_stream`]) —
/// the sweep expander uses this to reject stateful `ef(...)` pipelines up
/// front instead of panicking in a worker thread.
pub fn multiplexes_streams(spec: &str) -> Result<bool, String> {
    Ok(resolve_family(spec)?.0.multi_stream)
}

/// A validated, string-keyed algorithm selector — the registry handle the
/// CLI, all experiments, and the benches construct algorithms through.
///
/// Replaces the seed's closed enum: `AlgorithmSpec::parse("fedcomloc-com:topk:0.1")`
/// both validates the spec and remembers it, and [`AlgorithmSpec::build`]
/// instantiates a fresh [`FedAlgorithm`] per run.
pub struct AlgorithmSpec {
    spec: String,
    display: String,
}

impl AlgorithmSpec {
    /// Validate a registry spec string and remember it (see
    /// [`build_algorithm`] for the grammar).
    pub fn parse(spec: &str) -> Result<AlgorithmSpec, String> {
        let algo = build_algorithm(spec)?;
        Ok(AlgorithmSpec {
            spec: spec.trim().to_string(),
            display: algo.name(),
        })
    }

    /// Display name, e.g. `fedcomloc-com[topk(0.30)]`.
    pub fn name(&self) -> String {
        self.display.clone()
    }

    /// The spec string this was parsed from.
    pub fn key(&self) -> &str {
        &self.spec
    }

    /// Instantiate a fresh algorithm (algorithms are stateful; one per run).
    pub fn build(&self) -> Box<dyn FedAlgorithm> {
        build_algorithm(&self.spec).expect("spec validated at parse time")
    }
}

impl std::str::FromStr for AlgorithmSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        AlgorithmSpec::parse(s)
    }
}

/// Everything a federated run needs (see module docs).
#[derive(Clone)]
pub struct RunConfig {
    /// The dataset to train on (string-keyed registry).
    pub dataset: DatasetSpec,
    /// Model architecture override; `None` pairs the dataset's default
    /// (the paper's MLP↔FedMNIST / CNN↔FedCIFAR10) via
    /// [`ModelSpec::for_dataset`]. Keeping this an `Option` makes
    /// `--dataset`/`--model` overrides order-independent.
    pub model: Option<ModelSpec>,
    /// Training examples to load/synthesize.
    pub train_n: usize,
    /// Test examples to load/synthesize.
    pub test_n: usize,
    /// Total federated clients n.
    pub n_clients: usize,
    /// Clients sampled per communication round (paper §4: 10 of 100).
    pub clients_per_round: usize,
    /// Dirichlet heterogeneity factor α (paper §4).
    pub dirichlet_alpha: f64,
    /// Communication rounds to run.
    pub rounds: usize,
    /// Scaffnew communication probability p (expected 1/p local iterations
    /// per communication round).
    pub p: f64,
    /// Local iterations per round for round-based baselines (FedAvg et al.).
    pub local_steps: usize,
    /// Learning rate γ.
    pub gamma: f32,
    /// Local-step minibatch size.
    pub batch_size: usize,
    /// Evaluation minibatch size.
    pub eval_batch: usize,
    /// Evaluate test metrics every this many communication rounds.
    pub eval_every: usize,
    /// Root RNG seed every run-local stream derives from.
    pub seed: u64,
    /// Per-local-iteration cost τ for the total-cost metric (paper Fig. 8).
    pub tau: f64,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Data directory for real datasets (falls back to synthetic).
    pub data_dir: std::path::PathBuf,
    /// Client→server (uplink) compression pipeline spec
    /// ([`CompressorSpec`] grammar; `"none"` = dense). Every driver routes
    /// client uploads through this; state (e.g. `ef` residuals) is
    /// per-client. Mutually exclusive with an algorithm spec that embeds
    /// an uplink compressor (`fedcomloc-com:<spec>`, `sparsefedavg:...`).
    pub compress_up: String,
    /// Server→client (downlink) compression pipeline spec. Every driver
    /// routes server broadcasts through this; FedComLoc additionally
    /// retains the compressed model between rounds (the -Global
    /// semantics). Mutually exclusive with `fedcomloc-global:<spec>`.
    pub compress_down: String,
    /// Round runtime scenario ([`sim::Scenario`] grammar): `"sync"` runs
    /// the legacy lock-step loop bit-identically; `"semisync:<K>[@<a>]"`
    /// routes every round through the discrete-event scheduler in
    /// [`sim`] — the server folds the first K arrivals and stragglers
    /// land staleness-weighted in later rounds.
    pub scenario: String,
    /// Fault-plane spec ([`faults::FaultSpec`] grammar): `"none"` runs the
    /// legacy loop bit-identically; an active spec (e.g.
    /// `"corrupt:0.02|crash:0.01|quorum:0.6"`) wraps the transport in a
    /// [`faults::FaultNet`] that injects seeded frame corruption, crashes,
    /// duplicates and outages, and runs the retransmit/quorum recovery
    /// machinery.
    pub faults: String,
    /// Compute-plane backend key ([`crate::backend`] registry): `"auto"`
    /// (the default) defers to the CLI/option layer and ultimately the
    /// shared auto policy; an explicit key (`native`, `native-simd`,
    /// `native-bf16`, `xla`) pins the plane for this run and wins over
    /// `--backend`. Validated on entry by the config layer.
    pub backend: String,
}

impl RunConfig {
    /// The effective model spec: the explicit override, or the dataset's
    /// default pairing.
    pub fn model_spec(&self) -> ModelSpec {
        self.model
            .clone()
            .unwrap_or_else(|| ModelSpec::for_dataset(&self.dataset))
    }

    /// The paper's §4 "Default Configuration", scaled for this testbed (the
    /// full 60k-sample / 500-round setting is reachable via CLI flags).
    pub fn default_mnist() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec::mnist(),
            model: None,
            train_n: 12_000,
            test_n: 2_000,
            n_clients: 100,
            clients_per_round: 10,
            dirichlet_alpha: 0.7,
            rounds: 60,
            p: 0.1,
            local_steps: 10,
            gamma: 0.05,
            batch_size: 64,
            eval_batch: 256,
            eval_every: 5,
            seed: 42,
            tau: 0.01,
            threads: 0,
            data_dir: std::path::PathBuf::from("data"),
            compress_up: "none".to_string(),
            compress_down: "none".to_string(),
            scenario: "sync".to_string(),
            faults: "none".to_string(),
            backend: "auto".to_string(),
        }
    }

    /// The CIFAR testbed preset (paper §4.3 topology: 10 clients, full
    /// participation). Every field is explicit — this preset used to
    /// inherit MNIST's client-count-dependent fields via struct-update,
    /// which silently kept `clients_per_round = 10` only because MNIST's
    /// sampled count happened to equal CIFAR's client count.
    pub fn default_cifar() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec::cifar10(),
            model: None,
            train_n: 4_000,
            test_n: 1_000,
            n_clients: 10,
            // Full participation: all 10 clients every round (paper §4.3).
            clients_per_round: 10,
            dirichlet_alpha: 0.7,
            rounds: 40,
            p: 0.1,
            local_steps: 10,
            gamma: 0.05,
            batch_size: 32,
            eval_batch: 128,
            eval_every: 5,
            seed: 42,
            tau: 0.01,
            threads: 0,
            data_dir: std::path::PathBuf::from("data"),
            compress_up: "none".to_string(),
            compress_down: "none".to_string(),
            scenario: "sync".to_string(),
            faults: "none".to_string(),
            backend: "auto".to_string(),
        }
    }

    /// The validated uplink pipeline spec (panics on an invalid string —
    /// the config layer validates on entry).
    pub fn uplink_spec(&self) -> CompressorSpec {
        CompressorSpec::parse(&self.compress_up)
            .unwrap_or_else(|e| panic!("invalid compress_up '{}': {e}", self.compress_up))
    }

    /// The validated downlink pipeline spec (panics on an invalid string).
    pub fn downlink_spec(&self) -> CompressorSpec {
        CompressorSpec::parse(&self.compress_down)
            .unwrap_or_else(|e| panic!("invalid compress_down '{}': {e}", self.compress_down))
    }

    /// The validated round-runtime scenario (panics on an invalid string —
    /// the config layer validates on entry).
    pub fn scenario_spec(&self) -> sim::Scenario {
        sim::Scenario::parse(&self.scenario)
            .unwrap_or_else(|e| panic!("invalid scenario '{}': {e}", self.scenario))
    }

    /// The validated fault-plane spec (panics on an invalid string — the
    /// config layer validates on entry).
    pub fn faults_spec(&self) -> faults::FaultSpec {
        faults::FaultSpec::parse(&self.faults)
            .unwrap_or_else(|e| panic!("invalid faults '{}': {e}", self.faults))
    }
}

/// Per-client persistent state across rounds. At million-client scale
/// these are materialized lazily per sampled cohort by the paged
/// [`ClientStore`] — see [`state_store`] — not per population.
pub struct ClientState {
    /// The client's shard-local minibatch stream.
    pub loader: crate::data::loader::ClientLoader,
    /// Scaffnew control variate h_i (also reused as c_i by Scaffold and as
    /// the FedDyn gradient correction λ_i — exactly one algorithm runs per
    /// Federation, so the slot is never shared).
    pub h: Vec<f32>,
    /// Per-client RNG stream (compression stochasticity etc.).
    pub rng: Rng,
    /// The client's uplink compression pipeline — the per-(client,
    /// direction) codec instance. Stateful combinators (`ef`) keep their
    /// residual here, so it survives rounds and is independent of which
    /// worker slot runs the client (bit-determinism at any thread count).
    pub up: Pipeline,
}

/// Shared run state: data, clients, pool, model params.
pub struct Federation {
    /// The architecture every party trains (validated against the config).
    pub model: Model,
    /// The compute plane executing local objectives.
    pub trainer: Arc<dyn LocalTrainer>,
    /// Per-client persistent state, paged in per sampled cohort and
    /// lockable per worker (indexes like the `Vec` it replaced).
    pub clients: ClientStore,
    /// The sparse Dirichlet label-skew partition behind the client shards
    /// (only non-empty shards are materialized).
    pub partition: SparsePartition,
    /// Pre-batched test set for the evaluation cadence.
    pub eval_set: EvalBatches,
    /// Fork-join worker pool for per-round client parallelism and
    /// parallel evaluation.
    pub pool: ThreadPool,
    /// One compute [`Workspace`] per pool worker slot (never shared):
    /// worker `w` of a [`ThreadPool::map_worker`] call locks exactly
    /// `workspaces[w]`, so locks never contend and scratch stays warm
    /// across iterations, rounds, and runs.
    pub workspaces: Vec<Mutex<Workspace>>,
    /// The server broadcast's compression pipeline (the downlink twin of
    /// each client's [`ClientState::up`]): all four drivers route
    /// broadcasts through it, so `downlink_bits` always reflects the
    /// actual codec's [`crate::compress::CodecMeta`].
    pub downlink: Pipeline,
    /// The global model parameters x.
    pub x: Vec<f32>,
    /// The run's root RNG (client sampling; streams derive from it).
    pub rng: Rng,
    /// The materialized train/test data.
    pub data: TrainTest,
}

impl Federation {
    /// Partition data, build per-client loaders, initialize x₀ and h_i = 0
    /// (satisfying Algorithm 1's Σ h_{i,0} = 0).
    pub fn new(cfg: &RunConfig, trainer: Arc<dyn LocalTrainer>) -> Federation {
        assert!(
            cfg.clients_per_round <= cfg.n_clients,
            "clients_per_round ({}) must not exceed n_clients ({})",
            cfg.clients_per_round,
            cfg.n_clients
        );
        let want = cfg.model_spec();
        let model = trainer.model().clone();
        assert_eq!(
            model.name(),
            want.key(),
            "trainer/model mismatch: config selects '{}' but the trainer computes '{}'",
            want.key(),
            model.name()
        );
        assert_eq!(
            model.input_dim(),
            cfg.dataset.feature_dim(),
            "model '{}' expects input dim {} but dataset '{}' provides {}",
            model.name(),
            model.input_dim(),
            cfg.dataset.key(),
            cfg.dataset.feature_dim()
        );
        assert_eq!(
            model.num_classes(),
            cfg.dataset.num_classes(),
            "model '{}' emits {} classes but dataset '{}' has {}",
            model.name(),
            model.num_classes(),
            cfg.dataset.key(),
            cfg.dataset.num_classes()
        );
        let data =
            load_or_synthesize(&cfg.dataset, &cfg.data_dir, cfg.train_n, cfg.test_n, cfg.seed);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let part = partition_streaming(
            &data.train,
            cfg.n_clients,
            cfg.dirichlet_alpha,
            cfg.batch_size.min(data.train.len() / cfg.n_clients.max(1)).max(1),
            &mut rng,
        );
        let train = Arc::new(data.train.clone());
        let dim = model.dim();
        // Per-client streams derive (purely) from the post-partition root
        // state, so paging a client in at round 40 yields bit-identical
        // state to the retired eager per-population construction.
        let clients = ClientStore::new(
            cfg.n_clients,
            StateTemplate {
                root: rng.clone(),
                dim,
                batch_size: cfg.batch_size,
                rounds: cfg.rounds,
                up_spec: cfg.uplink_spec(),
                train: Arc::clone(&train),
            },
        );
        let eval_set = eval_batches(&data.test, cfg.eval_batch);
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            cfg.threads
        };
        let x = model.init(&mut rng.derive(0x1217));
        // The pool is sized from `threads` alone: capping at
        // clients_per_round (the old policy) starved evaluation — with 2
        // sampled clients on a 16-core box, eval_batches ran on 2 workers.
        // Training fan-out still uses at most |S_r| workers per round
        // (map_worker caps at the item count), so nothing oversubscribes.
        let pool = ThreadPool::new(threads);
        // One workspace per worker slot, initialized empty: a slot's arena
        // is grown by its first `_into` call (Workspace::ensure), so slots
        // the run never exercises (pool wider than clients_per_round and
        // the eval batch count) cost nothing.
        let workspaces = (0..pool.size()).map(|_| Mutex::new(Workspace::new())).collect();
        let downlink = cfg.downlink_spec().build(cfg.rounds);
        Federation {
            model,
            trainer,
            clients,
            partition: part,
            eval_set,
            pool,
            workspaces,
            downlink,
            x,
            rng,
            data,
        }
    }

    /// Install a legacy algorithm spec's inline compressor as the uplink
    /// pipeline of every client (`fedcomloc-com:<spec>` /
    /// `sparsefedavg:<spec>` back-compat shim). No-op for the identity;
    /// panics when the run config *also* sets `compress_up` — the two
    /// grammars must not silently fight over the same link.
    pub fn install_uplink_shim(&mut self, spec: &CompressorSpec, cfg: &RunConfig) {
        if spec.is_identity() {
            return;
        }
        assert!(
            cfg.uplink_spec().is_identity(),
            "uplink compressor conflict: algorithm spec embeds '{}' but compress_up='{}' \
             is also set; use one or the other",
            spec.key(),
            cfg.compress_up
        );
        self.clients.set_uplink_spec(spec.clone(), cfg.rounds);
    }

    /// Install a legacy algorithm spec's inline compressor as the server
    /// broadcast pipeline (`fedcomloc-global:<spec>` back-compat shim).
    /// No-op for the identity; panics when `compress_down` is also set.
    pub fn install_downlink_shim(&mut self, spec: &CompressorSpec, cfg: &RunConfig) {
        if spec.is_identity() {
            return;
        }
        assert!(
            cfg.downlink_spec().is_identity(),
            "downlink compressor conflict: algorithm spec embeds '{}' but compress_down='{}' \
             is also set; use one or the other",
            spec.key(),
            cfg.compress_down
        );
        self.downlink = spec.build(cfg.rounds);
    }

    /// Sample the participating set S_r for a round (uniform w/o
    /// replacement, paper §4: 10 of 100) and page the cohort's state in.
    /// O(clients_per_round) per round — the sampler never touches the
    /// population size, and only the sampled ids are materialized.
    pub fn sample_clients(&mut self, m: usize) -> Vec<usize> {
        let n = self.clients.len();
        let sampled = self.rng.sample_without_replacement(n, m.min(n));
        self.clients.materialize_all(&sampled, &self.partition);
        sampled
    }

    /// Evaluate the current global model on the test set, fanning the eval
    /// batches out across the worker pool (one workspace per worker slot).
    ///
    /// Bit-identical to the sequential `trainer.eval`: per-batch
    /// (loss_sum, correct) pairs are computed independently — each batch's
    /// arithmetic is self-contained — and folded on the coordinator in
    /// batch order, exactly the order `model::eval_with` accumulates in.
    pub fn evaluate(&self) -> crate::model::EvalResult {
        let idx: Vec<usize> = (0..self.eval_set.batches.len()).collect();
        let parts: Vec<(f64, usize)> = self.pool.map_worker(&idx, |w, _, &bi| {
            let mut ws = self.workspaces[w].lock().unwrap();
            self.trainer.eval_batch(
                &self.x,
                &self.eval_set.batches[bi],
                self.eval_set.valid[bi],
                &mut ws,
            )
        });
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut examples = 0usize;
        for ((l, c), &valid) in parts.into_iter().zip(&self.eval_set.valid) {
            loss_sum += l;
            correct += c;
            examples += valid;
        }
        crate::model::EvalResult {
            mean_loss: loss_sum / examples.max(1) as f64,
            accuracy: correct as f64 / examples.max(1) as f64,
            examples,
        }
    }

    /// Sum of all control variates (invariant diagnostics; see tests).
    /// Never-materialized clients hold an implicit h_i = 0 and contribute
    /// nothing, so summing the residents in ascending id order equals the
    /// retired whole-population sum.
    pub fn control_variate_sum(&self) -> Vec<f32> {
        let dim = self.x.len();
        let mut acc = vec![0.0f32; dim];
        for id in self.clients.resident_ids_sorted() {
            let c = self.clients[id].lock().unwrap();
            crate::tensor::axpy(1.0, &c.h, &mut acc);
        }
        acc
    }
}

/// Shared bookkeeping for the per-round records the drive loop emits.
pub struct RoundLogger<'a> {
    /// The run's configuration (for τ and cadence-derived fields).
    pub cfg: &'a RunConfig,
    /// The log under construction.
    pub log: MetricsLog,
    cum_up: u64,
    cum_down: u64,
    cum_local_iters: u64,
    cum_sim_secs: f64,
    round_start: std::time::Instant,
}

impl<'a> RoundLogger<'a> {
    /// Start bookkeeping for a run whose records land in `log`.
    pub fn new(cfg: &'a RunConfig, log: MetricsLog) -> Self {
        Self {
            cfg,
            log,
            cum_up: 0,
            cum_down: 0,
            cum_local_iters: 0,
            cum_sim_secs: 0.0,
            round_start: std::time::Instant::now(),
        }
    }

    /// Mark the start of a round (for the wall-clock column).
    pub fn begin_round(&mut self) {
        self.round_start = std::time::Instant::now();
    }

    /// Fold one finished round into the log: cumulative bit/iteration
    /// totals, the §4.5 total-cost gauge, and the optional eval result.
    pub fn end_round(
        &mut self,
        round: usize,
        local_steps: usize,
        train_loss: f64,
        report: &transport::LinkReport,
        eval: Option<crate::model::EvalResult>,
    ) {
        self.cum_up += report.usage.uplink_bits;
        self.cum_down += report.usage.downlink_bits;
        self.cum_local_iters += local_steps as u64;
        self.cum_sim_secs += report.sim_secs;
        let total_cost =
            cost::total_cost(round as u64 + 1, self.cum_local_iters, self.cfg.tau);
        self.log.push(RoundRecord {
            round,
            local_steps,
            train_loss,
            test_loss: eval.as_ref().map(|e| e.mean_loss),
            test_accuracy: eval.as_ref().map(|e| e.accuracy),
            uplink_bits: report.usage.uplink_bits,
            downlink_bits: report.usage.downlink_bits,
            cum_uplink_bits: self.cum_up,
            cum_downlink_bits: self.cum_down,
            total_cost,
            wall_secs: self.round_start.elapsed().as_secs_f64(),
            sim_secs: report.sim_secs,
            cum_sim_secs: self.cum_sim_secs,
            dropped_clients: report.dropped_clients,
            stale_updates: report.stale_updates,
            churned_clients: report.churned_clients,
            corrupt_frames: report.corrupt_frames,
            retransmits: report.retransmits,
            dup_frames: report.dup_frames,
            backoff_secs: report.backoff_secs,
            aborted: report.aborted as u64,
        });
    }

    /// Hand back the completed log.
    pub fn finish(self) -> MetricsLog {
        self.log
    }

    /// Snapshot the cumulative counters `(cum_up, cum_down,
    /// cum_local_iters, cum_sim_secs)` for a checkpoint ([`crate::ckpt`]).
    pub fn cum_state(&self) -> (u64, u64, u64, f64) {
        (self.cum_up, self.cum_down, self.cum_local_iters, self.cum_sim_secs)
    }

    /// Restore a [`RoundLogger::cum_state`] snapshot on resume, so
    /// cumulative columns continue exactly where the checkpoint left off.
    pub fn restore_cum_state(&mut self, cum_up: u64, cum_down: u64, cum_local_iters: u64, cum_sim_secs: f64) {
        self.cum_up = cum_up;
        self.cum_down = cum_down;
        self.cum_local_iters = cum_local_iters;
        self.cum_sim_secs = cum_sim_secs;
    }
}

/// Run an algorithm to completion over the in-process transport (the seed's
/// semantics, byte-exact).
pub fn run(cfg: &RunConfig, trainer: Arc<dyn LocalTrainer>, spec: &AlgorithmSpec) -> MetricsLog {
    let mut transport = transport::InProc::default();
    run_with_transport(cfg, trainer, spec, &mut transport)
}

/// Run an algorithm to completion over an arbitrary transport, routed
/// through the round runtime `cfg.scenario` selects: the legacy lock-step
/// loop for `sync` (bit-identical to every pre-scenario release), the
/// discrete-event scheduler in [`sim`] for `semisync:<K>[@<a>]`.
pub fn run_with_transport(
    cfg: &RunConfig,
    trainer: Arc<dyn LocalTrainer>,
    spec: &AlgorithmSpec,
    transport: &mut dyn transport::Transport,
) -> MetricsLog {
    run_with_transport_observed(cfg, trainer, spec, transport, &mut NoopObserver)
        .expect("noop observer cannot fail")
}

/// [`run_with_transport`] with a [`DriveObserver`] in the loop — how the
/// checkpoint subsystem ([`crate::ckpt`]) attaches snapshot/resume/stop
/// behavior to any algorithm × scenario combination without the drive
/// loops knowing about snapshot files.
pub fn run_with_transport_observed(
    cfg: &RunConfig,
    trainer: Arc<dyn LocalTrainer>,
    spec: &AlgorithmSpec,
    transport: &mut dyn transport::Transport,
    observer: &mut dyn DriveObserver,
) -> Result<MetricsLog, String> {
    let mut algo = spec.build();
    let mut fed = Federation::new(cfg, trainer);
    let fault_spec = cfg.faults_spec();
    if fault_spec.is_none() {
        // No fault plane is constructed at all: `faults = "none"` is
        // bit-identical to every pre-fault-plane release by construction.
        dispatch_scenario(cfg, &mut fed, algo.as_mut(), transport, observer)
    } else {
        // The fault plane sits directly on the wire; a scenario decorator
        // (built inside the dispatch) stacks above it, folding the fault
        // plane's backoff time into its virtual clock.
        let mut fault_net = faults::FaultNet::new(transport, fault_spec, cfg.seed);
        dispatch_scenario(cfg, &mut fed, algo.as_mut(), &mut fault_net, observer)
    }
}

/// Route a prepared run through the round runtime `cfg.scenario` selects.
fn dispatch_scenario(
    cfg: &RunConfig,
    fed: &mut Federation,
    algo: &mut dyn FedAlgorithm,
    transport: &mut dyn transport::Transport,
    observer: &mut dyn DriveObserver,
) -> Result<MetricsLog, String> {
    match cfg.scenario_spec() {
        sim::Scenario::Sync => drive_federation_observed(cfg, fed, algo, transport, observer),
        scenario @ sim::Scenario::Semisync { .. } => {
            sim::drive_scenario_federation_observed(cfg, fed, algo, transport, &scenario, observer)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_keys_unique_and_resolvable() {
        let reg = algorithm_registry();
        let mut keys: Vec<_> = reg.iter().map(|f| f.key).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), reg.len(), "duplicate registry keys");
        for fam in reg {
            // Every family must build with its default argument.
            assert!(build_algorithm(fam.key).is_ok(), "{}", fam.key);
        }
    }

    #[test]
    fn spec_strings_resolve_to_expected_names() {
        let cases = [
            ("fedcomloc-com:topk:0.1", "fedcomloc-com[topk(0.10)]"),
            ("fedcomloc-com", "fedcomloc-com[identity]"),
            ("fedcomloc:topk:0.3", "fedcomloc-com[topk(0.30)]"),
            ("fedcomloc-local:topk:0.5", "fedcomloc-local[topk(0.50)]"),
            ("fedcomloc-global:q:8", "fedcomloc-global[q8]"),
            ("fedcomloc-com:topk:0.25+q:4", "fedcomloc-com[topk(0.25)+q4]"),
            ("fedavg", "fedavg"),
            ("fedavg:topk:0.3", "sparsefedavg[topk(0.30)]"),
            ("sparsefedavg", "sparsefedavg[topk(0.30)]"),
            ("scaffold", "scaffold"),
            ("feddyn", "feddyn[a=0.01]"),
            ("feddyn:0.1", "feddyn[a=0.1]"),
            ("FEDAVG", "fedavg"),
        ];
        for (spec, want) in cases {
            let parsed = AlgorithmSpec::parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(parsed.name(), want, "{spec}");
        }
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "nope",
            "fedcomloc-com:wat",
            "scaffold:7",
            "feddyn:zero",
            "feddyn:-1",
            "sparsefedavg:topk:0",
            // -Local needs a pure topk density for the in-graph mask;
            // anything else would silently run (and transmit) dense.
            "fedcomloc-local:q:8",
            "fedcomloc-local:randk:0.2",
            "fedcomloc-local:topk:0.5|q8",
            "fedcomloc-local:ef(topk:0.1)",
        ] {
            assert!(AlgorithmSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn cifar_preset_is_full_participation() {
        let cfg = RunConfig::default_cifar();
        assert_eq!(cfg.n_clients, 10);
        assert_eq!(cfg.clients_per_round, 10);
        assert!(cfg.clients_per_round <= cfg.n_clients);
        // The fields that used to leak in from the MNIST preset.
        assert_eq!(cfg.dataset, DatasetSpec::cifar10());
        assert_eq!(cfg.model_spec().key(), "cnn");
        assert_eq!(cfg.p, 0.1);
        assert_eq!(cfg.local_steps, 10);
        assert_eq!(cfg.eval_every, 5);
    }

    #[test]
    fn embedded_wire_specs_map_families_to_directions() {
        let up = |s: &str| embedded_wire_specs(s).unwrap().0.map(|c| c.key().to_string());
        let down = |s: &str| embedded_wire_specs(s).unwrap().1.map(|c| c.key().to_string());
        assert_eq!(up("fedcomloc-com:topk:0.1"), Some("topk:0.1".into()));
        assert_eq!(down("fedcomloc-com:topk:0.1"), None);
        assert_eq!(down("fedcomloc-global:q:8"), Some("q:8".into()));
        assert_eq!(up("fedcomloc-global:q:8"), None);
        // Identity args and non-wire families embed nothing.
        assert_eq!(embedded_wire_specs("fedcomloc-com").unwrap(), (None, None));
        assert_eq!(embedded_wire_specs("fedavg").unwrap(), (None, None));
        assert_eq!(embedded_wire_specs("scaffold").unwrap(), (None, None));
        assert_eq!(embedded_wire_specs("feddyn:0.1").unwrap(), (None, None));
        // -Local's arg is the in-graph mask density, not a wire codec.
        assert_eq!(embedded_wire_specs("fedcomloc-local:topk:0.5").unwrap(), (None, None));
        // sparsefedavg's default argument counts as embedded.
        assert_eq!(up("sparsefedavg"), Some("topk:0.3".into()));
        assert!(embedded_wire_specs("wat").is_err());
        // Only Scaffold multiplexes several vector streams per link.
        assert!(multiplexes_streams("scaffold").unwrap());
        for single in ["fedcomloc-com:topk:0.1", "fedavg", "feddyn:0.01", "fedcomloc-global"] {
            assert!(!multiplexes_streams(single).unwrap(), "{single}");
        }
        assert!(multiplexes_streams("wat").is_err());
    }

    #[test]
    fn stateful_pipeline_specs_resolve_through_the_registry() {
        for (spec, want) in [
            ("fedcomloc-com:ef(topk:0.1)", "fedcomloc-com[ef(topk(0.10))]"),
            ("fedcomloc-com:topk:0.1|q8", "fedcomloc-com[topk(0.10)+q8]"),
            (
                "fedcomloc-com:sched:topk:0.3..0.05@cosine",
                "fedcomloc-com[sched:topk:0.3..0.05@cosine]",
            ),
            ("fedcomloc-com:randk:0.2", "fedcomloc-com[randk(0.20)]"),
            ("fedcomloc-com:natural", "fedcomloc-com[natural]"),
        ] {
            let parsed = AlgorithmSpec::parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(parsed.name(), want, "{spec}");
        }
    }

    #[test]
    #[should_panic(expected = "uplink compressor conflict")]
    fn uplink_shim_conflicts_with_explicit_compress_up() {
        let cfg = RunConfig {
            train_n: 400,
            test_n: 100,
            n_clients: 4,
            clients_per_round: 2,
            rounds: 2,
            compress_up: "q8".to_string(),
            ..RunConfig::default_mnist()
        };
        let trainer =
            Arc::new(crate::model::native::NativeTrainer::from_spec("mlp").unwrap());
        let mut fed = Federation::new(&cfg, trainer);
        let shim = CompressorSpec::parse("topk:0.1").unwrap();
        fed.install_uplink_shim(&shim, &cfg);
    }

    #[test]
    #[should_panic(expected = "clients_per_round")]
    fn oversampled_federation_rejected() {
        let cfg = RunConfig {
            n_clients: 4,
            clients_per_round: 5,
            train_n: 200,
            test_n: 50,
            ..RunConfig::default_mnist()
        };
        let trainer =
            Arc::new(crate::model::native::NativeTrainer::from_spec("mlp").unwrap());
        let _ = Federation::new(&cfg, trainer);
    }
}
