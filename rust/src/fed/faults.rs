//! Deterministic fault-injection plane and recovery runtime.
//!
//! [`FaultSpec`] is an open string grammar (like
//! [`crate::compress::CompressorSpec`]) describing link faults —
//! `"corrupt:0.02|crash:0.01|dup:0.01|outage:0.005@3"` — and
//! [`FaultNet`] compiles it into a [`Transport`] decorator that injects
//! those faults *and* runs the recovery machinery that survives them:
//!
//! * **Frame corruption / truncation** (`corrupt:<p>`): each delivery may be
//!   damaged in flight. Damage is detected at the transport boundary — the
//!   damaged frame is actually produced byte-for-byte and pushed through
//!   [`Message::decode`], which must surface a structured
//!   [`crate::fed::message::WireError`] (never a panic, extending the
//!   `wire_fuzz` totality contract) or fail the modeled link-layer CRC
//!   ([`crate::util::bytes::crc32`]). Detected damage triggers a bounded
//!   retransmit (`retry:<n>`, default 2) with exponential backoff
//!   (`backoff:<secs>`, default 0.5) charged to the simulated clock and to
//!   the wire-bit accounting of the wrapped transport.
//! * **Mid-round client crashes** (`crash:<p>`): the client dies before its
//!   uplink reaches the wire; nothing is billed and the server aggregates
//!   without it.
//! * **Duplicated deliveries** (`dup:<p>`): a successful uplink arrives
//!   twice; the receiver deduplicates (the duplicate is billed and
//!   discarded) so aggregation is unaffected.
//! * **Transient link outages** (`outage:<p>@<secs>`): the client's link is
//!   down for `<secs>` simulated seconds, long enough to miss the round.
//! * **Quorum rounds** (`quorum:<f>`): after the per-round timeout the
//!   server aggregates whatever arrived if at least `ceil(f · sampled)`
//!   uplinks survived; otherwise the round is recorded as aborted and the
//!   drive loop carries the model over unchanged.
//!
//! Every draw comes from a dedicated RNG stream seeded `seed ^`
//! [`FAULT_SALT`], so the client-sampling, [`crate::fed::transport::SimNet`]
//! and [`crate::fed::sim::ScenarioNet`] streams are untouched and
//! `faults = "none"` is bit-identical to not constructing a [`FaultNet`] at
//! all — by construction, not by accident.
//!
//! Error feedback stays correct across recovery: a retransmit re-sends the
//! *identical already-encoded frame* (residuals were folded exactly once at
//! [`Message::through`] compress time), and a transmit that exhausts its
//! retries loses the update with the same semantics as an existing
//! `SimNet` dropout — the residual keeps the compression error of the
//! attempted send, which is the contract every driver already handles.
//!
//! All retries resolve within the round that issued them, so the only
//! cross-round fault state is the RNG cursor; [`Transport::save_state`]
//! persists it (nesting the wrapped transport's section) and crash+resume
//! under an active fault spec is therefore bit-identical.

use super::message::Message;
use super::transport::{LinkReport, Transport};
use crate::util::bytes::{crc32, ByteReader, ByteWriter};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// Salt XORed into the run seed for the fault plane's private RNG stream,
/// keeping it decoupled from every other consumer of the seed.
pub const FAULT_SALT: u64 = 0xFA01_7817;

/// Default bounded-retransmit attempt budget per frame (`retry:<n>`).
pub const DEFAULT_RETRY: u32 = 2;

/// Default base backoff in simulated seconds (`backoff:<secs>`); attempt
/// `k` waits `backoff · 2^(k-1)`.
pub const DEFAULT_BACKOFF_SECS: f64 = 0.5;

/// A parsed fault-plane specification.
///
/// Built by [`FaultSpec::parse`] from a `|`-separated clause list;
/// [`FaultSpec::key`] re-emits the canonical form (a fixpoint of `parse`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Per-delivery probability a frame is corrupted or truncated in
    /// flight (`corrupt:<p>`).
    pub corrupt: f64,
    /// Per-client per-round probability of a mid-round crash before the
    /// uplink reaches the wire (`crash:<p>`).
    pub crash: f64,
    /// Per-delivery probability a successful uplink is duplicated
    /// (`dup:<p>`).
    pub dup: f64,
    /// Per-client per-round probability of a transient link outage
    /// (`outage:<p>@<secs>`).
    pub outage_prob: f64,
    /// Duration of a transient outage in simulated seconds.
    pub outage_secs: f64,
    /// Minimum fraction of the sampled cohort whose uplinks must survive
    /// for the server to aggregate (`quorum:<f>`); `0` disables the check.
    pub quorum: f64,
    /// Bounded retransmit budget per frame (`retry:<n>`).
    pub retry: u32,
    /// Base exponential-backoff delay in simulated seconds
    /// (`backoff:<secs>`).
    pub backoff: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            corrupt: 0.0,
            crash: 0.0,
            dup: 0.0,
            outage_prob: 0.0,
            outage_secs: 0.0,
            quorum: 0.0,
            retry: DEFAULT_RETRY,
            backoff: DEFAULT_BACKOFF_SECS,
        }
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f64, String> {
    let p: f64 = v
        .parse()
        .map_err(|_| format!("fault clause '{key}': '{v}' is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("fault clause '{key}': probability {v} not in [0, 1]"));
    }
    Ok(p)
}

fn parse_nonneg(key: &str, v: &str) -> Result<f64, String> {
    let s: f64 = v
        .parse()
        .map_err(|_| format!("fault clause '{key}': '{v}' is not a number"))?;
    if !s.is_finite() || s < 0.0 {
        return Err(format!("fault clause '{key}': {v} must be finite and >= 0"));
    }
    Ok(s)
}

impl FaultSpec {
    /// Parse a fault spec string.
    ///
    /// Grammar: `|`-separated clauses from the registry `corrupt:<p>`,
    /// `crash:<p>`, `dup:<p>`, `outage:<p>@<secs>`, `quorum:<f>`,
    /// `retry:<n>`, `backoff:<secs>`. The strings `"none"` and `""` mean no
    /// fault plane. Probabilities must lie in `[0, 1]`; repeating a clause
    /// or naming an unknown one is an error.
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let mut out = FaultSpec::default();
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(out);
        }
        let mut seen = BTreeSet::new();
        for clause in spec.split('|') {
            let clause = clause.trim();
            let (key, value) = clause
                .split_once(':')
                .ok_or_else(|| format!("fault clause '{clause}': expected '<name>:<value>'"))?;
            if !seen.insert(key.to_string()) {
                return Err(format!("fault clause '{key}' given twice"));
            }
            match key {
                "corrupt" => out.corrupt = parse_prob(key, value)?,
                "crash" => out.crash = parse_prob(key, value)?,
                "dup" => out.dup = parse_prob(key, value)?,
                "outage" => {
                    let (p, secs) = value.split_once('@').ok_or_else(|| {
                        format!("fault clause 'outage': expected 'outage:<p>@<secs>', got '{clause}'")
                    })?;
                    out.outage_prob = parse_prob(key, p)?;
                    out.outage_secs = parse_nonneg(key, secs)?;
                }
                "quorum" => out.quorum = parse_prob(key, value)?,
                "retry" => {
                    out.retry = value
                        .parse()
                        .map_err(|_| format!("fault clause 'retry': '{value}' is not a count"))?;
                }
                "backoff" => out.backoff = parse_nonneg(key, value)?,
                other => {
                    return Err(format!(
                        "unknown fault clause '{other}' \
                         (known: corrupt, crash, dup, outage, quorum, retry, backoff)"
                    ))
                }
            }
        }
        Ok(out)
    }

    /// True when the spec injects nothing: no fault plane is constructed
    /// and the run is bit-identical to one with `faults = "none"`.
    pub fn is_none(&self) -> bool {
        self.corrupt == 0.0
            && self.crash == 0.0
            && self.dup == 0.0
            && self.outage_prob == 0.0
            && self.quorum == 0.0
    }

    /// Canonical spec string: active clauses in fixed order with default
    /// `retry`/`backoff` elided, or `"none"` when nothing is injected
    /// (no-op knobs on an inactive spec are dropped). A fixpoint of
    /// [`FaultSpec::parse`].
    pub fn key(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut clauses = Vec::new();
        if self.corrupt > 0.0 {
            clauses.push(format!("corrupt:{}", self.corrupt));
        }
        if self.crash > 0.0 {
            clauses.push(format!("crash:{}", self.crash));
        }
        if self.dup > 0.0 {
            clauses.push(format!("dup:{}", self.dup));
        }
        if self.outage_prob > 0.0 {
            clauses.push(format!("outage:{}@{}", self.outage_prob, self.outage_secs));
        }
        if self.quorum > 0.0 {
            clauses.push(format!("quorum:{}", self.quorum));
        }
        if self.retry != DEFAULT_RETRY {
            clauses.push(format!("retry:{}", self.retry));
        }
        if self.backoff != DEFAULT_BACKOFF_SECS {
            clauses.push(format!("backoff:{}", self.backoff));
        }
        clauses.join("|")
    }
}

/// A [`Transport`] decorator injecting the faults of a [`FaultSpec`] and
/// running the recovery runtime (integrity check → bounded retransmit with
/// exponential backoff → quorum accounting).
///
/// Stacking order is `ScenarioNet(FaultNet(SimNet | InProc))`: the fault
/// plane sits directly on the wire so corruption, retransmit billing and
/// outages apply to physical deliveries, while the scenario engine above it
/// keeps its own virtual clock (it folds [`LinkReport::backoff_secs`] into
/// the round's simulated time).
///
/// Fault fates are decided *once per client per round* on first touch —
/// matching the [`Transport`] contract that repeated broadcasts (and
/// multi-vector uplinks like Scaffold's `(Δx, Δc)`) see one coherent
/// participant set.
pub struct FaultNet<'a> {
    inner: &'a mut dyn Transport,
    spec: FaultSpec,
    rng: Rng,
    /// Round stamped by the first broadcast; uplinks from other rounds are
    /// stale replays and are rejected.
    round: Option<u32>,
    /// Size of the sampled cohort (first broadcast's target list), the
    /// quorum denominator.
    expected: usize,
    /// Sticky per-round downlink fate per client.
    down_ok: BTreeMap<usize, bool>,
    /// Sticky per-round uplink fate per client.
    up_ok: BTreeMap<usize, bool>,
    /// Clients whose uplink survived this round (quorum numerator).
    delivered: BTreeSet<usize>,
    corrupt_frames: u64,
    retransmits: u64,
    dup_frames: u64,
    stale_frames: u64,
    faulted_clients: u64,
    backoff_secs: f64,
}

impl<'a> FaultNet<'a> {
    /// Wrap `inner` with the fault plane described by `spec`, drawing all
    /// fault randomness from the stream `seed ^ FAULT_SALT`.
    pub fn new(inner: &'a mut dyn Transport, spec: FaultSpec, seed: u64) -> FaultNet<'a> {
        FaultNet {
            inner,
            spec,
            rng: Rng::seed_from_u64(seed ^ FAULT_SALT),
            round: None,
            expected: 0,
            down_ok: BTreeMap::new(),
            up_ok: BTreeMap::new(),
            delivered: BTreeSet::new(),
            corrupt_frames: 0,
            retransmits: 0,
            dup_frames: 0,
            stale_frames: 0,
            faulted_clients: 0,
            backoff_secs: 0.0,
        }
    }

    /// Stale uplink frames rejected at the boundary this round (replays
    /// carrying a round stamp other than the current one).
    pub fn stale_frames(&self) -> u64 {
        self.stale_frames
    }

    /// Produce the damaged frame byte-for-byte and verify the boundary
    /// detects it: either [`Message::decode`] surfaces a structured
    /// [`crate::fed::message::WireError`] (the totality contract — no
    /// panics), or decode still succeeds and the modeled link-layer CRC
    /// catches the damage. Returns `true` when the damage was detected;
    /// the injected damage always changes at least one byte, so the CRC
    /// backstop makes silent acceptance impossible.
    fn damage_detected(&mut self, msg: &Message) -> bool {
        let mut bytes = msg.encode();
        let clean_crc = crc32(&bytes);
        if self.rng.bernoulli(0.25) {
            // Truncation: the tail never made it.
            let keep = self.rng.below_usize(bytes.len());
            bytes.truncate(keep);
        } else {
            // Bit rot: flip 1–4 bytes with a nonzero xor mask.
            let flips = 1 + self.rng.below_usize(4);
            for _ in 0..flips {
                let pos = self.rng.below_usize(bytes.len());
                let mask = (self.rng.next_u64() as u8) | 1;
                bytes[pos] ^= mask;
            }
        }
        match Message::decode(&bytes) {
            Err(_) => true,
            Ok(_) => crc32(&bytes) != clean_crc,
        }
    }

    /// Charge one backoff delay for retransmit attempt `attempt` (1-based).
    fn charge_backoff(&mut self, attempt: u32) {
        self.retransmits += 1;
        self.backoff_secs += self.spec.backoff * f64::powi(2.0, attempt as i32 - 1);
    }

    /// Decide a client's downlink fate for the round: outage check, then a
    /// corruption/retransmit loop. The first transmission was already
    /// billed by the wrapping [`FaultNet::broadcast`]; every retransmit is
    /// billed through the inner transport here.
    fn resolve_downlink(&mut self, client: usize, msg: &Message) -> bool {
        if self.spec.outage_prob > 0.0 && self.rng.bernoulli(self.spec.outage_prob) {
            // Link down for the outage window: the client misses the round.
            self.backoff_secs += self.spec.outage_secs;
            self.faulted_clients += 1;
            return false;
        }
        let mut attempt = 0u32;
        loop {
            let corrupted = self.spec.corrupt > 0.0 && self.rng.bernoulli(self.spec.corrupt);
            if !corrupted {
                return true;
            }
            self.corrupt_frames += 1;
            let detected = self.damage_detected(msg);
            assert!(detected, "fault plane injected undetectable frame damage");
            if attempt >= self.spec.retry {
                self.faulted_clients += 1;
                return false;
            }
            attempt += 1;
            self.charge_backoff(attempt);
            self.inner.broadcast(&[client], msg);
        }
    }

    /// Decide a client's uplink fate: crash check, then the
    /// corruption/retransmit loop. Damaged transmissions are billed as they
    /// happen; the final clean transmission is billed by the caller.
    fn resolve_uplink(&mut self, client: usize, msg: &Message) -> bool {
        if self.spec.crash > 0.0 && self.rng.bernoulli(self.spec.crash) {
            // Crashed mid-round: nothing reached the wire, nothing billed.
            self.faulted_clients += 1;
            return false;
        }
        let mut attempt = 0u32;
        loop {
            let corrupted = self.spec.corrupt > 0.0 && self.rng.bernoulli(self.spec.corrupt);
            if !corrupted {
                return true;
            }
            self.corrupt_frames += 1;
            let detected = self.damage_detected(msg);
            assert!(detected, "fault plane injected undetectable frame damage");
            // The damaged transmission still crossed (and is billed on)
            // the wire.
            self.inner.uplink(client, msg.clone());
            if attempt >= self.spec.retry {
                self.faulted_clients += 1;
                return false;
            }
            attempt += 1;
            self.charge_backoff(attempt);
        }
    }
}

impl Transport for FaultNet<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn broadcast(&mut self, clients: &[usize], msg: &Message) -> Vec<usize> {
        let reached = self.inner.broadcast(clients, msg);
        if self.round.is_none() {
            self.round = Some(msg.header.round);
            self.expected = clients.len();
        }
        let mut out = Vec::with_capacity(reached.len());
        for &c in &reached {
            let ok = match self.down_ok.get(&c) {
                Some(&ok) => ok,
                None => {
                    let ok = self.resolve_downlink(c, msg);
                    self.down_ok.insert(c, ok);
                    ok
                }
            };
            if ok {
                out.push(c);
            }
        }
        out
    }

    fn uplink(&mut self, client: usize, msg: Message) -> Option<Message> {
        if let Some(round) = self.round {
            if msg.header.round != round {
                // Replayed stale frame: rejected at the boundary.
                self.stale_frames += 1;
                return None;
            }
        }
        let ok = match self.up_ok.get(&client) {
            Some(&ok) => ok,
            None => {
                let ok = self.resolve_uplink(client, &msg);
                self.up_ok.insert(client, ok);
                ok
            }
        };
        if !ok {
            return None;
        }
        let received = self.inner.uplink(client, msg)?;
        if self.spec.dup > 0.0 && self.rng.bernoulli(self.spec.dup) {
            // Duplicated delivery: billed on the wire, deduplicated here.
            self.dup_frames += 1;
            let _ = self.inner.uplink(client, received.clone());
        }
        self.delivered.insert(client);
        Some(received)
    }

    fn end_round(&mut self) -> LinkReport {
        let mut report = self.inner.end_round();
        report.corrupt_frames += self.corrupt_frames;
        report.retransmits += self.retransmits;
        report.dup_frames += self.dup_frames;
        report.dropped_clients += self.faulted_clients;
        report.backoff_secs += self.backoff_secs;
        report.sim_secs += self.backoff_secs;
        if self.spec.quorum > 0.0 && self.expected > 0 {
            let needed = (self.spec.quorum * self.expected as f64).ceil() as usize;
            if self.delivered.len() < needed {
                report.aborted = true;
            }
        }
        self.round = None;
        self.expected = 0;
        self.down_ok.clear();
        self.up_ok.clear();
        self.delivered.clear();
        self.corrupt_frames = 0;
        self.retransmits = 0;
        self.dup_frames = 0;
        self.stale_frames = 0;
        self.faulted_clients = 0;
        self.backoff_secs = 0.0;
        report
    }

    fn link_secs(&self, client: usize, bits: u64) -> f64 {
        self.inner.link_secs(client, bits)
    }

    fn save_state(&self) -> Vec<u8> {
        // Retries resolve within their round, so the only cross-round
        // fault state is the RNG cursor; the wrapped transport's section
        // nests after it.
        let mut w = ByteWriter::new();
        w.put_rng(&self.rng);
        w.put_bytes(&self.inner.save_state());
        w.into_bytes()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = ByteReader::new(bytes, "faultnet state");
        self.rng = r.take_rng()?;
        let inner = r.take_bytes()?;
        r.finish()?;
        self.inner.restore_state(&inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::message::SERVER;
    use crate::fed::transport::InProc;

    fn spec(s: &str) -> FaultSpec {
        FaultSpec::parse(s).unwrap()
    }

    #[test]
    fn parse_full_grammar_and_key_fixpoint() {
        let s = spec("corrupt:0.02|crash:0.01|dup:0.01|outage:0.005@3|quorum:0.6|retry:4|backoff:0.25");
        assert_eq!(s.corrupt, 0.02);
        assert_eq!(s.crash, 0.01);
        assert_eq!(s.dup, 0.01);
        assert_eq!(s.outage_prob, 0.005);
        assert_eq!(s.outage_secs, 3.0);
        assert_eq!(s.quorum, 0.6);
        assert_eq!(s.retry, 4);
        assert_eq!(s.backoff, 0.25);
        let key = s.key();
        assert_eq!(
            key,
            "corrupt:0.02|crash:0.01|dup:0.01|outage:0.005@3|quorum:0.6|retry:4|backoff:0.25"
        );
        assert_eq!(spec(&key).key(), key, "key() must be a parse fixpoint");
    }

    #[test]
    fn none_empty_and_zero_probs_are_none() {
        assert!(spec("none").is_none());
        assert!(spec("").is_none());
        assert!(spec("corrupt:0").is_none());
        assert_eq!(spec("corrupt:0").key(), "none");
        // No-op knobs without an active fault collapse to none.
        assert_eq!(spec("retry:9").key(), "none");
        // Defaults are elided from canonical keys.
        assert_eq!(spec("corrupt:0.1|retry:2|backoff:0.5").key(), "corrupt:0.1");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "corrupt",             // missing value
            "corrupt:1.5",         // out of range
            "corrupt:x",           // not a number
            "corrupt:0.1|corrupt:0.2", // duplicate clause
            "outage:0.1",          // missing @secs
            "outage:0.1@-2",       // negative duration
            "retry:-1",            // not a count
            "jitter:0.5",          // unknown clause
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "expected error for {bad:?}");
        }
    }

    fn msg(round: usize, d: usize) -> Message {
        Message::dense(round, SERVER, &vec![1.0f32; d])
    }

    #[test]
    fn injected_damage_is_always_detected() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("corrupt:1"), 7);
        let m = msg(0, 17);
        for _ in 0..200 {
            assert!(net.damage_detected(&m));
        }
    }

    #[test]
    fn retransmit_recovers_and_is_billed() {
        // corrupt:0.5 with a deep retry budget: every delivery eventually
        // succeeds, corruption is observed, and retransmits are billed.
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("corrupt:0.5|retry:16"), 3);
        let clients = [0usize, 1, 2, 3];
        let delivered = net.broadcast(&clients, &msg(0, 8));
        assert_eq!(delivered, clients, "deep retries always recover");
        for &c in &clients {
            let up = net.uplink(c, msg(0, 8)).expect("uplink recovers");
            assert_eq!(up.header.sender, SERVER);
        }
        let report = net.end_round();
        assert!(report.corrupt_frames > 0, "corruption must have been observed");
        assert_eq!(report.retransmits, report.corrupt_frames);
        assert!(report.backoff_secs > 0.0);
        assert!(report.sim_secs >= report.backoff_secs);
        assert!(!report.aborted);
        // A fault-free run bills one broadcast and four uplink messages;
        // every corrupted transmission on top of that was also billed.
        let clean_msgs = 1 + clients.len() as u64;
        assert_eq!(
            report.usage.downlink_msgs + report.usage.uplink_msgs,
            clean_msgs + report.corrupt_frames
        );
    }

    #[test]
    fn exhausted_retries_lose_the_client() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("corrupt:1|retry:1"), 11);
        let delivered = net.broadcast(&[0, 1], &msg(0, 4));
        assert!(delivered.is_empty(), "corrupt:1 can never deliver");
        let report = net.end_round();
        assert_eq!(report.dropped_clients, 2);
        assert_eq!(report.retransmits, 2, "one bounded retry per client");
        assert_eq!(report.corrupt_frames, 4, "initial + retry per client");
    }

    #[test]
    fn crash_loses_uplink_without_billing() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("crash:1"), 5);
        let delivered = net.broadcast(&[0], &msg(0, 4));
        assert_eq!(delivered, vec![0], "crash only affects uplinks");
        assert!(net.uplink(0, msg(0, 4)).is_none());
        // Sticky within the round: a second stream from the same client is
        // also lost (coherent participant set).
        assert!(net.uplink(0, msg(0, 4)).is_none());
        let report = net.end_round();
        assert_eq!(report.dropped_clients, 1);
        assert_eq!(report.usage.uplink_msgs, 0, "a crashed client bills nothing");
    }

    #[test]
    fn duplicates_are_billed_and_deduplicated() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("dup:1"), 9);
        net.broadcast(&[0, 1], &msg(0, 6));
        for c in 0..2 {
            assert!(net.uplink(c, msg(0, 6)).is_some(), "dup never loses data");
        }
        let report = net.end_round();
        assert_eq!(report.dup_frames, 2);
        assert_eq!(report.usage.uplink_msgs, 4, "each duplicate is billed");
    }

    #[test]
    fn stale_replayed_frames_are_rejected() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("dup:0.5"), 13);
        net.broadcast(&[0], &msg(3, 4));
        assert!(net.uplink(0, msg(1, 4)).is_none(), "round-1 frame in round 3");
        assert_eq!(net.stale_frames(), 1);
        assert!(net.uplink(0, msg(3, 4)).is_some(), "current round passes");
    }

    #[test]
    fn quorum_aborts_round_below_threshold() {
        let mut inner = InProc::default();
        let mut net = FaultNet::new(&mut inner, spec("crash:1|quorum:0.5"), 1);
        net.broadcast(&[0, 1, 2, 3], &msg(0, 4));
        for c in 0..4 {
            assert!(net.uplink(c, msg(0, 4)).is_none());
        }
        let report = net.end_round();
        assert!(report.aborted, "0/4 uplinks < quorum 0.5");
        // Per-round state cleared: a clean next round is not aborted.
        let mut inner2 = InProc::default();
        let mut ok = FaultNet::new(&mut inner2, spec("quorum:0.5"), 1);
        ok.broadcast(&[0, 1], &msg(0, 4));
        ok.uplink(0, msg(0, 4)).unwrap();
        ok.uplink(1, msg(0, 4)).unwrap();
        assert!(!ok.end_round().aborted);
    }

    #[test]
    fn same_seed_same_faults_and_state_roundtrips() {
        let run = |seed: u64| {
            let mut inner = InProc::default();
            let mut net = FaultNet::new(&mut inner, spec("corrupt:0.3|dup:0.2"), seed);
            let mut reports = Vec::new();
            for round in 0..4 {
                net.broadcast(&[0, 1, 2], &msg(round, 8));
                for c in 0..3 {
                    net.uplink(c, msg(round, 8));
                }
                let r = net.end_round();
                reports.push((r.corrupt_frames, r.retransmits, r.dup_frames));
            }
            reports
        };
        assert_eq!(run(42), run(42), "identical seed, identical fault stream");
        assert_ne!(run(42), run(43), "fault stream is seed-dependent");

        // Saving at a round boundary and restoring onto a fresh decorator
        // continues the identical fault stream.
        let mut inner_a = InProc::default();
        let mut a = FaultNet::new(&mut inner_a, spec("corrupt:0.3|dup:0.2"), 42);
        a.broadcast(&[0, 1, 2], &msg(0, 8));
        for c in 0..3 {
            a.uplink(c, msg(0, 8));
        }
        a.end_round();
        let state = a.save_state();
        let mut inner_b = InProc::default();
        let mut b = FaultNet::new(&mut inner_b, spec("corrupt:0.3|dup:0.2"), 999);
        b.restore_state(&state).unwrap();
        fn drive(net: &mut FaultNet<'_>) -> (u64, u64, u64) {
            net.broadcast(&[0, 1, 2], &msg(1, 8));
            for c in 0..3 {
                net.uplink(c, msg(1, 8));
            }
            let r = net.end_round();
            (r.corrupt_frames, r.retransmits, r.dup_frames)
        }
        assert_eq!(drive(&mut a), drive(&mut b), "restored RNG continues the stream");
    }
}
