//! Pure-Rust [`LocalTrainer`]: the PJRT-free twin of the AOT artifacts.
//!
//! Used by unit/property tests and fast CPU benches, and as the numeric
//! cross-check for the HLO programs (identical parameter layout and loss;
//! see `rust/tests/integration_fed.rs` and `runtime_artifacts.rs`). The
//! production path is `runtime::PjrtTrainer`.

use super::{cnn, eval_with, mlp, EvalResult, LocalTrainer, ModelKind};
use crate::data::loader::{Batch, EvalBatches};

#[derive(Debug, Clone, Copy)]
pub struct NativeTrainer {
    kind: ModelKind,
}

impl NativeTrainer {
    pub fn new(kind: ModelKind) -> Self {
        Self { kind }
    }
}

impl LocalTrainer for NativeTrainer {
    fn model(&self) -> ModelKind {
        self.kind
    }

    fn grad(&self, params: &[f32], batch: &Batch) -> (Vec<f32>, f32) {
        assert_eq!(params.len(), self.kind.dim());
        assert_eq!(batch.feature_dim, self.kind.input_dim());
        match self.kind {
            ModelKind::Mlp => mlp::grad(params, &batch.x, &batch.y),
            ModelKind::Cnn => cnn::grad(params, &batch.x, &batch.y),
        }
    }

    fn eval(&self, params: &[f32], batches: &EvalBatches) -> EvalResult {
        eval_with(batches, |batch, valid| match self.kind {
            ModelKind::Mlp => mlp::eval_batch(params, &batch.x, &batch.y, valid),
            ModelKind::Cnn => cnn::eval_batch(params, &batch.x, &batch.y, valid),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::{eval_batches, ClientLoader};
    use crate::data::{synthetic, DatasetKind};
    use crate::model::init_params;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn train_step_matches_manual_composition() {
        let mut rng = Rng::seed_from_u64(1);
        let tt = synthetic::generate(DatasetKind::Mnist, 64, 16, &mut rng);
        let data = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&data), (0..64).collect(), 8, Rng::seed_from_u64(2));
        let batch = loader.next_batch();
        let trainer = NativeTrainer::new(ModelKind::Mlp);
        let params = init_params(ModelKind::Mlp, &mut rng);
        let h: Vec<f32> = (0..params.len()).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let gamma = 0.1;
        let (stepped, loss) = trainer.train_step(&params, &h, &batch, gamma);
        let (g, loss2) = trainer.grad(&params, &batch);
        assert_eq!(loss, loss2);
        for i in 0..params.len() {
            let expect = params[i] - gamma * (g[i] - h[i]);
            assert!((stepped[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn masked_step_uses_compressed_gradient_point() {
        let mut rng = Rng::seed_from_u64(3);
        let tt = synthetic::generate(DatasetKind::Mnist, 32, 8, &mut rng);
        let data = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&data), (0..32).collect(), 8, Rng::seed_from_u64(4));
        let batch = loader.next_batch();
        let trainer = NativeTrainer::new(ModelKind::Mlp);
        let params = init_params(ModelKind::Mlp, &mut rng);
        let h = vec![0.0f32; params.len()];
        // density=1.0 must equal the unmasked step exactly.
        let (full, _) = trainer.train_step(&params, &h, &batch, 0.1);
        let (masked_full, _) = trainer.train_step_masked(&params, &h, &batch, 0.1, 1.0);
        assert_eq!(full, masked_full);
        // A tiny density must differ (gradient at a heavily masked model).
        let (masked_tiny, _) = trainer.train_step_masked(&params, &h, &batch, 0.1, 0.01);
        assert_ne!(full, masked_tiny);
    }

    #[test]
    fn federated_local_epochs_learn_on_synthetic_mnist() {
        // Single-client sanity: 60 local SGD steps should beat chance
        // accuracy clearly (>30% over 10 classes).
        let mut rng = Rng::seed_from_u64(5);
        let tt = synthetic::generate(DatasetKind::Mnist, 512, 256, &mut rng);
        let train = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&train), (0..512).collect(), 32, Rng::seed_from_u64(6));
        let trainer = NativeTrainer::new(ModelKind::Mlp);
        let mut params = init_params(ModelKind::Mlp, &mut rng);
        let h = vec![0.0f32; params.len()];
        for _ in 0..300 {
            let batch = loader.next_batch();
            let (next, _) = trainer.train_step(&params, &h, &batch, 0.05);
            params = next;
        }
        let eb = eval_batches(&tt.test, 64);
        let result = trainer.eval(&params, &eb);
        assert!(
            result.accuracy > 0.6,
            "accuracy too low: {}",
            result.accuracy
        );
        assert_eq!(result.examples, 256);
    }
}
