"""L2 model definitions over flat parameter vectors (mlp, cnn)."""

from . import cnn, mlp  # noqa: F401
