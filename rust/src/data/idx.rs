//! Loaders for the real datasets' on-disk formats:
//!
//! * MNIST IDX (`train-images-idx3-ubyte`, `train-labels-idx1-ubyte`, and
//!   the `t10k-*` pair), optionally `.gz`-less raw files only (no flate2
//!   dependency on this path — the vendored flate2 belongs to `xla`'s build
//!   graph; users should gunzip first, as the README notes);
//! * CIFAR-10 binary batches (`data_batch_{1..5}.bin`, `test_batch.bin`),
//!   3073-byte records: 1 label byte + 3×32×32 pixel bytes.
//!
//! When the files are absent, [`try_load`] returns `None` and the caller
//! falls back to the synthetic generators.

use super::{DataSource, Dataset, DatasetSpec, TrainTest};
use std::io::Read;
use std::path::Path;

/// Attempt to load real data; `None` when files are missing/corrupt, or
/// when the spec has no real-file backing (pure-synthetic specs).
pub fn try_load(
    spec: &DatasetSpec,
    dir: &Path,
    train_n: usize,
    test_n: usize,
) -> Option<TrainTest> {
    match spec.source() {
        DataSource::MnistIdx => {
            let train = load_mnist_pair(
                &dir.join("train-images-idx3-ubyte"),
                &dir.join("train-labels-idx1-ubyte"),
                train_n,
            )?;
            let test = load_mnist_pair(
                &dir.join("t10k-images-idx3-ubyte"),
                &dir.join("t10k-labels-idx1-ubyte"),
                test_n,
            )?;
            Some(TrainTest { train, test })
        }
        DataSource::CifarBin => {
            let train_files: Vec<_> = (1..=5)
                .map(|i| dir.join(format!("data_batch_{i}.bin")))
                .collect();
            let train = load_cifar_batches(&train_files, train_n)?;
            let test = load_cifar_batches(&[dir.join("test_batch.bin")], test_n)?;
            Some(TrainTest { train, test })
        }
        DataSource::Synthetic => None,
    }
}

fn read_all(path: &Path) -> Option<Vec<u8>> {
    let mut buf = Vec::new();
    std::fs::File::open(path).ok()?.read_to_end(&mut buf).ok()?;
    Some(buf)
}

fn be_u32(b: &[u8], off: usize) -> Option<u32> {
    Some(u32::from_be_bytes([
        *b.get(off)?,
        *b.get(off + 1)?,
        *b.get(off + 2)?,
        *b.get(off + 3)?,
    ]))
}

/// Parse an IDX3 image file + IDX1 label file into a Dataset (pixels → [0,1]).
fn load_mnist_pair(images: &Path, labels: &Path, limit: usize) -> Option<Dataset> {
    let img = read_all(images)?;
    let lab = read_all(labels)?;
    if be_u32(&img, 0)? != 0x0000_0803 || be_u32(&lab, 0)? != 0x0000_0801 {
        log::warn!("bad IDX magic in {} / {}", images.display(), labels.display());
        return None;
    }
    let n_img = be_u32(&img, 4)? as usize;
    let rows = be_u32(&img, 8)? as usize;
    let cols = be_u32(&img, 12)? as usize;
    let n_lab = be_u32(&lab, 4)? as usize;
    if rows != 28 || cols != 28 || n_img != n_lab {
        return None;
    }
    let n = n_img.min(limit.max(1));
    let dim = rows * cols;
    if img.len() < 16 + n * dim || lab.len() < 8 + n {
        return None;
    }
    let features: Vec<f32> = img[16..16 + n * dim]
        .iter()
        .map(|&b| b as f32 / 255.0)
        .collect();
    let labels_v: Vec<u8> = lab[8..8 + n].to_vec();
    if labels_v.iter().any(|&l| l > 9) {
        return None;
    }
    Some(Dataset {
        spec: DatasetSpec::mnist(),
        features,
        labels: labels_v,
        feature_dim: dim,
        num_classes: 10,
    })
}

/// Parse CIFAR-10 binary batches (label byte + 3072 pixel bytes per record).
fn load_cifar_batches(paths: &[std::path::PathBuf], limit: usize) -> Option<Dataset> {
    const REC: usize = 3073;
    let mut features = Vec::new();
    let mut labels = Vec::new();
    for path in paths {
        let buf = read_all(path)?;
        if buf.len() % REC != 0 {
            return None;
        }
        for rec in buf.chunks_exact(REC) {
            if labels.len() >= limit {
                break;
            }
            let label = rec[0];
            if label > 9 {
                return None;
            }
            labels.push(label);
            features.extend(rec[1..].iter().map(|&b| b as f32 / 255.0));
        }
    }
    if labels.is_empty() {
        return None;
    }
    Some(Dataset {
        spec: DatasetSpec::cifar10(),
        features,
        labels,
        feature_dim: 3072,
        num_classes: 10,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx_pair(dir: &Path, prefix: &str, n: usize) {
        let mut img = Vec::new();
        img.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        for i in 0..n * 784 {
            img.push((i % 251) as u8);
        }
        let mut lab = Vec::new();
        lab.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        lab.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lab.push((i % 10) as u8);
        }
        std::fs::File::create(dir.join(format!("{prefix}-images-idx3-ubyte")))
            .unwrap()
            .write_all(&img)
            .unwrap();
        std::fs::File::create(dir.join(format!("{prefix}-labels-idx1-ubyte")))
            .unwrap()
            .write_all(&lab)
            .unwrap();
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("fedcomloc_idx_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn loads_synthetic_idx_files() {
        let dir = tmpdir("mnist");
        write_idx_pair(&dir, "train", 50);
        write_idx_pair(&dir, "t10k", 20);
        let tt = try_load(&DatasetSpec::mnist(), &dir, 40, 20).unwrap();
        assert_eq!(tt.train.len(), 40); // truncated to limit
        assert_eq!(tt.test.len(), 20);
        assert_eq!(tt.train.labels[3], 3);
        assert!((tt.train.features[1] - 1.0 / 255.0).abs() < 1e-6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_files_return_none() {
        assert!(try_load(&DatasetSpec::mnist(), Path::new("/nonexistent"), 10, 10).is_none());
        assert!(try_load(&DatasetSpec::cifar10(), Path::new("/nonexistent"), 10, 10).is_none());
        // Pure-synthetic specs never load from disk.
        let synth = DatasetSpec::parse("synthetic:64").unwrap();
        assert!(try_load(&synth, Path::new("/nonexistent"), 10, 10).is_none());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = tmpdir("badmagic");
        std::fs::write(dir.join("train-images-idx3-ubyte"), [0u8; 32]).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), [0u8; 16]).unwrap();
        assert!(try_load(&DatasetSpec::mnist(), &dir, 10, 10).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn loads_cifar_binary() {
        let dir = tmpdir("cifar");
        for b in 1..=5 {
            let mut buf = Vec::new();
            for rec in 0..10 {
                buf.push((rec % 10) as u8);
                buf.extend(std::iter::repeat(128u8).take(3072));
            }
            std::fs::write(dir.join(format!("data_batch_{b}.bin")), &buf).unwrap();
        }
        let mut buf = Vec::new();
        for rec in 0..10 {
            buf.push((rec % 10) as u8);
            buf.extend(std::iter::repeat(64u8).take(3072));
        }
        std::fs::write(dir.join("test_batch.bin"), &buf).unwrap();
        let tt = try_load(&DatasetSpec::cifar10(), &dir, 30, 10).unwrap();
        assert_eq!(tt.train.len(), 30);
        assert_eq!(tt.test.len(), 10);
        assert_eq!(tt.train.feature_dim, 3072);
        assert!((tt.test.features[0] - 64.0 / 255.0).abs() < 1e-6);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
