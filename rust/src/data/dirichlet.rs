//! Dirichlet label-skew federated partitioning (paper §4 "Heterogeneous
//! Setting", Appendix B.1; FedLab-style LDA partitioning).
//!
//! For each class c, draw proportions over the n clients from Dir(α·1_n)
//! and split that class's examples accordingly. Smaller α ⇒ each class
//! concentrates on fewer clients ⇒ more heterogeneity (Figure 11). α → ∞
//! approaches a uniform IID split.

use super::Dataset;
use crate::util::rng::Rng;

/// Partition result: per-client example indices into the source dataset.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Example indices per client, in client order.
    pub client_indices: Vec<Vec<usize>>,
    /// The Dirichlet concentration this partition was drawn with.
    pub alpha: f64,
}

impl Partition {
    /// Number of clients the data was split over.
    pub fn num_clients(&self) -> usize {
        self.client_indices.len()
    }

    /// Per-client class histogram (rows: clients, cols: classes) — the data
    /// behind the paper's Figure 11 visualization.
    pub fn class_histogram(&self, data: &Dataset) -> Vec<Vec<usize>> {
        self.client_indices
            .iter()
            .map(|idx| {
                let mut h = vec![0usize; data.num_classes];
                for &i in idx {
                    h[data.labels[i] as usize] += 1;
                }
                h
            })
            .collect()
    }

    /// Mean (over clients) total-variation distance between the client's
    /// class distribution and the global one — a scalar heterogeneity gauge
    /// used in tests and data-stats output.
    pub fn heterogeneity_tv(&self, data: &Dataset) -> f64 {
        let global = data.class_counts();
        let gtotal: usize = global.iter().sum();
        let gdist: Vec<f64> = global.iter().map(|&c| c as f64 / gtotal as f64).collect();
        let hists = self.class_histogram(data);
        let mut acc = 0.0;
        let mut counted = 0usize;
        for h in &hists {
            let total: usize = h.iter().sum();
            if total == 0 {
                continue;
            }
            let tv: f64 = h
                .iter()
                .zip(&gdist)
                .map(|(&c, &g)| (c as f64 / total as f64 - g).abs())
                .sum::<f64>()
                / 2.0;
            acc += tv;
            counted += 1;
        }
        if counted == 0 {
            0.0
        } else {
            acc / counted as f64
        }
    }
}

/// Dirichlet partition of `data` into `n_clients` shards.
///
/// Guarantees: every example is assigned exactly once; every client receives
/// at least `min_per_client` examples (rebalanced from the largest shards —
/// without this, tiny-α draws can leave clients empty, which would make the
/// paper's 10-of-100 sampling degenerate).
pub fn partition(
    data: &Dataset,
    n_clients: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> Partition {
    assert!(n_clients > 0);
    assert!(alpha > 0.0, "Dirichlet alpha must be positive");
    // Bucket example ids by class, shuffled.
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.num_classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    for bucket in &mut by_class {
        rng.shuffle(bucket);
    }

    let mut client_indices: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for bucket in &by_class {
        if bucket.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, n_clients);
        // Largest-remainder allocation of bucket.len() items by props.
        let n = bucket.len();
        let mut alloc: Vec<usize> = props.iter().map(|&p| (p * n as f64).floor() as usize).collect();
        let mut assigned: usize = alloc.iter().sum();
        // Distribute the remainder to the largest fractional parts.
        let mut frac: Vec<(f64, usize)> = props
            .iter()
            .enumerate()
            .map(|(i, &p)| (p * n as f64 - (p * n as f64).floor(), i))
            .collect();
        frac.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut fi = 0;
        while assigned < n {
            alloc[frac[fi % n_clients].1] += 1;
            assigned += 1;
            fi += 1;
        }
        let mut cursor = 0;
        for (client, &take) in alloc.iter().enumerate() {
            client_indices[client].extend_from_slice(&bucket[cursor..cursor + take]);
            cursor += take;
        }
        debug_assert_eq!(cursor, n);
    }

    // Rebalance: top up clients below the floor from the largest shards.
    let floor = min_per_client.min(data.len() / n_clients.max(1));
    loop {
        let (small_i, small_n) = client_indices
            .iter()
            .enumerate()
            .map(|(i, v)| (i, v.len()))
            .min_by_key(|&(_, n)| n)
            .unwrap();
        if small_n >= floor {
            break;
        }
        let (big_i, _) = client_indices
            .iter()
            .enumerate()
            .map(|(i, v)| (i, v.len()))
            .max_by_key(|&(_, n)| n)
            .unwrap();
        let moved = client_indices[big_i].pop().expect("donor shard empty");
        client_indices[small_i].push(moved);
    }

    for shard in &mut client_indices {
        rng.shuffle(shard);
    }
    Partition {
        client_indices,
        alpha,
    }
}

/// Render the Figure 11-style per-client class distribution as text (rows:
/// first `max_clients` clients; one bar per class).
pub fn render_histogram(partition: &Partition, data: &Dataset, max_clients: usize) -> String {
    let hist = partition.class_histogram(data);
    let mut out = String::new();
    out.push_str(&format!(
        "client-class distribution (alpha={}, showing {} of {} clients)\n",
        partition.alpha,
        max_clients.min(hist.len()),
        hist.len()
    ));
    for (c, h) in hist.iter().take(max_clients).enumerate() {
        let total: usize = h.iter().sum();
        out.push_str(&format!("client {c:>3} ({total:>5} ex): "));
        for &count in h {
            let frac = if total == 0 { 0.0 } else { count as f64 / total as f64 };
            let bar = (frac * 20.0).round() as usize;
            out.push_str(&format!("{:>4}|{}", count, "#".repeat(bar)));
            out.push(' ');
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, DatasetSpec};

    fn dataset(n: usize) -> Dataset {
        let mut rng = Rng::seed_from_u64(9);
        synthetic::generate(&DatasetSpec::mnist(), n, 10, &mut rng).train
    }

    #[test]
    fn partition_covers_all_examples_once() {
        let data = dataset(2000);
        let mut rng = Rng::seed_from_u64(1);
        let p = partition(&data, 100, 0.7, 5, &mut rng);
        let mut seen = vec![false; data.len()];
        for shard in &p.client_indices {
            for &i in shard {
                assert!(!seen[i], "example {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some examples unassigned");
    }

    #[test]
    fn min_per_client_enforced() {
        let data = dataset(2000);
        let mut rng = Rng::seed_from_u64(2);
        let p = partition(&data, 100, 0.1, 5, &mut rng);
        assert!(p.client_indices.iter().all(|s| s.len() >= 5));
    }

    #[test]
    fn smaller_alpha_is_more_heterogeneous() {
        let data = dataset(4000);
        let mut tvs = Vec::new();
        for &alpha in &[0.1, 0.5, 1.0, 10.0, 1000.0] {
            let mut rng = Rng::seed_from_u64(3);
            let p = partition(&data, 20, alpha, 1, &mut rng);
            tvs.push(p.heterogeneity_tv(&data));
        }
        // TV distance should decrease (weakly) as alpha grows.
        for w in tvs.windows(2) {
            assert!(
                w[0] >= w[1] - 0.02,
                "heterogeneity not monotone: {tvs:?}"
            );
        }
        assert!(tvs[0] > 0.4, "alpha=0.1 should be very skewed: {tvs:?}");
        assert!(*tvs.last().unwrap() < 0.15, "alpha=1000 nearly IID: {tvs:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let data = dataset(500);
        let mut r1 = Rng::seed_from_u64(4);
        let mut r2 = Rng::seed_from_u64(4);
        let p1 = partition(&data, 10, 0.7, 1, &mut r1);
        let p2 = partition(&data, 10, 0.7, 1, &mut r2);
        assert_eq!(p1.client_indices, p2.client_indices);
    }

    #[test]
    fn histogram_shape_and_render() {
        let data = dataset(500);
        let mut rng = Rng::seed_from_u64(5);
        let p = partition(&data, 10, 0.3, 1, &mut rng);
        let h = p.class_histogram(&data);
        assert_eq!(h.len(), 10);
        assert_eq!(h[0].len(), 10);
        let total: usize = h.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(total, data.len());
        let text = render_histogram(&p, &data, 5);
        assert!(text.contains("client   0"));
    }
}
