//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-check numerics against the native Rust compute plane.
//!
//! These tests require `make artifacts` (external data: HLO/PJRT artifacts)
//! and are `#[ignore]`d so tier-1 `cargo test` runs clean on a fresh
//! checkout; run them with `cargo test -- --ignored` after building the
//! artifacts. Each also self-skips with a note if the manifest is absent.

use fedcomloc::data::loader::{eval_batches, ClientLoader};
use fedcomloc::data::{synthetic, DatasetSpec};
use fedcomloc::model::native::NativeTrainer;
use fedcomloc::model::{build_model, init_params, LocalTrainer, Model};

fn mlp() -> Model {
    build_model("mlp").unwrap()
}
use fedcomloc::runtime::engine::Input;
use fedcomloc::runtime::{artifacts_available, default_artifacts_dir, Engine, PjrtTrainer};
use fedcomloc::util::rng::Rng;
use std::sync::Arc;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = default_artifacts_dir();
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn mnist_batch(batch: usize, seed: u64) -> fedcomloc::data::loader::Batch {
    let mut rng = Rng::seed_from_u64(seed);
    let tt = synthetic::generate(&DatasetSpec::mnist(), 256, 64, &mut rng);
    let data = Arc::new(tt.train);
    let mut loader = ClientLoader::new(
        Arc::clone(&data),
        (0..256).collect(),
        batch,
        Rng::seed_from_u64(seed + 1),
    );
    loader.next_batch()
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn pjrt_grad_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtTrainer::load(&dir, &mlp()).expect("load artifacts");
    let native = NativeTrainer::new(mlp());
    let mut rng = Rng::seed_from_u64(7);
    let params = init_params(&mlp(), &mut rng);
    let batch = mnist_batch(pjrt.batch_size(), 11);

    let (g_pjrt, loss_pjrt) = pjrt.grad(&params, &batch);
    let (g_native, loss_native) = native.grad(&params, &batch);
    assert!(
        (loss_pjrt - loss_native).abs() < 1e-3,
        "loss: pjrt {loss_pjrt} native {loss_native}"
    );
    assert_eq!(g_pjrt.len(), g_native.len());
    let dot = fedcomloc::tensor::dot(&g_pjrt, &g_native);
    let cos = dot
        / (fedcomloc::tensor::norm2(&g_pjrt) * fedcomloc::tensor::norm2(&g_native)).max(1e-12);
    assert!(cos > 0.9999, "gradient cosine {cos}");
    let max_err = g_pjrt
        .iter()
        .zip(&g_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max |Δg| {max_err}");
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn pjrt_train_step_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtTrainer::load(&dir, &mlp()).expect("load artifacts");
    let native = NativeTrainer::new(mlp());
    let mut rng = Rng::seed_from_u64(9);
    let params = init_params(&mlp(), &mut rng);
    let mut h = vec![0.0f32; params.len()];
    rng.fill_normal_f32(&mut h, 0.0, 0.01);
    let batch = mnist_batch(pjrt.batch_size(), 13);

    let (x_pjrt, _) = pjrt.train_step(&params, &h, &batch, 0.05);
    let (x_native, _) = native.train_step(&params, &h, &batch, 0.05);
    let dist = fedcomloc::tensor::l2_distance(&x_pjrt, &x_native);
    let scale = fedcomloc::tensor::norm2(&x_native);
    assert!(dist / scale < 1e-5, "relative step distance {}", dist / scale);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn pjrt_masked_step_density_one_matches_plain() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtTrainer::load(&dir, &mlp()).expect("load artifacts");
    let mut rng = Rng::seed_from_u64(15);
    let params = init_params(&mlp(), &mut rng);
    let h = vec![0.0f32; params.len()];
    let batch = mnist_batch(pjrt.batch_size(), 17);
    let (plain, _) = pjrt.train_step(&params, &h, &batch, 0.05);
    let (masked, _) = pjrt.train_step_masked(&params, &h, &batch, 0.05, 1.0);
    let dist = fedcomloc::tensor::l2_distance(&plain, &masked);
    assert!(dist < 1e-4, "density=1 masked step differs: {dist}");
    // Low density must actually change the gradient point.
    let (masked_low, _) = pjrt.train_step_masked(&params, &h, &batch, 0.05, 0.05);
    assert!(fedcomloc::tensor::l2_distance(&plain, &masked_low) > 1e-4);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn pjrt_eval_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtTrainer::load(&dir, &mlp()).expect("load artifacts");
    let native = NativeTrainer::new(mlp());
    let mut rng = Rng::seed_from_u64(21);
    let params = init_params(&mlp(), &mut rng);
    let tt = synthetic::generate(&DatasetSpec::mnist(), 64, 300, &mut rng);
    let eb = eval_batches(&tt.test, pjrt.eval_batch_size());
    let r_pjrt = pjrt.eval(&params, &eb);
    let r_native = native.eval(&params, &eb);
    assert_eq!(r_pjrt.examples, r_native.examples);
    assert_eq!(r_pjrt.accuracy, r_native.accuracy, "accuracy must match exactly");
    assert!((r_pjrt.mean_loss - r_native.mean_loss).abs() < 1e-4);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn quantize_artifact_matches_rust_wire_codec() {
    // The standalone Pallas quantizer and the Rust QSGD codec implement the
    // same Definition 3.2 — drive both with the same uniforms and compare.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir, &["quantize"]).expect("load quantize");
    let spec = engine.manifest().artifact("quantize").unwrap().clone();
    let d = spec.inputs[0].elements();
    let mut rng = Rng::seed_from_u64(31);
    let mut x = vec![0.0f32; d];
    rng.fill_normal_f32(&mut x, 0.0, 1.0);
    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);
    let bits = 6u32;

    let outs = engine
        .call(
            "quantize",
            &[Input::F32(&x), Input::F32(&u), Input::ScalarF32(bits as f32)],
        )
        .expect("execute quantize");
    let q_pallas = outs[0].as_f32();

    // Reference computation with the same uniforms (single global bucket,
    // deterministic rounding: up iff u < frac).
    let norm = fedcomloc::tensor::norm2(&x);
    let s = (1u64 << bits) as f64;
    let mut max_err = 0.0f32;
    for i in 0..d {
        let y = (x[i].abs() / norm) as f64;
        let scaled = y * s;
        let lo = scaled.floor();
        let level = if (u[i] as f64) < scaled - lo { lo + 1.0 } else { lo };
        let want = (norm as f64 * x[i].signum() as f64 * level / s) as f32;
        max_err = max_err.max((want - q_pallas[i]).abs());
    }
    assert!(max_err < 1e-4 * norm, "pallas-vs-rust max err {max_err}");
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts): PJRT plane not built in tier-1 CI"]
fn pjrt_federated_smoke() {
    // Whole-stack: FedComLoc-Com on the AOT plane for a few rounds.
    let Some(dir) = artifacts_dir() else { return };
    use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
    let cfg = RunConfig {
        train_n: 1_000,
        test_n: 256,
        n_clients: 10,
        clients_per_round: 3,
        rounds: 4,
        eval_every: 2,
        eval_batch: 256,
        ..RunConfig::default_mnist()
    };
    let trainer = Arc::new(PjrtTrainer::load(&dir, &mlp()).unwrap());
    let spec = AlgorithmSpec::parse("fedcomloc-com:topk:0.3").unwrap();
    let log = run(&cfg, trainer, &spec);
    assert_eq!(log.records.len(), 4);
    assert!(log.best_accuracy().is_some());
    assert!(log.records[0].uplink_bits > 0);
}
