//! Table 1 + Figure 1: TopK sparsity sweep on FedMNIST.
//!
//! Regenerates the paper's accuracy row and bits-axis series at bench scale
//! (env FEDCOMLOC_BENCH_ROUNDS to widen), and times each full federated run
//! so the communication/computation trade is visible in wall clock too.

mod common;

use fedcomloc::fed::{run, AlgorithmSpec};

fn spec(density: f64) -> AlgorithmSpec {
    common::fedcomloc_topk(density)
}

fn main() {
    println!("== Table 1 / Figure 1: Top-K ratios (bench scale) ==");
    let trainer = common::mlp_trainer();
    let mut baseline = None;
    let mut rows = Vec::new();
    for &density in &[1.0, 0.10, 0.30, 0.50, 0.70, 0.90] {
        let cfg = common::mnist_cfg();
        let t0 = std::time::Instant::now();
        let log = run(&cfg, trainer.clone(), &spec(density));
        let wall = t0.elapsed();
        let acc = log.best_accuracy().unwrap_or(0.0);
        if density >= 1.0 {
            baseline = Some(acc);
        }
        common::row(
            &format!("K={:>3.0}% ({wall:.2?})", density * 100.0),
            acc,
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits(),
        );
        rows.push((density, acc, log.total_uplink_bits()));
    }
    if let Some(b) = baseline {
        println!("\n  Decrease vs K=100% (paper Table 1 row 2):");
        for &(d, a, _) in &rows {
            if d < 1.0 {
                println!("    K={:>3.0}%: {:+.2}%", d * 100.0, (b - a) / b * 100.0);
            }
        }
    }
    let dense_bits = rows[0].2 as f64;
    let k10_bits = rows[1].2 as f64;
    println!(
        "\n  bits ratio K=10% vs dense: {:.3} (paper: ≈0.10 of uplink payload)",
        k10_bits / dense_bits
    );
}
