"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematical definition of the corresponding
kernel in this package, written with plain jax.numpy ops only. pytest +
hypothesis sweep shapes/dtypes and assert_allclose kernel-vs-ref; the AOT
artifacts are only ever built from kernels that pass those checks.
"""

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "none"):
    """y = act(x @ w + b) — oracle for kernels.dense.dense."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def sgd_cv_ref(x, g, h, gamma):
    """Scaffnew local step x − γ·(g − h) — oracle for kernels.sgd_cv."""
    return x - gamma * (g - h)


def topk_threshold_ref(x, k):
    """|value| of the k-th largest-magnitude entry of flat x (k ≥ 1)."""
    mags = jnp.sort(jnp.abs(x.reshape(-1)))
    d = mags.shape[0]
    idx = jnp.clip(d - k, 0, d - 1)
    return mags[idx]


def topk_mask_ref(x, threshold):
    """Keep entries with |x| ≥ threshold — oracle for kernels.topk.mask."""
    return jnp.where(jnp.abs(x) >= threshold, x, jnp.zeros_like(x))


def topk_ref(x, density):
    """Full TopK by density ratio (Definition 3.1; ties keep ≥K entries)."""
    d = x.reshape(-1).shape[0]
    k = jnp.clip(jnp.ceil(density * d).astype(jnp.int32), 1, d)
    return topk_mask_ref(x, topk_threshold_ref(x, k))


def quantize_ref(x, u, bits):
    """Stochastic quantizer Q_r (Definition 3.2) with externalized noise.

    u ∈ [0,1) supplies the stochastic-rounding uniforms, making the operator
    a deterministic function of (x, u) — which is what lets pytest compare
    the Pallas kernel against this oracle exactly, and the Rust runtime test
    cross-check the wire codec against the compiled artifact.
    """
    s = jnp.float32(2.0) ** jnp.float32(bits)
    norm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    safe = jnp.where(norm > 0, norm, jnp.float32(1.0))
    y = jnp.abs(x) / safe
    scaled = y * s
    lo = jnp.floor(scaled)
    frac = scaled - lo
    level = lo + (u < frac).astype(jnp.float32)
    q = norm * jnp.sign(x) * level / s
    return jnp.where(norm > 0, q, jnp.zeros_like(x))
