//! Integration tests: the federated algorithms end-to-end on the native
//! compute plane (synthetic FedMNIST, scaled-down configs), through the
//! `FedAlgorithm` + `Transport` API.

use fedcomloc::data::DatasetSpec;
use fedcomloc::fed::transport::{InProc, SimNet, SimNetCfg};
use fedcomloc::fed::{run, run_with_transport, AlgorithmSpec, RunConfig};
use fedcomloc::model::native::NativeTrainer;
use fedcomloc::model::ModelSpec;
use std::sync::Arc;

/// d of the seed MLP (the registry's `mlp` spec).
fn mlp_dim() -> usize {
    ModelSpec::parse("mlp").unwrap().dim()
}

fn quick_cfg() -> RunConfig {
    RunConfig {
        train_n: 2_000,
        test_n: 500,
        n_clients: 20,
        clients_per_round: 5,
        rounds: 25,
        eval_every: 5,
        gamma: 0.05,
        ..RunConfig::default_mnist()
    }
}

fn native() -> Arc<NativeTrainer> {
    Arc::new(NativeTrainer::from_spec("mlp").unwrap())
}

fn algo(spec: &str) -> AlgorithmSpec {
    AlgorithmSpec::parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"))
}

#[test]
fn fedcomloc_com_learns_and_counts_bits() {
    let cfg = quick_cfg();
    let log = run(&cfg, native(), &algo("fedcomloc-com:topk:0.3"));
    assert_eq!(log.records.len(), 25);
    let acc = log.best_accuracy().unwrap();
    assert!(acc > 0.45, "accuracy {acc}");
    // Compressed uplink must be well below dense uplink.
    let dense_bits = 32 * mlp_dim() as u64 * cfg.clients_per_round as u64;
    let r0 = &log.records[0];
    assert!(r0.uplink_bits < dense_bits / 2, "uplink {}", r0.uplink_bits);
    assert_eq!(r0.downlink_bits, dense_bits);
    // Cumulative counters are monotone; in-process transport simulates no
    // network time and drops nobody.
    for w in log.records.windows(2) {
        assert!(w[1].cum_uplink_bits > w[0].cum_uplink_bits);
        assert!(w[1].total_cost > w[0].total_cost);
    }
    assert!(log.records.iter().all(|r| r.sim_secs == 0.0 && r.dropped_clients == 0));
}

#[test]
fn fedcomloc_uncompressed_beats_chance_quickly() {
    let cfg = quick_cfg();
    let log = run(&cfg, native(), &algo("fedcomloc-com:none"));
    assert!(log.best_accuracy().unwrap() > 0.5);
    // Identity uplink counts full dense bits.
    let dense_bits = 32 * mlp_dim() as u64 * cfg.clients_per_round as u64;
    assert_eq!(log.records[0].uplink_bits, dense_bits);
}

#[test]
fn variants_all_run_and_learn() {
    for variant in ["com", "local", "global"] {
        let cfg = quick_cfg();
        let log = run(
            &cfg,
            native(),
            &algo(&format!("fedcomloc-{variant}:topk:0.5")),
        );
        let acc = log.best_accuracy().unwrap();
        assert!(acc > 0.35, "variant {variant} acc {acc}");
        if variant == "global" {
            // Downlink compressed after the first aggregation.
            let later = &log.records[3];
            let dense =
                32 * mlp_dim() as u64 * cfg.clients_per_round as u64;
            assert!(later.downlink_bits < dense, "downlink {}", later.downlink_bits);
        }
    }
}

#[test]
fn quantized_fedcomloc_learns() {
    let cfg = quick_cfg();
    let log = run(&cfg, native(), &algo("fedcomloc-com:q:8"));
    assert!(log.best_accuracy().unwrap() > 0.45);
    // 8-bit quantization: ~10 bits/coord on our wire vs 32 dense.
    let dense_bits = 32 * mlp_dim() as u64 * cfg.clients_per_round as u64;
    assert!(log.records[0].uplink_bits < dense_bits / 3 + 64_000);
}

#[test]
fn baselines_run_and_learn() {
    let cfg = quick_cfg();
    for spec in ["fedavg", "sparsefedavg:topk:0.3", "scaffold", "feddyn:0.01"] {
        let spec = algo(spec);
        let name = spec.name();
        let log = run(&cfg, native(), &spec);
        let acc = log.best_accuracy().unwrap();
        assert!(acc > 0.3, "{name} acc {acc}");
        assert_eq!(log.records.len(), cfg.rounds);
    }
}

#[test]
fn scaffold_uplink_is_double() {
    let cfg = quick_cfg();
    let log = run(&cfg, native(), &algo("scaffold"));
    let dense_bits = 32 * mlp_dim() as u64 * cfg.clients_per_round as u64;
    assert_eq!(log.records[0].uplink_bits, 2 * dense_bits);
    assert_eq!(log.records[0].downlink_bits, 2 * dense_bits);
}

#[test]
fn control_variate_sum_stays_zero_for_com() {
    // Σ h_i = 0 is Algorithm 1's invariant under -Com (exact averaging).
    use fedcomloc::fed::{drive_federation, Federation};
    let cfg = quick_cfg();
    let mut fed = Federation::new(&cfg, native());
    let mut algorithm = algo("fedcomloc-com:topk:0.3").build();
    let mut transport = InProc::default();
    let log = drive_federation(&cfg, &mut fed, algorithm.as_mut(), &mut transport);
    assert!(log.best_accuracy().is_some());
    let h_sum = fed.control_variate_sum();
    let norm = fedcomloc::tensor::norm2(&h_sum);
    // f32 accumulation over 25 rounds: tolerance scales with dim.
    assert!(norm < 0.05, "sum of control variates drifted: {norm}");
}

#[test]
fn deterministic_given_seed() {
    let cfg = quick_cfg();
    let a = run(&cfg, native(), &algo("fedcomloc-com:topk:0.3"));
    let b = run(&cfg, native(), &algo("fedcomloc-com:topk:0.3"));
    let accs_a: Vec<_> = a.records.iter().map(|r| r.test_accuracy).collect();
    let accs_b: Vec<_> = b.records.iter().map(|r| r.test_accuracy).collect();
    assert_eq!(accs_a, accs_b);
    assert_eq!(
        a.records.last().unwrap().cum_uplink_bits,
        b.records.last().unwrap().cum_uplink_bits
    );
}

#[test]
fn smaller_p_means_fewer_comm_rounds_per_iteration() {
    // With p = 0.5 vs p = 0.05 the same number of communication rounds
    // consumes ~10x fewer local iterations.
    let mut cfg = quick_cfg();
    cfg.rounds = 20;
    cfg.p = 0.5;
    let log_hi = run(&cfg, native(), &algo("fedcomloc-com:none"));
    cfg.p = 0.05;
    let log_lo = run(&cfg, native(), &algo("fedcomloc-com:none"));
    let iters_hi: usize = log_hi.records.iter().map(|r| r.local_steps).sum();
    let iters_lo: usize = log_lo.records.iter().map(|r| r.local_steps).sum();
    assert!(
        iters_lo > 4 * iters_hi,
        "p=0.05 iters {iters_lo} vs p=0.5 iters {iters_hi}"
    );
    // And total cost reflects the τ-weighted tradeoff.
    let cost_hi = log_hi.records.last().unwrap().total_cost;
    let cost_lo = log_lo.records.last().unwrap().total_cost;
    assert!(cost_lo > cost_hi);
}

#[test]
fn dataset_kind_cifar_runs_with_native_cnn() {
    // Tiny CNN smoke (native conv is slow; keep rounds minimal).
    let cfg = RunConfig {
        dataset: DatasetSpec::cifar10(),
        train_n: 320,
        test_n: 64,
        n_clients: 4,
        clients_per_round: 2,
        rounds: 2,
        p: 0.5,
        batch_size: 16,
        eval_batch: 32,
        eval_every: 2,
        ..RunConfig::default_cifar()
    };
    let trainer = Arc::new(NativeTrainer::from_spec("cnn").unwrap());
    let log = run(&cfg, trainer, &algo("fedcomloc-com:topk:0.3"));
    assert_eq!(log.records.len(), 2);
    assert!(log.best_accuracy().is_some());
}

#[test]
fn simnet_smoke_accounts_latency_and_drops() {
    // The SimNet transport must feed nonzero simulated wall-clock and drop
    // accounting into RoundRecord without changing algorithm code.
    let cfg = RunConfig {
        rounds: 10,
        ..quick_cfg()
    };
    let sim = SimNetCfg {
        bandwidth_bps: 5e6,
        latency_secs: 0.05,
        drop_prob: 0.3,
        heterogeneity: 4.0,
    };
    let mut transport = SimNet::new(sim, cfg.seed);
    let log = run_with_transport(
        &cfg,
        native(),
        &algo("fedcomloc-com:topk:0.3"),
        &mut transport,
    );
    assert_eq!(log.records.len(), cfg.rounds);
    // Every round with at least one participant has >= latency of sim time.
    assert!(log.records.iter().all(|r| r.sim_secs > 0.0 || r.dropped_clients == 5));
    let total_sim = log.records.last().unwrap().cum_sim_secs;
    assert!(total_sim > 0.0, "no simulated time accumulated");
    let total_drops: u64 = log.records.iter().map(|r| r.dropped_clients).sum();
    assert!(
        total_drops > 0,
        "p=0.3 over {} client-rounds produced no drops",
        cfg.rounds * cfg.clients_per_round
    );
    // Cumulative sim clock is monotone.
    for w in log.records.windows(2) {
        assert!(w[1].cum_sim_secs >= w[0].cum_sim_secs);
    }
    // Dropped clients don't train: with drops the run still completes and
    // still learns something.
    assert!(log.best_accuracy().unwrap() > 0.3);
}

#[test]
fn simnet_is_deterministic_given_seed() {
    let cfg = RunConfig {
        rounds: 6,
        ..quick_cfg()
    };
    let sim = SimNetCfg {
        drop_prob: 0.2,
        ..SimNetCfg::default()
    };
    let run_once = || {
        let mut transport = SimNet::new(sim, cfg.seed);
        run_with_transport(&cfg, native(), &algo("fedcomloc-com:topk:0.3"), &mut transport)
    };
    let a = run_once();
    let b = run_once();
    let key = |log: &fedcomloc::metrics::MetricsLog| -> Vec<(u64, u64, u64)> {
        log.records
            .iter()
            .map(|r| (r.uplink_bits, r.downlink_bits, r.dropped_clients))
            .collect()
    };
    assert_eq!(key(&a), key(&b));
    assert_eq!(
        a.records.last().unwrap().cum_sim_secs,
        b.records.last().unwrap().cum_sim_secs
    );
}
