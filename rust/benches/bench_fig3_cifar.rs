//! Figure 3: CNN on FedCIFAR10 — density sweep, tuned vs fixed stepsize.

mod common;

use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};

fn spec(density: f64) -> AlgorithmSpec {
    common::fedcomloc_topk(density)
}

fn main() {
    println!("== Figure 3: CNN / FedCIFAR10 (bench scale) ==");
    let trainer = common::cnn_trainer();
    println!("-- tuned γ per density (grid 0.01/0.05) --");
    for &density in &[1.0, 0.10, 0.50] {
        let mut best = (0.0f64, 0.0f32, 0u64);
        for &gamma in &[0.01f32, 0.05] {
            let cfg = RunConfig {
                gamma,
                ..common::cifar_cfg()
            };
            let log = run(&cfg, trainer.clone(), &spec(density));
            let acc = log.best_accuracy().unwrap_or(0.0);
            if acc > best.0 {
                best = (acc, gamma, log.total_uplink_bits());
            }
        }
        common::row(
            &format!("K={:>3.0}% tuned γ={}", density * 100.0, best.1),
            best.0,
            f64::NAN,
            best.2,
        );
    }
    println!("-- fixed γ=0.01 --");
    for &density in &[1.0, 0.10, 0.50] {
        let cfg = RunConfig {
            gamma: 0.01,
            ..common::cifar_cfg()
        };
        let log = run(&cfg, trainer.clone(), &spec(density));
        common::row(
            &format!("K={:>3.0}% fixed", density * 100.0),
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits(),
        );
    }
    println!("\n  paper shape: per-bit, sparsified converge faster when γ tuned;");
    println!("  at fixed small γ, K=10% is slowest per round.");
}
