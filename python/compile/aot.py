"""AOT lowering: JAX/Pallas programs -> artifacts/*.hlo.txt + manifest.json.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here. `make artifacts` skips the rebuild when inputs are
unchanged, and the Rust binary is self-contained afterwards.

Usage: python -m compile.aot [--out DIR] [--models mlp,cnn] [--check]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_program(name, program):
    """Lower one (model, program) pair; returns (hlo_text, manifest entry)."""
    fn = M.PROGRAMS[program](name)
    args = M.example_args(name, program)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *args)
    flat_out = jax.tree_util.tree_leaves(out_shapes)
    entry = {
        "file": f"{name}_{program}.hlo.txt",
        "inputs": [_shape_entry(a) for a in args],
        "outputs": [_shape_entry(o) for o in flat_out],
    }
    return text, entry


def lower_quantize(dim=8192):
    fn = M.build_quantize()
    S = jax.ShapeDtypeStruct
    args = (S((dim,), jnp.float32), S((dim,), jnp.float32), S((), jnp.float32))
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    entry = {
        "file": "quantize.hlo.txt",
        "inputs": [_shape_entry(a) for a in args],
        "outputs": [{"shape": [dim], "dtype": "float32"}],
    }
    return text, entry


def build_all(out_dir, models=("mlp", "cnn")):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "hlo": "text", "artifacts": {}, "models": {}}
    for name in models:
        model = M.MODELS[name]
        manifest["models"][name] = {
            "dim": model.DIM,
            "batch": M.BATCH[name],
            "eval_batch": M.EVAL_BATCH[name],
            "input_shape": list(M.INPUT_SHAPE[name]),
            "num_classes": 10,
        }
        for program in M.PROGRAMS:
            key = f"{name}_{program}"
            print(f"lowering {key} ...", flush=True)
            text, entry = lower_program(name, program)
            path = os.path.join(out_dir, entry["file"])
            with open(path, "w") as f:
                f.write(text)
            entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
            manifest["artifacts"][key] = entry
            print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)
    print("lowering quantize ...", flush=True)
    text, entry = lower_quantize()
    with open(os.path.join(out_dir, entry["file"]), "w") as f:
        f.write(text)
    entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
    manifest["artifacts"]["quantize"] = entry

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest with {len(manifest['artifacts'])} artifacts -> {out_dir}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default="mlp,cnn",
        help="comma-separated subset of models to lower",
    )
    args = ap.parse_args(argv)
    models = tuple(m for m in args.models.split(",") if m)
    for m in models:
        if m not in M.MODELS:
            ap.error(f"unknown model {m!r}")
    build_all(args.out, models)


if __name__ == "__main__":
    sys.exit(main())
