//! Figures 5/7/14/15: quantization sweep + heterogeneity ablation.

mod common;

use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};

fn spec(bits: u32) -> AlgorithmSpec {
    common::algo(&format!("fedcomloc-com:q:{bits}"))
}

fn main() {
    println!("== Figure 5: Q_r sweep on FedMNIST (bench scale) ==");
    let trainer = common::mlp_trainer();
    let mut base = 0.0;
    for &bits in &[32u32, 16, 8, 4] {
        let cfg = common::mnist_cfg();
        let log = run(&cfg, trainer.clone(), &spec(bits));
        let acc = log.best_accuracy().unwrap_or(0.0);
        if bits == 32 {
            base = acc;
        }
        common::row(
            &format!("r={bits:>2} (Δ vs r32 {:+.2}%)", (base - acc) / base.max(1e-9) * 100.0),
            acc,
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits(),
        );
    }

    println!("\n== Figures 7/14: Q_r × α (bench scale) ==");
    for &bits in &[8u32, 16] {
        for &alpha in &[0.1, 0.7] {
            let cfg = RunConfig {
                dirichlet_alpha: alpha,
                ..common::mnist_cfg()
            };
            let log = run(&cfg, trainer.clone(), &spec(bits));
            common::row(
                &format!("r={bits:>2} α={alpha}"),
                log.best_accuracy().unwrap_or(0.0),
                log.final_train_loss().unwrap_or(f64::NAN),
                log.total_uplink_bits(),
            );
        }
    }

    println!("\n== Figure 15: Q_r on FedCIFAR10 (bench scale) ==");
    let trainer = common::cnn_trainer();
    for &bits in &[32u32, 8] {
        let cfg = common::cifar_cfg();
        let log = run(&cfg, trainer.clone(), &spec(bits));
        common::row(
            &format!("cifar r={bits:>2}"),
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits(),
        );
    }
    println!("\n  paper shape: r=16 ≈ free (−0.14%), minor sensitivity to α.");
}
