"""Shared Pallas plumbing: tiling helpers for 1-D elementwise kernels.

TPU-minded structure even though we lower with interpret=True for the CPU
PJRT plugin (see DESIGN.md §Hardware-Adaptation): elementwise work is tiled
into (8, 128) VPU-shaped lanes, wide vectors are padded up to a whole number
of tiles, and each grid step touches one VMEM-sized block. The same helpers
serve the topk-mask, quantize, and sgd_cv kernels so they all share one
audited schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VPU tile: 8 sublanes × 128 lanes of f32.
SUBLANES = 8
LANES = 128
TILE = SUBLANES * LANES
# Max elements per grid step: 2^18 f32 = 1 MiB per operand in VMEM — large
# enough that the whole MLP parameter vector is a single block and the CNN's
# 744k vector is three, small enough that a 4-operand kernel stays ≪ 16 MiB.
# (Perf note, EXPERIMENTS.md §Perf: interpret-lowered Pallas grids become
# XLA while-loops with per-step buffer copies; shrinking the grid from 91
# steps to ≤3 cut the CNN fused-update overhead by ~20×.)
MAX_BLOCK = 1 << 18

# All Pallas kernels in this project MUST run in interpret mode: real TPU
# lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.
INTERPRET = True


def block_geometry(n: int):
    """(padded_len, block) for an n-element vector: pad to whole tiles, one
    grid step per MAX_BLOCK elements."""
    tiles = max((n + TILE - 1) // TILE, 1)
    m0 = tiles * TILE
    block = min(m0, MAX_BLOCK)
    m = (m0 + block - 1) // block * block
    return m, block


def padded_len(n: int) -> int:
    """Smallest padded length for an n-element vector (see block_geometry)."""
    return block_geometry(n)[0]


def pad_to(v, m):
    """Pad a flat vector with zeros to length m."""
    n = v.shape[0]
    if m == n:
        return v
    return jnp.pad(v, (0, m - n))


def elementwise_call(kernel, out_dtype, *flat_inputs, scalars=()):
    """Run `kernel` over 1-D inputs tiled as (rows, LANES) blocks.

    flat_inputs: same-length 1-D arrays, padded here and un-padded after.
    scalars: () -shaped values broadcast to every block via a (1, 1) ref.
    kernel signature: kernel(*input_refs, *scalar_refs, out_ref).
    """
    n = flat_inputs[0].shape[0]
    for v in flat_inputs[1:]:
        assert v.shape == flat_inputs[0].shape, "elementwise inputs must match"
    m, block = block_geometry(n)
    rows_per_block = block // LANES
    grid = (m // block,)

    padded = [pad_to(v, m).reshape(m // LANES, LANES) for v in flat_inputs]
    scalar_arrays = [jnp.asarray(s, jnp.float32).reshape(1, 1) for s in scalars]

    in_specs = [
        pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0)) for _ in padded
    ] + [pl.BlockSpec((1, 1), lambda i: (0, 0)) for _ in scalar_arrays]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m // LANES, LANES), out_dtype),
        interpret=INTERPRET,
    )(*padded, *scalar_arrays)
    return out.reshape(m)[:n]


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


@functools.lru_cache(maxsize=None)
def _noop():  # pragma: no cover - placeholder keeping functools imported
    return None
