//! Pure-Rust [`LocalTrainer`]: the PJRT-free twin of the AOT artifacts.
//!
//! Generic over the composable layer API — any registry [`Model`] runs
//! here, including parameterized specs with no prebuilt artifacts. Used by
//! unit/property tests and fast CPU benches, and as the numeric
//! cross-check for the HLO programs (identical parameter layout and loss;
//! see `rust/tests/integration_fed.rs` and `runtime_artifacts.rs`). The
//! production path for the artifact-backed seed layouts is
//! `runtime::PjrtTrainer`.

use super::workspace::Workspace;
use super::{LocalTrainer, Model};
use crate::backend::kernels::MicroKernels;
use crate::data::loader::Batch;

/// The pure-Rust compute plane for any registry [`Model`].
///
/// Parameterized by a [`MicroKernels`] set: [`NativeTrainer::new`] routes
/// the model walks through the canonical scalar kernels (the `native`
/// backend), while [`NativeTrainer::with_kernels`] plugs in the wide or
/// bf16-storage sets for the `native-simd` / `native-bf16` backends.
#[derive(Debug, Clone)]
pub struct NativeTrainer {
    model: Model,
    kernels: &'static dyn MicroKernels,
}

impl NativeTrainer {
    /// A trainer computing over `model` with the canonical scalar kernels
    /// (stateless besides the descriptor).
    pub fn new(model: Model) -> Self {
        Self::with_kernels(model, &crate::backend::kernels::SCALAR)
    }

    /// A trainer routing every model walk through `kernels` — the hook the
    /// `native-simd` and `native-bf16` backends use.
    pub fn with_kernels(model: Model, kernels: &'static dyn MicroKernels) -> Self {
        Self { model, kernels }
    }

    /// Build straight from a registry spec string (`"mlp"`, `"linear:784"`, …).
    pub fn from_spec(spec: &str) -> Result<Self, String> {
        Ok(Self::new(super::build_model(spec)?))
    }

    /// The micro-kernel set this trainer walks the model with.
    pub fn kernels(&self) -> &'static dyn MicroKernels {
        self.kernels
    }
}

impl LocalTrainer for NativeTrainer {
    fn model(&self) -> &Model {
        &self.model
    }

    fn grad(&self, params: &[f32], batch: &Batch) -> (Vec<f32>, f32) {
        assert_eq!(params.len(), self.model.dim());
        assert_eq!(batch.feature_dim, self.model.input_dim());
        let mut ws = Workspace::for_model(&self.model, batch.y.len());
        let loss = self
            .model
            .grad_into_with(self.kernels, params, &batch.x, &batch.y, &mut ws);
        (std::mem::take(&mut ws.grad), loss)
    }

    fn grad_into(&self, params: &[f32], batch: &Batch, ws: &mut Workspace) -> f32 {
        assert_eq!(params.len(), self.model.dim());
        assert_eq!(batch.feature_dim, self.model.input_dim());
        self.model
            .grad_into_with(self.kernels, params, &batch.x, &batch.y, ws)
    }

    fn train_step_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        ws: &mut Workspace,
    ) -> f32 {
        // Same shape as the trait default, with the optimizer verb routed
        // through the backend kernel set (bit-identical across planes —
        // the step is elementwise — but vectorized on native-simd).
        let loss = self.grad_into(params, batch, ws);
        let (g, out) = ws.grad_and_step(params.len());
        self.kernels.apply_step(params, g, h, gamma, out);
        loss
    }

    fn train_step_masked_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
        ws: &mut Workspace,
    ) -> f32 {
        let d = params.len();
        let k = ((density * d as f64).ceil() as usize).clamp(1, d);
        // Mirrors the trait default (see `LocalTrainer::train_step_masked_into`
        // for the buffer choreography), with `apply_step` routed through the
        // backend kernels.
        let mut masked = std::mem::take(&mut ws.masked);
        if masked.len() < d {
            masked.resize(d, 0.0);
        }
        masked[..d].copy_from_slice(params);
        let mut keys = std::mem::take(&mut ws.topk_keys);
        let mut idx = std::mem::take(&mut ws.topk_idx);
        crate::compress::topk::apply_topk_with(&mut masked[..d], k, &mut keys, &mut idx);
        ws.topk_keys = keys;
        ws.topk_idx = idx;
        let loss = self.grad_into(&masked[..d], batch, ws);
        ws.masked = masked;
        let (g, out) = ws.grad_and_step(d);
        self.kernels.apply_step(params, g, h, gamma, out);
        loss
    }

    fn eval_batch(
        &self,
        params: &[f32],
        batch: &Batch,
        valid: usize,
        ws: &mut Workspace,
    ) -> (f64, usize) {
        self.model
            .eval_batch_into_with(self.kernels, params, &batch.x, &batch.y, valid, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::{eval_batches, ClientLoader};
    use crate::data::{synthetic, DatasetSpec};
    use crate::model::init_params;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn train_step_matches_manual_composition() {
        let mut rng = Rng::seed_from_u64(1);
        let tt = synthetic::generate(&DatasetSpec::mnist(), 64, 16, &mut rng);
        let data = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&data), (0..64).collect(), 8, Rng::seed_from_u64(2));
        let batch = loader.next_batch();
        let trainer = NativeTrainer::from_spec("mlp").unwrap();
        let params = init_params(trainer.model(), &mut rng);
        let h: Vec<f32> = (0..params.len()).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let gamma = 0.1;
        let (stepped, loss) = trainer.train_step(&params, &h, &batch, gamma);
        let (g, loss2) = trainer.grad(&params, &batch);
        assert_eq!(loss, loss2);
        for i in 0..params.len() {
            let expect = params[i] - gamma * (g[i] - h[i]);
            assert!((stepped[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn masked_step_uses_compressed_gradient_point() {
        let mut rng = Rng::seed_from_u64(3);
        let tt = synthetic::generate(&DatasetSpec::mnist(), 32, 8, &mut rng);
        let data = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&data), (0..32).collect(), 8, Rng::seed_from_u64(4));
        let batch = loader.next_batch();
        let trainer = NativeTrainer::from_spec("mlp").unwrap();
        let params = init_params(trainer.model(), &mut rng);
        let h = vec![0.0f32; params.len()];
        // density=1.0 must equal the unmasked step exactly.
        let (full, _) = trainer.train_step(&params, &h, &batch, 0.1);
        let (masked_full, _) = trainer.train_step_masked(&params, &h, &batch, 0.1, 1.0);
        assert_eq!(full, masked_full);
        // A tiny density must differ (gradient at a heavily masked model).
        let (masked_tiny, _) = trainer.train_step_masked(&params, &h, &batch, 0.1, 0.01);
        assert_ne!(full, masked_tiny);
    }

    #[test]
    fn federated_local_epochs_learn_on_synthetic_mnist() {
        // Single-client sanity: 300 local SGD steps should beat chance
        // accuracy clearly over 10 classes.
        let mut rng = Rng::seed_from_u64(5);
        let tt = synthetic::generate(&DatasetSpec::mnist(), 512, 256, &mut rng);
        let train = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&train), (0..512).collect(), 32, Rng::seed_from_u64(6));
        let trainer = NativeTrainer::from_spec("mlp").unwrap();
        let mut params = init_params(trainer.model(), &mut rng);
        let h = vec![0.0f32; params.len()];
        for _ in 0..300 {
            let batch = loader.next_batch();
            let (next, _) = trainer.train_step(&params, &h, &batch, 0.05);
            params = next;
        }
        let eb = eval_batches(&tt.test, 64);
        let result = trainer.eval(&params, &eb);
        assert!(
            result.accuracy > 0.6,
            "accuracy too low: {}",
            result.accuracy
        );
        assert_eq!(result.examples, 256);
    }

    #[test]
    fn softmax_regression_learns_on_flat_mixture() {
        // The convex workload end-to-end on the native plane: softmax
        // regression over the flat Gaussian mixture.
        let spec = DatasetSpec::parse("synthetic:64-c5").unwrap();
        let mut rng = Rng::seed_from_u64(7);
        let tt = synthetic::generate(&spec, 512, 256, &mut rng);
        let train = Arc::new(tt.train);
        let mut loader =
            ClientLoader::new(Arc::clone(&train), (0..512).collect(), 32, Rng::seed_from_u64(8));
        let trainer = NativeTrainer::from_spec("softmax:64x5").unwrap();
        let mut params = init_params(trainer.model(), &mut rng);
        let h = vec![0.0f32; params.len()];
        for _ in 0..200 {
            let batch = loader.next_batch();
            let (next, _) = trainer.train_step(&params, &h, &batch, 0.1);
            params = next;
        }
        let eb = eval_batches(&tt.test, 64);
        let result = trainer.eval(&params, &eb);
        assert!(
            result.accuracy > 0.7,
            "accuracy too low: {}",
            result.accuracy
        );
    }
}
