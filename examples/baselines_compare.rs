//! Baseline comparison scenario (paper §4.7, Figure 9): FedComLoc vs
//! FedAvg, sparseFedAvg, Scaffold and FedDyn under identical data, sampling
//! and bit accounting.
//!
//!     cargo run --release --example baselines_compare

use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
use fedcomloc::model::native::NativeTrainer;
use std::sync::Arc;

fn main() {
    let cfg = RunConfig {
        rounds: 40,
        train_n: 8_000,
        test_n: 1_500,
        eval_every: 5,
        ..RunConfig::default_mnist()
    };
    let trainer = Arc::new(NativeTrainer::from_spec("mlp").unwrap());

    let algo = |spec: &str| AlgorithmSpec::parse(spec).unwrap();
    let runs: Vec<(&str, AlgorithmSpec)> = vec![
        ("FedAvg", algo("fedavg")),
        ("sparseFedAvg 30%", algo("sparsefedavg:topk:0.3")),
        ("Scaffold", algo("scaffold")),
        ("FedDyn", algo("feddyn:0.01")),
        ("FedComLoc 30%", algo("fedcomloc-com:topk:0.3")),
    ];

    println!(
        "{:<18}{:>10}{:>14}{:>14}{:>14}",
        "method", "best_acc", "final_loss", "uplink_MB", "rounds→55%"
    );
    for (label, spec) in runs {
        let log = run(&cfg, trainer.clone(), &spec);
        let to_target = log
            .rounds_to_accuracy(0.55)
            .map(|(r, _)| r.to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{label:<18}{:>10.4}{:>14.4}{:>14.2}{:>14}",
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits() as f64 / 8e6,
            to_target,
        );
        let _ = log.save(std::path::Path::new("results/example_baselines"));
    }
}
