//! In-tree benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` targets are `harness = false` binaries that drive this
//! module: warmup, calibrated batching so each measurement batch is long
//! enough to swamp timer noise, repeated sampling, and a report with
//! mean ± std and quantiles. Results are also appended as JSON lines to
//! `target/benchkit/<bench>.jsonl` so perf regressions can be diffed across
//! runs (see EXPERIMENTS.md §Perf).

use crate::util::stats::{format_duration_ns, Summary};
use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

/// Harness configuration (tunable per bench binary or via env).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub samples: usize,
    /// Target wall time per measured sample (iterations are batched to hit
    /// this, so very fast functions still measure accurately).
    pub sample_target: Duration,
    /// Hard cap on total time per benchmark.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // FEDCOMLOC_BENCH_FAST=1 trims everything for CI smoke runs.
        let fast = std::env::var("FEDCOMLOC_BENCH_FAST").ok().as_deref() == Some("1");
        if fast {
            Self {
                warmup: Duration::from_millis(50),
                samples: 10,
                sample_target: Duration::from_millis(10),
                max_total: Duration::from_secs(5),
            }
        } else {
            Self {
                warmup: Duration::from_millis(300),
                samples: 30,
                sample_target: Duration::from_millis(30),
                max_total: Duration::from_secs(60),
            }
        }
    }
}

/// One benchmark group ≈ one paper table/figure or one hot path.
pub struct Bench {
    name: String,
    config: BenchConfig,
    results: Vec<(String, Summary, f64)>, // (case, per-iter summary ns, iters/sample)
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("\n== bench: {name} ==");
        Self {
            name: name.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Measure `f` under the case label. `f` should perform ONE logical
    /// iteration; batching is handled here.
    pub fn case<F: FnMut()>(&mut self, label: &str, mut f: F) {
        let cfg = &self.config;
        // Warmup + batch calibration.
        let mut iters_per_sample: u64 = 1;
        let warmup_start = Instant::now();
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let dt = t.elapsed();
            if dt >= cfg.sample_target {
                break;
            }
            if warmup_start.elapsed() > cfg.warmup && dt > Duration::ZERO {
                // Scale batch to hit the target sample duration.
                let scale = (cfg.sample_target.as_secs_f64() / dt.as_secs_f64()).ceil();
                iters_per_sample = (iters_per_sample as f64 * scale.max(2.0)) as u64;
                break;
            }
            iters_per_sample = iters_per_sample.saturating_mul(2);
        }

        // Measurement.
        let total_start = Instant::now();
        let mut per_iter_ns: Vec<f64> = Vec::with_capacity(cfg.samples);
        for _ in 0..cfg.samples {
            if total_start.elapsed() > cfg.max_total {
                break;
            }
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            per_iter_ns.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        if per_iter_ns.is_empty() {
            per_iter_ns.push(f64::NAN);
        }
        let summary = Summary::of(&per_iter_ns);
        println!(
            "  {label:<44} {:>12} ± {:>10}  (p95 {:>12}, n={} × {} iters)",
            format_duration_ns(summary.mean),
            format_duration_ns(summary.std),
            format_duration_ns(summary.p95),
            summary.count,
            iters_per_sample,
        );
        self.results
            .push((label.to_string(), summary, iters_per_sample as f64));
    }

    /// Measure a function returning a value (kept alive via black_box).
    pub fn case_with_output<R, F: FnMut() -> R>(&mut self, label: &str, mut f: F) {
        self.case(label, || {
            black_box(f());
        });
    }

    /// Record an externally-measured scalar series (used by experiment
    /// benches that report accuracy/bits rather than wall time).
    pub fn record_metric(&mut self, label: &str, value: f64, unit: &str) {
        println!("  {label:<44} {value:>14.6} {unit}");
    }

    /// Write the JSONL report. Called on drop as well.
    pub fn finish(&mut self) {
        if self.results.is_empty() {
            return;
        }
        let dir = std::path::Path::new("target/benchkit");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.jsonl", self.name));
        let mut lines = String::new();
        let stamp = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        for (label, s, iters) in &self.results {
            use crate::util::json::Json;
            let mut o = Json::obj();
            o.set("bench", self.name.as_str().into());
            o.set("case", label.as_str().into());
            o.set("mean_ns", s.mean.into());
            o.set("std_ns", s.std.into());
            o.set("p95_ns", s.p95.into());
            o.set("iters_per_sample", (*iters).into());
            o.set("unix_time", (stamp as f64).into());
            lines.push_str(&o.to_string_compact());
            lines.push('\n');
        }
        use std::io::Write;
        if let Ok(mut fh) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            let _ = fh.write_all(lines.as_bytes());
        }
        self.results.clear();
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(1),
            samples: 3,
            sample_target: Duration::from_micros(200),
            max_total: Duration::from_millis(500),
        }
    }

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new("benchkit_selftest").with_config(tiny_config());
        b.case("noop-ish", || {
            black_box(1 + 1);
        });
        b.case_with_output("sum", || (0..100u64).sum::<u64>());
        b.finish();
        assert!(std::path::Path::new("target/benchkit/benchkit_selftest.jsonl").exists());
    }

    #[test]
    fn timing_orders_are_sane() {
        // A function that sleeps must measure slower than a no-op.
        let mut b = Bench::new("benchkit_order").with_config(tiny_config());
        let mut slow_mean = 0.0;
        let mut fast_mean = 0.0;
        {
            let t = Instant::now();
            std::hint::black_box(&t);
        }
        // Use case() output indirectly: measure manually with same batching.
        let t0 = Instant::now();
        for _ in 0..10 {
            black_box(0u64);
        }
        fast_mean += t0.elapsed().as_nanos() as f64;
        let t1 = Instant::now();
        for _ in 0..10 {
            std::thread::sleep(Duration::from_micros(50));
        }
        slow_mean += t1.elapsed().as_nanos() as f64;
        assert!(slow_mean > fast_mean);
        b.finish();
    }
}
