//! Infrastructure substrates built in-tree (the offline vendor set ships no
//! rand/serde/tokio/clap/criterion/proptest): PRNG and distributions,
//! bit-exact wire I/O, JSON/TOML, summary statistics, a worker pool, a
//! bench harness, and a property-testing mini-framework.

pub mod benchkit;
pub mod bitio;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod toml;
