//! Property-based invariant tests (in-tree quickcheck substrate).
//!
//! Coordinator- and compressor-level invariants the paper's correctness
//! rests on, checked over randomized inputs with shrink-on-failure.

use fedcomloc::compress::{parse_spec, topk, Compressor, Identity, Natural, QuantizeR, RandK, TopK};
use fedcomloc::fed::message::Message;
use fedcomloc::tensor;
use fedcomloc::util::bitio::{BitReader, BitWriter};
use fedcomloc::util::quickcheck::{check, Gen};
use fedcomloc::util::rng::Rng;

fn any_vec(g: &mut Gen) -> Vec<f32> {
    g.vec_f32(1..=2048, -10.0, 10.0)
}

/// One randomly-parameterized compressor per codec family, including the
/// fused and generic chain compositions.
fn any_compressors(g: &mut Gen) -> Vec<Box<dyn Compressor>> {
    let density = *g.choose(&[0.01, 0.1, 0.3, 0.5, 0.9, 1.0]);
    let bits = *g.choose(&[1u32, 2, 4, 7, 8, 12, 16]);
    let bucket = *g.choose(&[32usize, 100, 512, 1024]);
    vec![
        Box::new(Identity),
        Box::new(TopK::with_density(density)),
        Box::new(RandK::with_density(density)),
        Box::new(QuantizeR::with_bucket(bits, bucket)),
        Box::new(Natural),
        parse_spec(&format!("topk:{density}|q{bits}")).unwrap(),
        parse_spec(&format!("randk:{density}|q{bits}")).unwrap(),
        parse_spec(&format!("q{bits}|topk:{density}")).unwrap(),
        parse_spec(&format!("natural|topk:{density}")).unwrap(),
    ]
}

#[test]
fn prop_topk_roundtrip_is_apply() {
    check("topk wire == apply", 150, |g| {
        let x = any_vec(g);
        let density = *g.choose(&[0.01, 0.1, 0.3, 0.5, 0.9, 1.0]);
        let c = TopK::with_density(density);
        let mut rng = Rng::seed_from_u64(1);
        let wire = c.decompress(&c.compress(&x, &mut rng));
        let mut applied = x.clone();
        c.apply(&mut applied, &mut rng);
        if wire == applied {
            Ok(())
        } else {
            Err(format!("mismatch d={} density={density}", x.len()))
        }
    });
}

#[test]
fn prop_topk_is_l2_projection() {
    // TopK(x) minimizes ||y − x|| over ||y||₀ ≤ K (Definition 3.1): any
    // other support of size K has ≥ error.
    check("topk optimality", 100, |g| {
        let x = any_vec(g);
        let d = x.len();
        let k = 1 + g.usize_in(0..=(d - 1).min(64));
        let c = TopK::with_k(k);
        let mut rng = Rng::seed_from_u64(2);
        let y = c.decompress(&c.compress(&x, &mut rng));
        let err_topk = tensor::l2_distance(&x, &y) as f64;
        // Random alternative support of the same size.
        let mut alt = vec![0.0f32; d];
        let idx = rng.sample_without_replacement(d, k.min(d));
        for i in idx {
            alt[i] = x[i];
        }
        let err_alt = tensor::l2_distance(&x, &alt) as f64;
        if err_topk <= err_alt + 1e-4 {
            Ok(())
        } else {
            Err(format!("topk err {err_topk} > alt err {err_alt} (d={d}, k={k})"))
        }
    });
}

#[test]
fn prop_quantizer_error_bounded() {
    // Per-bucket: |Q(x)_i − x_i| ≤ bucket_norm / 2^r.
    check("quantizer grid bound", 120, |g| {
        let x = any_vec(g);
        let bits = *g.choose(&[1u32, 2, 4, 8, 12]);
        let bucket = *g.choose(&[64usize, 256, 1024]);
        let q = QuantizeR::with_bucket(bits, bucket);
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        let y = q.decompress(&q.compress(&x, &mut rng));
        for (chunk_i, chunk) in x.chunks(bucket).enumerate() {
            let norm = tensor::norm2(chunk);
            let bound = norm / (1u64 << bits) as f32 + 1e-5 + norm * 1e-6;
            for (j, (&xi, &yi)) in chunk
                .iter()
                .zip(&y[chunk_i * bucket..chunk_i * bucket + chunk.len()])
                .enumerate()
            {
                if (xi - yi).abs() > bound {
                    return Err(format!(
                        "bucket {chunk_i} coord {j}: |{xi} - {yi}| > {bound} (r={bits})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_bits_never_exceed_payload() {
    check("wire_bits <= 8*payload < wire_bits+8", 150, |g| {
        let x = any_vec(g);
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(TopK::with_density(0.2)),
            Box::new(RandK::with_density(0.2)),
            Box::new(QuantizeR::new(6)),
            Box::new(Natural),
            parse_spec("topk:0.3|q5").unwrap(),
        ];
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        for c in comps {
            let enc = c.compress(&x, &mut rng);
            let payload_bits = enc.payload.len() as u64 * 8;
            if enc.wire_bits > payload_bits || payload_bits >= enc.wire_bits + 8 {
                return Err(format!(
                    "{}: wire {} payload {payload_bits}",
                    c.name(),
                    enc.wire_bits
                ));
            }
            // Decode must give the declared dimension.
            if c.decompress(&enc).len() != x.len() {
                return Err(format!("{}: bad dim", c.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_message_frame_roundtrips_byte_exactly() {
    // Message::encode → decode must be lossless for every codec under
    // random dims/densities/bit-widths: header fields, payload bytes, and
    // the decoded dense vector all survive framing.
    check("message frame roundtrip", 120, |g| {
        let x = any_vec(g);
        let round = g.usize_in(0..=10_000);
        let sender = g.usize_in(0..=1_000) as u32;
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        for c in any_compressors(g) {
            let enc = c.compress(&x, &mut rng);
            let reference = c.decompress(&enc);
            let msg = Message::from_compressed(round, sender, enc);
            let back = match Message::decode(&msg.encode()) {
                Ok(m) => m,
                Err(e) => return Err(format!("{}: decode failed: {e}", c.name())),
            };
            if back != msg {
                return Err(format!("{}: frame not byte-exact", c.name()));
            }
            // Decoding from the wire header alone must agree with the
            // sender's compressor instance.
            if back.to_dense() != reference {
                return Err(format!("{}: codec-driven decode mismatch", c.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_message_wire_bits_bounded_by_payload() {
    // wire_bits ≤ 8·payload.len() always holds, and the payload never pads
    // by a full byte or more.
    check("message wire_bits bounds", 120, |g| {
        let x = any_vec(g);
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        for c in any_compressors(g) {
            let msg = Message::from_compressed(0, 0, c.compress(&x, &mut rng));
            let payload_bits = 8 * msg.payload.len() as u64;
            if msg.wire_bits() > payload_bits {
                return Err(format!(
                    "{}: wire_bits {} > payload bits {payload_bits}",
                    c.name(),
                    msg.wire_bits()
                ));
            }
            if payload_bits >= msg.wire_bits() + 8 {
                return Err(format!("{}: over-padded payload", c.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitio_roundtrip() {
    check("bitio roundtrip arbitrary widths", 200, |g| {
        let n = g.usize_in(1..=300);
        let fields: Vec<(u64, u32)> = (0..n)
            .map(|_| {
                let w = 1 + g.rng().below(64) as u32;
                let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
                (g.rng().next_u64() & mask, w)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, width) in &fields {
            w.write_bits(v, width);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, width) in &fields {
            if r.read_bits(width) != v {
                return Err(format!("field width {width}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_select_topk_sorted_and_within_range() {
    check("select_topk_indices well-formed", 200, |g| {
        let x = any_vec(g);
        let k = g.usize_in(0..=x.len());
        let idx = topk::select_topk_indices(&x, k);
        if idx.len() != k.min(x.len()) {
            return Err(format!("len {} != k {}", idx.len(), k));
        }
        if !idx.windows(2).all(|w| w[0] < w[1]) {
            return Err("indices not strictly ascending".into());
        }
        if idx.iter().any(|&i| i >= x.len()) {
            return Err("index out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mean_into_is_affine() {
    // mean(a+c, b+c) == mean(a,b) + c — aggregation must be exact averaging.
    check("server mean affine", 100, |g| {
        let a = any_vec(g);
        let b: Vec<f32> = a.iter().map(|v| v * 0.5 + 1.0).collect();
        let shift = g.f32_in(-2.0, 2.0);
        let a2: Vec<f32> = a.iter().map(|v| v + shift).collect();
        let b2: Vec<f32> = b.iter().map(|v| v + shift).collect();
        let mut m1 = vec![0.0f32; a.len()];
        tensor::mean_into(&[&a, &b], &mut m1);
        let mut m2 = vec![0.0f32; a.len()];
        tensor::mean_into(&[&a2, &b2], &mut m2);
        for i in 0..a.len() {
            if (m2[i] - (m1[i] + shift)).abs() > 1e-4 {
                return Err(format!("coord {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scaffnew_step_linear_in_h() {
    // x̂(h1) − x̂(h2) == γ(h1 − h2): the control variate enters linearly.
    check("local step linear in h", 100, |g| {
        let x = any_vec(g);
        let d = x.len();
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        let gvec: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h1: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h2: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let gamma = 0.3f32;
        let mut s1 = vec![0.0f32; d];
        let mut s2 = vec![0.0f32; d];
        tensor::sgd_control_variate_step(&x, &gvec, &h1, gamma, &mut s1);
        tensor::sgd_control_variate_step(&x, &gvec, &h2, gamma, &mut s2);
        for i in 0..d {
            let want = gamma * (h1[i] - h2[i]);
            if ((s1[i] - s2[i]) - want).abs() > 1e-4 {
                return Err(format!("coord {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dirichlet_partition_total_and_disjoint() {
    use fedcomloc::data::dirichlet::partition;
    use fedcomloc::data::{synthetic, DatasetSpec};
    check("partition covers exactly once", 12, |g| {
        let n = 300 + g.usize_in(0..=500);
        let clients = 2 + g.usize_in(0..=30);
        let alpha = *g.choose(&[0.1, 0.5, 1.0, 10.0]);
        let mut rng = Rng::seed_from_u64(g.rng().next_u64());
        let data = synthetic::generate(&DatasetSpec::mnist(), n, 10, &mut rng).train;
        let p = partition(&data, clients, alpha, 1, &mut rng);
        let mut seen = vec![0u8; n];
        for shard in &p.client_indices {
            for &i in shard {
                seen[i] += 1;
            }
        }
        if seen.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err(format!("n={n} clients={clients} alpha={alpha}"))
        }
    });
}
