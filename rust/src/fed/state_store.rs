//! The paged client-state store behind the million-client federation
//! engine: per-client state ([`ClientState`] — loader cursor, control
//! variate h_i, RNG stream, uplink [`crate::compress::Pipeline`]) is
//! materialized *on first touch*, so memory is O(clients sampled so far)
//! instead of O(n_clients).
//!
//! Untouched clients are implicit: their control variate is zero, their
//! loader has never drawn a batch, their RNG streams are untapped, and
//! their EF residuals are empty — exactly the state the eager
//! `Vec<Mutex<ClientState>>` held for a never-sampled client, because
//! every per-client stream is *derived* (pure, order-independent) from the
//! federation's post-partition root generator via [`Rng::derive`]. A
//! client materialized lazily at round 40 is therefore bit-identical to
//! one materialized eagerly at construction, and all existing identity
//! pins hold.
//!
//! The store indexes like the `Vec` it replaces (`store[ci].lock()`), but
//! only resident ids resolve — indexing a never-materialized client is a
//! logic error (the drive loop materializes each round's cohort before any
//! worker touches it) and panics with a clear message.

use super::ClientState;
use crate::compress::CompressorSpec;
use crate::data::dirichlet::SparsePartition;
use crate::data::loader::ClientLoader;
use crate::data::Dataset;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Everything needed to materialize any client's initial state on demand.
/// `root` is a clone of the federation RNG *after* partitioning (the state
/// every eager per-client derive used), so lazily derived streams match the
/// eager construction bit for bit.
pub struct StateTemplate {
    /// Post-partition root generator all per-client streams derive from.
    pub root: Rng,
    /// Model parameter count (h_i length).
    pub dim: usize,
    /// Local-step minibatch size.
    pub batch_size: usize,
    /// Total communication rounds (compression schedules need it).
    pub rounds: usize,
    /// The per-client uplink pipeline spec.
    pub up_spec: CompressorSpec,
    /// The shared training data the loaders index into.
    pub train: Arc<Dataset>,
}

/// Paged client-state store: resident [`ClientState`]s keyed by client id,
/// plus the [`StateTemplate`] that materializes absent ones on demand.
pub struct ClientStore {
    n_clients: usize,
    resident: HashMap<usize, Mutex<ClientState>>,
    template: StateTemplate,
}

/// Derivation salt for client `i`'s loader shuffle stream (matches the
/// eager construction in every prior release).
const LOADER_SALT: u64 = 0xC11E27;
/// Derivation salt for client `i`'s compression/stochasticity stream.
const CLIENT_SALT: u64 = 0xC0_FFEE;

impl ClientStore {
    /// An empty store over a population of `n_clients`, materializing from
    /// `template`.
    pub fn new(n_clients: usize, template: StateTemplate) -> ClientStore {
        ClientStore {
            n_clients,
            resident: HashMap::new(),
            template,
        }
    }

    /// Population size (total federated clients, resident or not).
    pub fn len(&self) -> usize {
        self.n_clients
    }

    /// True when the population is empty (never for a valid run config).
    pub fn is_empty(&self) -> bool {
        self.n_clients == 0
    }

    /// Number of clients whose state is actually materialized — bounded by
    /// the number of distinct clients sampled so far, i.e. at most
    /// `rounds × clients_per_round`.
    pub fn resident_clients(&self) -> usize {
        self.resident.len()
    }

    /// True when client `id`'s state is materialized.
    pub fn is_resident(&self, id: usize) -> bool {
        self.resident.contains_key(&id)
    }

    /// Materialize client `id` from the template (no-op when already
    /// resident). The derived streams are pure functions of the template
    /// root and the id, so materialization order never matters.
    pub fn materialize(&mut self, id: usize, partition: &SparsePartition) {
        assert!(id < self.n_clients, "client {id} out of range");
        if self.resident.contains_key(&id) {
            return;
        }
        let t = &self.template;
        let state = ClientState {
            loader: ClientLoader::new(
                Arc::clone(&t.train),
                partition.shard(id).to_vec(),
                t.batch_size,
                t.root.derive(LOADER_SALT + id as u64),
            ),
            h: vec![0.0f32; t.dim],
            rng: t.root.derive(CLIENT_SALT + id as u64),
            up: t.up_spec.build(t.rounds),
        };
        self.resident.insert(id, Mutex::new(state));
    }

    /// Materialize a whole cohort (the per-round entry point).
    pub fn materialize_all(&mut self, ids: &[usize], partition: &SparsePartition) {
        for &id in ids {
            self.materialize(id, partition);
        }
    }

    /// The resident client's state, or `None` when never materialized.
    pub fn get(&self, id: usize) -> Option<&Mutex<ClientState>> {
        self.resident.get(&id)
    }

    /// Resident client ids in ascending order — the canonical iteration
    /// order for checkpoints and control-variate sums, independent of hash
    /// iteration order.
    pub fn resident_ids_sorted(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.resident.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Replace the uplink pipeline spec (the legacy algorithm-spec shim):
    /// updates the template for future materializations and rebuilds every
    /// resident client's pipeline.
    pub fn set_uplink_spec(&mut self, spec: CompressorSpec, rounds: usize) {
        for state in self.resident.values() {
            state.lock().unwrap().up = spec.build(rounds);
        }
        self.template.up_spec = spec;
        self.template.rounds = rounds;
    }
}

impl std::ops::Index<usize> for ClientStore {
    type Output = Mutex<ClientState>;

    fn index(&self, id: usize) -> &Mutex<ClientState> {
        self.resident.get(&id).unwrap_or_else(|| {
            panic!(
                "client {id} not resident (population {}, {} resident) — cohorts must be \
                 materialized via sample_clients/materialize before use",
                self.n_clients,
                self.resident.len()
            )
        })
    }
}
