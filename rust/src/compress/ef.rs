//! Error-feedback compressor state (EF14 / SoteriaFL-style shifted
//! compression): the memory a stateful `ef(...)` pipeline keeps per link.
//!
//! EF turns any (possibly biased) compressor C into a contractive update:
//! each round the link transmits C(x + e), where e is everything previous
//! rounds failed to deliver, then keeps the fresh residual
//!
//! ```text
//! m_t = x_t + e_{t-1};   wire_t = C(m_t);   e_t = m_t − decode(wire_t)
//! ```
//!
//! so dropped coordinates are retried until they land instead of being
//! lost forever. The state is **per link** (one instance per client
//! uplink; the server broadcast keeps its own) and deterministic: its
//! trajectory depends only on the inputs and the link's RNG stream, never
//! on worker scheduling — the sweep engine's threads-invariance pin covers
//! an `ef(...)` run (`tests/compress_pipeline.rs`).
//!
//! For a pure support sparsifier (TopK/RandK) the residual identity is
//! exact in floating point: on the kept support `decode(wire) = m`, so
//! `e = m − decode(wire)` is zero there and equals `m` off-support —
//! `decode(wire) + e == m` bitwise (pinned in the tests below).

use super::{decode_payload_into, CodecMeta};

/// Per-link error-feedback memory: the residual plus the scratch the
/// encode step needs. Buffers grow once to the link's dimension and are
/// reused for the lifetime of the run.
#[derive(Debug, Default)]
pub struct ErrorFeedback {
    /// The residual e: mass previous compressions failed to deliver.
    err: Vec<f32>,
    /// Scratch for the shifted input m = x + e (what the inner codec sees).
    carry: Vec<f32>,
    /// Scratch for decoding the freshly-encoded payload.
    dec: Vec<f32>,
}

impl ErrorFeedback {
    /// A fresh state with zero residual (dimension fixed by the first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Build m = x + e into the carry buffer and return it for encoding.
    /// The first call (and a dimension change, which cannot happen within
    /// a run) starts from a zero residual.
    pub fn shift<'a>(&'a mut self, x: &[f32]) -> &'a [f32] {
        let d = x.len();
        if self.err.len() != d {
            self.err.clear();
            self.err.resize(d, 0.0);
        }
        self.carry.resize(d, 0.0);
        for ((c, &xi), &e) in self.carry.iter_mut().zip(x).zip(&self.err) {
            *c = xi + e;
        }
        &self.carry
    }

    /// Fold the encoded payload back into the residual:
    /// e ← m − decode(payload). Must be called with the bytes produced by
    /// encoding the slice [`ErrorFeedback::shift`] returned.
    pub fn absorb(&mut self, meta: &CodecMeta, payload: &[u8]) {
        debug_assert_eq!(meta.dim, self.carry.len());
        self.dec.resize(meta.dim, 0.0);
        decode_payload_into(meta.codec, meta.dim, payload, &mut self.dec);
        for ((e, &m), &y) in self.err.iter_mut().zip(&self.carry).zip(&self.dec) {
            *e = m - y;
        }
    }

    /// The current residual (diagnostics/tests).
    pub fn residual(&self) -> &[f32] {
        &self.err
    }

    /// Overwrite the residual with a checkpointed value (see
    /// [`crate::ckpt`]). The restored dimension must match the link's first
    /// post-restore [`ErrorFeedback::shift`] input, otherwise `shift` would
    /// discard it as a dimension change.
    pub fn restore_residual(&mut self, err: Vec<f32>) {
        self.err = err;
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Compressor, TopK};
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn residual_identity_is_exact_for_support_sparsifiers() {
        let mut rng = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..300).map(|i| ((i as f32) * 0.7).sin()).collect();
        let mut ef = ErrorFeedback::new();
        let comp = TopK::with_density(0.1);
        let mut payload = Vec::new();
        for _round in 0..4 {
            let m: Vec<f32> = ef.shift(&x).to_vec();
            let meta = comp.compress_into(ef.shift(&x), &mut rng, &mut payload);
            ef.absorb(&meta, &payload);
            // decode + residual == m, bitwise, for a pure support selector.
            let mut dec = vec![0.0f32; x.len()];
            decode_payload_into(meta.codec, meta.dim, &payload, &mut dec);
            for i in 0..x.len() {
                let sum = dec[i] + ef.residual()[i];
                assert_eq!(sum.to_bits(), m[i].to_bits(), "i={i}");
            }
        }
    }

    #[test]
    fn residual_accumulates_undelivered_mass() {
        let mut rng = Rng::seed_from_u64(4);
        // Constant small coordinates + one large: TopK(k=1) keeps only the
        // large one, so small coordinates pile up in the residual until
        // they outgrow it and get flushed.
        let mut x = vec![0.1f32; 10];
        x[0] = 5.0;
        let mut ef = ErrorFeedback::new();
        let comp = TopK::with_k(1);
        let mut payload = Vec::new();
        let meta = comp.compress_into(ef.shift(&x), &mut rng, &mut payload);
        ef.absorb(&meta, &payload);
        assert_eq!(ef.residual()[0], 0.0, "delivered coordinate has no residual");
        assert!(ef.residual()[1..].iter().all(|&e| e == 0.1));
        // Second round: residual shifts the input, small coords now 0.2.
        let m2 = ef.shift(&x).to_vec();
        assert_eq!(m2[1], 0.2);
        assert_eq!(m2[0], 5.0);
    }
}
