//! The declarative sweep engine: the paper's entire empirical section as
//! data, not code.
//!
//! A sweep is a TOML file ([`SweepSpec`]) that lists values over the four
//! string-keyed registries (`--algo`, `--model`, `--dataset`, and the
//! `compress_up`/`compress_down` pipeline specs), the transport, and
//! scalar grids (rounds, local iterations, Dirichlet α, stepsize,
//! communication probability, seeds). The engine expands the
//! cross-product into validated [`RunUnit`]s ([`spec`]), executes them in
//! parallel on the shared worker pool — one run per worker, each run
//! seeding its own RNG streams so results are order-independent and
//! bit-reproducible ([`runner`]) — and streams results to a
//! schema-versioned sink: one JSONL file of per-round records per run plus
//! one summary CSV row per run ([`sink`]).
//!
//! ```text
//! experiments/<name>.toml ──► SweepSpec::expand ──► [RunUnit; N]
//!                                                       │  ThreadPool (one run/worker)
//!                                                       ▼
//!                              results/<name>/rounds/<run_id>.jsonl   (per round)
//!                              results/<name>/summary.csv             (per run)
//! ```
//!
//! The eight hand-written experiment modules of the original reproduction
//! are retired: every paper figure/table is now a shipped TOML under
//! `experiments/` ([`presets`]), runnable as
//! `fedcomloc sweep run --preset <name>` and mapped figure-by-figure in
//! EXPERIMENTS.md. Adding a scenario is editing a TOML — no Rust involved.

pub mod presets;
pub mod runner;
pub mod sink;
pub mod spec;

pub use presets::{preset_by_name, sweep_presets, SweepPreset};
pub use runner::{format_matrix, run_sweep, SweepOptions, SweepOutcome};
pub use spec::{GridBlock, RunUnit, SweepSpec, SCHEMA_VERSION};
