//! FedCIFAR10 CNN scenario (paper §4.3) on the AOT/PJRT compute plane.
//!
//!     make artifacts && cargo run --release --example fedcifar_cnn -- --rounds 30
//!
//! Trains the 744k-parameter FedLab CNN with FedComLoc-Com at two densities
//! and reports the Figure 3 reading: sparsified models converge faster per
//! communicated bit.

use fedcomloc::fed::{run, AlgorithmSpec, RunConfig};
use fedcomloc::model::{build_model, native::NativeTrainer, LocalTrainer};
use fedcomloc::runtime::{artifacts_available, default_artifacts_dir, PjrtTrainer};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rounds = args
        .iter()
        .position(|a| a == "--rounds")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);

    let dir = default_artifacts_dir();
    let model = build_model("cnn").unwrap();
    let trainer: Arc<dyn LocalTrainer> = if artifacts_available(&dir) {
        println!("compute plane: PJRT/XLA (artifacts: {})", dir.display());
        Arc::new(PjrtTrainer::load(&dir, &model).expect("artifacts load"))
    } else {
        println!("compute plane: native Rust (naive conv — run `make artifacts` for XLA)");
        Arc::new(NativeTrainer::new(model))
    };

    println!("{:<22}{:>10}{:>14}{:>16}", "config", "best_acc", "final_loss", "uplink_MB");
    for (label, density) in [("dense (K=100%)", 1.0f64), ("sparse (K=30%)", 0.3), ("sparse (K=10%)", 0.1)] {
        let cfg = RunConfig {
            rounds,
            ..RunConfig::default_cifar()
        };
        let spec = if density >= 1.0 {
            AlgorithmSpec::parse("fedcomloc-com:none").unwrap()
        } else {
            AlgorithmSpec::parse(&format!("fedcomloc-com:topk:{density}")).unwrap()
        };
        let log = run(&cfg, trainer.clone(), &spec);
        println!(
            "{label:<22}{:>10.4}{:>14.4}{:>16.2}",
            log.best_accuracy().unwrap_or(0.0),
            log.final_train_loss().unwrap_or(f64::NAN),
            log.total_uplink_bits() as f64 / 8e6,
        );
        let _ = log.save(std::path::Path::new("results/example_cifar"));
    }
}
