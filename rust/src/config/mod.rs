//! Configuration system: typed [`RunConfig`] construction from presets,
//! TOML files, and CLI overrides (highest precedence last).
//!
//! ```toml
//! # experiment.toml
//! [run]
//! dataset = "fedmnist"
//! rounds = 500
//! clients = 100
//! sampled = 10
//! alpha = 0.7
//! p = 0.1
//! gamma = 0.05
//! ```

pub mod presets;

use crate::compress::CompressorSpec;
use crate::data::DatasetSpec;
use crate::fed::RunConfig;
use crate::model::ModelSpec;
use crate::util::toml::{self, TomlValue};
use std::path::Path;

/// Failure loading or applying a configuration source.
#[derive(Debug)]
pub enum ConfigError {
    /// The config file could not be read.
    Io(std::path::PathBuf, std::io::Error),
    /// The config file is not valid TOML.
    Toml(toml::TomlError),
    /// A key exists but its value was rejected.
    Invalid {
        /// The offending key (or CLI flag).
        key: String,
        /// Why the value was rejected.
        reason: String,
    },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(path, err) => write!(f, "cannot read {}: {err}", path.display()),
            ConfigError::Toml(err) => err.fmt(f),
            ConfigError::Invalid { key, reason } => write!(f, "config key '{key}': {reason}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<toml::TomlError> for ConfigError {
    fn from(e: toml::TomlError) -> ConfigError {
        ConfigError::Toml(e)
    }
}

/// Apply `[run]` table keys from a TOML document onto a RunConfig.
pub fn apply_toml(cfg: &mut RunConfig, doc: &toml::TomlDoc) -> Result<(), ConfigError> {
    let table = match doc.tables.get("run") {
        Some(t) => t,
        None => return Ok(()),
    };
    for (key, value) in table {
        apply_kv(cfg, key, value).map_err(|reason| ConfigError::Invalid {
            key: key.clone(),
            reason,
        })?;
    }
    Ok(())
}

/// Load a TOML file and apply its `[run]` table onto `cfg`.
pub fn load_file(cfg: &mut RunConfig, path: &Path) -> Result<(), ConfigError> {
    let text =
        std::fs::read_to_string(path).map_err(|e| ConfigError::Io(path.to_path_buf(), e))?;
    let doc = toml::parse(&text)?;
    apply_toml(cfg, &doc)
}

/// Apply one `[run]`-table key onto a [`RunConfig`]. This is the single
/// schema point for run-level settings: the TOML loader, the CLI override
/// layer, and the sweep engine's fixed/axis values all dispatch here, so a
/// key accepted in one place is accepted everywhere.
pub fn apply_kv(cfg: &mut RunConfig, key: &str, value: &TomlValue) -> Result<(), String> {
    let as_usize = || value.as_usize().ok_or_else(|| "expected integer".to_string());
    let as_f64 = || value.as_f64().ok_or_else(|| "expected number".to_string());
    match key {
        "dataset" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.dataset = DatasetSpec::parse(s)?;
        }
        "model" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.model = Some(ModelSpec::parse(s)?);
        }
        "train_n" => cfg.train_n = as_usize()?,
        "test_n" => cfg.test_n = as_usize()?,
        "clients" | "n_clients" => cfg.n_clients = as_usize()?,
        "sampled" | "clients_per_round" => cfg.clients_per_round = as_usize()?,
        "alpha" | "dirichlet_alpha" => cfg.dirichlet_alpha = as_f64()?,
        "rounds" => cfg.rounds = as_usize()?,
        "p" => cfg.p = as_f64()?,
        "local_steps" => cfg.local_steps = as_usize()?,
        "gamma" | "lr" => cfg.gamma = as_f64()? as f32,
        "batch_size" => cfg.batch_size = as_usize()?,
        "eval_batch" => cfg.eval_batch = as_usize()?,
        "eval_every" => cfg.eval_every = as_usize()?,
        "seed" => cfg.seed = as_usize()? as u64,
        "tau" => cfg.tau = as_f64()?,
        "threads" => cfg.threads = as_usize()?,
        "data_dir" => {
            cfg.data_dir = value.as_str().ok_or("expected string")?.into();
        }
        "compress_up" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.compress_up = CompressorSpec::parse(s)?.key().to_string();
        }
        "compress_down" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.compress_down = CompressorSpec::parse(s)?.key().to_string();
        }
        "scenario" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.scenario = crate::fed::sim::Scenario::parse(s)?.key();
        }
        "faults" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.faults = crate::fed::faults::FaultSpec::parse(s)?.key();
        }
        "backend" | "trainer" => {
            let s = value.as_str().ok_or("expected string")?;
            cfg.backend = crate::backend::canonical_backend_key(s)?;
        }
        other => return Err(format!("unknown key '{other}'")),
    }
    Ok(())
}

/// Apply one `[run]`-table key given as a raw string (the checkpoint kv
/// section's format): the value is typed per-key exactly like a CLI flag
/// and routed through [`apply_kv`], so a key accepted here is accepted in
/// TOML and on the command line and vice versa.
pub fn apply_kv_str(cfg: &mut RunConfig, key: &str, raw: &str) -> Result<(), String> {
    let value = parse_flag_value(key, raw)?;
    apply_kv(cfg, key, &value)
}

/// Export a [`RunConfig`] as canonical `(key, value)` string pairs — the
/// inverse of [`apply_kv_str`] for every result-affecting setting. Used by
/// the checkpoint format to embed (and on resume, validate) the exact run
/// configuration. `threads` is deliberately excluded: it is host-local
/// parallelism and must not block resuming on a different machine; the
/// `model` key is emitted only when explicitly set, mirroring the
/// dataset-default fallback of [`RunConfig::model_spec`].
pub fn to_kv(cfg: &RunConfig) -> Vec<(String, String)> {
    let mut kv: Vec<(String, String)> = Vec::new();
    let mut put = |k: &str, v: String| kv.push((k.to_string(), v));
    put("dataset", cfg.dataset.key().to_string());
    if let Some(model) = &cfg.model {
        put("model", model.key().to_string());
    }
    put("train_n", cfg.train_n.to_string());
    put("test_n", cfg.test_n.to_string());
    put("clients", cfg.n_clients.to_string());
    put("sampled", cfg.clients_per_round.to_string());
    put("alpha", cfg.dirichlet_alpha.to_string());
    put("rounds", cfg.rounds.to_string());
    put("p", cfg.p.to_string());
    put("local_steps", cfg.local_steps.to_string());
    put("gamma", cfg.gamma.to_string());
    put("batch_size", cfg.batch_size.to_string());
    put("eval_batch", cfg.eval_batch.to_string());
    put("eval_every", cfg.eval_every.to_string());
    put("seed", cfg.seed.to_string());
    put("tau", cfg.tau.to_string());
    put("data_dir", cfg.data_dir.to_string_lossy().into_owned());
    put("compress_up", cfg.compress_up.clone());
    put("compress_down", cfg.compress_down.clone());
    put("scenario", cfg.scenario.clone());
    put("faults", cfg.faults.clone());
    // `auto` (the default) is elided so checkpoints written before the
    // backend key existed keep byte-identical kv sections — and resume
    // under whatever `--backend` the resuming invocation picks, exactly
    // like `threads`. An explicit key is result-affecting for
    // `native-bf16`/`xla` and pinned for reproducibility on all planes.
    if cfg.backend != "auto" {
        put("backend", cfg.backend.clone());
    }
    kv
}

/// Apply the `--scale` factor shared by `fedcomloc experiment` and
/// `fedcomloc sweep run`: multiply rounds and dataset sizes toward the
/// paper's full configuration, with floors keeping tiny factors runnable.
/// One definition so the experiment alias layer and the sweep engine can
/// never drift apart.
pub fn apply_scale(cfg: &mut RunConfig, scale: f64) {
    if (scale - 1.0).abs() > 1e-9 {
        cfg.rounds = ((cfg.rounds as f64 * scale).round() as usize).max(2);
        cfg.train_n = ((cfg.train_n as f64 * scale).round() as usize).max(500);
        cfg.test_n = ((cfg.test_n as f64 * scale).round() as usize).max(100);
    }
}

/// Apply `--key value` style CLI overrides (see `fedcomloc train --help`).
pub fn apply_cli(cfg: &mut RunConfig, args: &crate::cli::Args) -> Result<(), ConfigError> {
    let pairs: &[(&str, &str)] = &[
        ("dataset", "dataset"),
        ("model", "model"),
        ("train-n", "train_n"),
        ("test-n", "test_n"),
        ("clients", "clients"),
        ("sampled", "sampled"),
        ("alpha", "alpha"),
        ("rounds", "rounds"),
        ("p", "p"),
        ("local-steps", "local_steps"),
        ("gamma", "gamma"),
        ("batch-size", "batch_size"),
        ("eval-batch", "eval_batch"),
        ("eval-every", "eval_every"),
        ("seed", "seed"),
        ("tau", "tau"),
        ("threads", "threads"),
        ("data-dir", "data_dir"),
        ("compress-up", "compress_up"),
        ("compress-down", "compress_down"),
        ("scenario", "scenario"),
        ("faults", "faults"),
        ("backend", "backend"),
    ];
    for (flag, key) in pairs {
        if let Some(raw) = args.get(flag) {
            let invalid = |reason: String| ConfigError::Invalid {
                key: (*flag).to_string(),
                reason,
            };
            let value = parse_flag_value(key, raw).map_err(invalid)?;
            apply_kv(cfg, key, &value).map_err(invalid)?;
        }
    }
    Ok(())
}

/// Typed parse of one CLI flag value. Numeric flags that fail to parse are
/// an error *here*, naming the raw value — they used to fall back to
/// `TomlValue::Str`, which turned typos like `--rounds 1O0` into a bare
/// "expected integer" from `apply_kv`, far from the cause.
fn parse_flag_value(key: &str, raw: &str) -> Result<TomlValue, String> {
    match key {
        "dataset" | "data_dir" | "model" | "compress_up" | "compress_down" | "scenario"
        | "faults" | "backend" | "trainer" => Ok(TomlValue::Str(raw.to_string())),
        "alpha" | "p" | "gamma" | "tau" => raw
            .parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| format!("expected a number, got '{raw}'")),
        _ => raw
            .parse::<i64>()
            .map(TomlValue::Int)
            .map_err(|_| format!("expected an integer, got '{raw}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_overrides_apply() {
        let mut cfg = RunConfig::default_mnist();
        let doc = toml::parse(
            r#"
[run]
dataset = "cifar10"
rounds = 123
alpha = 0.3
gamma = 0.01
clients = 50
"#,
        )
        .unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.dataset, DatasetSpec::cifar10());
        assert_eq!(cfg.rounds, 123);
        assert_eq!(cfg.dirichlet_alpha, 0.3);
        assert_eq!(cfg.gamma, 0.01);
        assert_eq!(cfg.n_clients, 50);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = RunConfig::default_mnist();
        let doc = toml::parse("[run]\nwat = 1").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        assert!(err.to_string().contains("wat"));
    }

    #[test]
    fn missing_run_table_is_noop() {
        let mut cfg = RunConfig::default_mnist();
        let rounds = cfg.rounds;
        let doc = toml::parse("[other]\nx = 1").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.rounds, rounds);
    }

    #[test]
    fn cli_overrides_apply() {
        let mut cfg = RunConfig::default_mnist();
        let cmd = crate::cli::Command::new("train", "t")
            .opt("rounds", "N", "")
            .opt("alpha", "F", "")
            .opt("dataset", "NAME", "");
        let args = cmd
            .parse(&[
                "--rounds".into(),
                "77".into(),
                "--alpha".into(),
                "0.1".into(),
                "--dataset".into(),
                "cifar10".into(),
            ])
            .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.rounds, 77);
        assert_eq!(cfg.dirichlet_alpha, 0.1);
        assert_eq!(cfg.dataset, DatasetSpec::cifar10());
    }

    #[test]
    fn model_key_applies_and_canonicalizes() {
        let mut cfg = RunConfig::default_mnist();
        assert_eq!(cfg.model_spec().key(), "mlp");
        let doc = toml::parse("[run]\nmodel = \"mlp:784x128x64x10\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.model_spec().key(), "mlp");
        let doc = toml::parse("[run]\nmodel = \"linear:784\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.model_spec().key(), "linear:784");
        let doc = toml::parse("[run]\nmodel = \"nope\"").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn compression_keys_apply_and_validate() {
        let mut cfg = RunConfig::default_mnist();
        let doc = toml::parse(
            "[run]\ncompress_up = \"ef(topk:0.1|q8)\"\ncompress_down = \"sched:topk:0.3..0.05@cosine\"",
        )
        .unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.compress_up, "ef(topk:0.1|q8)");
        assert_eq!(cfg.compress_down, "sched:topk:0.3..0.05@cosine");
        // Validation happens at entry, naming the key.
        let doc = toml::parse("[run]\ncompress_up = \"wat\"").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("compress_up") && msg.contains("unknown compressor"), "{msg}");
        // CLI flags route to the same schema point.
        let cmd = crate::cli::Command::new("train", "t")
            .opt("compress-up", "SPEC", "")
            .opt("compress-down", "SPEC", "");
        let args = cmd
            .parse(&["--compress-up".into(), "q8".into(), "--compress-down".into(), "topk:0.3".into()])
            .unwrap();
        let mut cfg = RunConfig::default_mnist();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.compress_up, "q8");
        assert_eq!(cfg.compress_down, "topk:0.3");
    }

    #[test]
    fn scenario_key_applies_and_canonicalizes() {
        let mut cfg = RunConfig::default_mnist();
        assert_eq!(cfg.scenario, "sync");
        // Omitted staleness canonicalizes to an explicit 0.5.
        let doc = toml::parse("[run]\nscenario = \"semisync:4\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.scenario, "semisync:4@0.5");
        let doc = toml::parse("[run]\nscenario = \"async\"").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        assert!(err.to_string().contains("unknown scenario"), "{err}");
        // CLI flag routes to the same schema point.
        let cmd = crate::cli::Command::new("train", "t").opt("scenario", "SPEC", "");
        let args = cmd
            .parse(&["--scenario".into(), "semisync:2@1".into()])
            .unwrap();
        let mut cfg = RunConfig::default_mnist();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.scenario, "semisync:2@1");
    }

    #[test]
    fn faults_key_applies_and_canonicalizes() {
        let mut cfg = RunConfig::default_mnist();
        assert_eq!(cfg.faults, "none");
        // Default retry/backoff knobs are elided from the canonical key.
        let doc =
            toml::parse("[run]\nfaults = \"corrupt:0.02|retry:2|backoff:0.5\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.faults, "corrupt:0.02");
        let doc = toml::parse("[run]\nfaults = \"jitter:0.5\"").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        assert!(err.to_string().contains("unknown fault clause"), "{err}");
        // CLI flag routes to the same schema point.
        let cmd = crate::cli::Command::new("train", "t").opt("faults", "SPEC", "");
        let args = cmd
            .parse(&["--faults".into(), "crash:0.1|quorum:0.6".into()])
            .unwrap();
        let mut cfg = RunConfig::default_mnist();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.faults, "crash:0.1|quorum:0.6");
    }

    #[test]
    fn backend_key_applies_validates_and_resolves_aliases() {
        let mut cfg = RunConfig::default_mnist();
        assert_eq!(cfg.backend, "auto");
        let doc = toml::parse("[run]\nbackend = \"native-simd\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.backend, "native-simd");
        // The legacy `trainer` key and `pjrt` spelling still work.
        let doc = toml::parse("[run]\ntrainer = \"pjrt\"").unwrap();
        apply_toml(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.backend, "xla");
        let doc = toml::parse("[run]\nbackend = \"cuda\"").unwrap();
        let err = apply_toml(&mut cfg, &doc).unwrap_err();
        assert!(err.to_string().contains("unknown backend"), "{err}");
        // CLI flag routes to the same schema point.
        let cmd = crate::cli::Command::new("train", "t").opt("backend", "KEY", "");
        let args = cmd.parse(&["--backend".into(), "native-bf16".into()]).unwrap();
        let mut cfg = RunConfig::default_mnist();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.backend, "native-bf16");
    }

    #[test]
    fn backend_auto_is_elided_from_kv_export() {
        let cfg = RunConfig::default_mnist();
        let kv = to_kv(&cfg);
        assert!(kv.iter().all(|(k, _)| k != "backend"), "auto must be elided");
        let mut pinned = RunConfig::default_mnist();
        pinned.backend = "native-simd".into();
        let kv = to_kv(&pinned);
        assert!(kv.iter().any(|(k, v)| k == "backend" && v == "native-simd"));
        let mut back = RunConfig::default_mnist();
        for (k, v) in &kv {
            apply_kv_str(&mut back, k, v).unwrap();
        }
        assert_eq!(back.backend, "native-simd");
    }

    #[test]
    fn kv_roundtrip_reconstructs_config() {
        let mut cfg = RunConfig::default_mnist();
        cfg.model = Some(ModelSpec::parse("linear:784").unwrap());
        cfg.compress_up = "ef(topk:0.1)".into();
        cfg.compress_down = "q8".into();
        cfg.scenario = "semisync:2@0.5".into();
        cfg.seed = 42;
        cfg.gamma = 0.037;
        cfg.dirichlet_alpha = 0.31;
        cfg.rounds = 17;
        let kv = to_kv(&cfg);
        assert!(kv.iter().all(|(k, _)| k != "threads"), "threads is host-local");
        let mut back = RunConfig::default_mnist();
        for (k, v) in &kv {
            apply_kv_str(&mut back, k, v).unwrap();
        }
        // Fixpoint: re-exporting the reconstruction reproduces the pairs.
        assert_eq!(to_kv(&back), kv);
        assert_eq!(back.gamma, cfg.gamma);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.model_spec().key(), "linear:784");
    }

    #[test]
    fn numeric_flag_typo_names_flag_and_raw_value() {
        // `--rounds 1O0` (letter O) must produce an error that names the
        // flag and the bad value, not a silent string fallback.
        let mut cfg = RunConfig::default_mnist();
        let cmd = crate::cli::Command::new("train", "t")
            .opt("rounds", "N", "")
            .opt("gamma", "F", "");
        let args = cmd.parse(&["--rounds".into(), "1O0".into()]).unwrap();
        let err = apply_cli(&mut cfg, &args).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("rounds") && msg.contains("1O0"), "{msg}");
        let args = cmd.parse(&["--gamma".into(), "0.0five".into()]).unwrap();
        let err = apply_cli(&mut cfg, &args).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gamma") && msg.contains("0.0five"), "{msg}");
    }
}
