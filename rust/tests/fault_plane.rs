//! Acceptance pins for the deterministic fault-injection plane and its
//! recovery runtime (`fedcomloc::fed::faults`):
//!
//! * **transparency** — a `FaultNet` built from an inactive spec is an
//!   exact no-op decorator: wrapping the transport changes nothing, byte
//!   for byte, across all four algorithm families (and `faults = "none"`
//!   never constructs one at all, so legacy output is preserved by
//!   construction);
//! * **thread invariance** — an *active* fault plan draws every fault from
//!   the coordinator-side salted RNG stream, so results are bit-identical
//!   at any `threads` setting;
//! * **EF correctness across retransmits** — with a deep retry budget every
//!   corrupted frame eventually recovers, and the learning trajectory
//!   (losses/accuracies) is bit-identical to the fault-free run even
//!   through a stateful `ef(...)` uplink pipeline: retransmits re-send the
//!   identical encoded frame, never re-folding residuals;
//! * **crash + resume under chaos** — a run killed mid-flight under an
//!   active fault plan resumes bit-identically (the fault RNG cursor rides
//!   in the transport's checkpoint section).

use fedcomloc::ckpt::Checkpointer;
use fedcomloc::data::DatasetSpec;
use fedcomloc::fed::faults::{FaultNet, FaultSpec};
use fedcomloc::fed::transport::parse_transport;
use fedcomloc::fed::{
    run_with_transport, run_with_transport_observed, AlgorithmSpec, RunConfig,
};
use fedcomloc::metrics::MetricsLog;
use fedcomloc::sweep::sink;
use std::path::{Path, PathBuf};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedcomloc-faults-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Fast convex workload (softmax on flat synthetic Gaussians) driven
/// through the `semisync:2` scenario, so the fault plane is exercised in
/// its full stacking order `ScenarioNet(FaultNet(inner))` with straggler
/// buffering above it.
fn tiny_cfg(compress_up: &str, faults: &str) -> RunConfig {
    RunConfig {
        dataset: DatasetSpec::parse("synthetic:32-c4").unwrap(),
        train_n: 400,
        test_n: 100,
        n_clients: 6,
        clients_per_round: 4,
        rounds: 6,
        eval_every: 2,
        batch_size: 16,
        eval_batch: 32,
        threads: 1,
        compress_up: compress_up.to_string(),
        scenario: "semisync:2".to_string(),
        faults: faults.to_string(),
        ..RunConfig::default_mnist()
    }
}

fn run(cfg: &RunConfig, algo: &str) -> MetricsLog {
    let spec = AlgorithmSpec::parse(algo).unwrap_or_else(|e| panic!("{algo}: {e}"));
    let trainer =
        fedcomloc::runtime::build_trainer("native", Path::new("artifacts"), &cfg.model_spec());
    let mut transport = parse_transport("inproc", cfg.seed).unwrap();
    run_with_transport(cfg, trainer, &spec, transport.as_mut())
}

/// The deterministic per-round serialization the sweep sink writes —
/// byte equality here covers losses, wire accounting, *and* the fault/
/// recovery counters.
fn lines(log: &MetricsLog) -> Vec<String> {
    log.records.iter().map(|r| sink::round_line("case", r)).collect()
}

#[test]
fn inactive_fault_plane_is_a_transparent_decorator_for_all_algorithms() {
    for (algo, up) in [
        ("fedcomloc-com", "ef(topk:0.25)"),
        ("fedavg", "ef(topk:0.25)"),
        ("scaffold", "none"),
        ("feddyn:0.01", "ef(topk:0.25)"),
    ] {
        let cfg = tiny_cfg(up, "none");
        let plain = run(&cfg, algo);

        // Same run with the transport explicitly wrapped in an inactive
        // FaultNet: the decorator must be invisible — it draws no RNG and
        // filters nothing, so every byte of the output is unchanged.
        let spec = AlgorithmSpec::parse(algo).unwrap();
        let trainer =
            fedcomloc::runtime::build_trainer("native", Path::new("artifacts"), &cfg.model_spec());
        let mut inner = parse_transport("inproc", cfg.seed).unwrap();
        let mut net = FaultNet::new(inner.as_mut(), FaultSpec::default(), cfg.seed);
        let wrapped = run_with_transport(&cfg, trainer, &spec, &mut net);

        assert_eq!(
            lines(&plain),
            lines(&wrapped),
            "{algo}: an inactive FaultNet perturbed the run"
        );
    }
}

#[test]
fn active_fault_plan_is_bit_identical_across_thread_counts() {
    let plan = "corrupt:0.3|crash:0.1|dup:0.2|quorum:0.5|retry:4";
    let mut cfg1 = tiny_cfg("ef(topk:0.25)", plan);
    cfg1.threads = 1;
    let mut cfg4 = cfg1.clone();
    cfg4.threads = 4;
    let (log1, log4) = (run(&cfg1, "fedcomloc-com"), run(&cfg4, "fedcomloc-com"));
    assert_eq!(lines(&log1), lines(&log4), "fault stream must be thread-invariant");
    // The plan actually fired: corruption was observed and recovered from.
    let corrupt: u64 = log1.records.iter().map(|r| r.corrupt_frames).sum();
    let retrans: u64 = log1.records.iter().map(|r| r.retransmits).sum();
    assert!(corrupt > 0, "corrupt:0.3 over 6 rounds must corrupt something");
    assert!(retrans > 0, "recovery must have retransmitted");
}

#[test]
fn deep_retries_recover_every_frame_and_preserve_ef_learning() {
    // corrupt:0.4 with a deep retry budget: every transmission eventually
    // succeeds, so the participant sets — and therefore the entire
    // learning trajectory through the stateful ef(...) pipeline — are
    // bit-identical to the fault-free run. Only the recovery accounting
    // (extra billed frames, backoff seconds) differs: retransmits re-send
    // the identical encoded frame and never re-fold EF residuals.
    let faulty = run(&tiny_cfg("ef(topk:0.25)", "corrupt:0.4|retry:24"), "fedcomloc-com");
    let clean = run(&tiny_cfg("ef(topk:0.25)", "none"), "fedcomloc-com");
    assert_eq!(faulty.records.len(), clean.records.len());
    for (f, c) in faulty.records.iter().zip(&clean.records) {
        assert_eq!(
            f.train_loss.to_bits(),
            c.train_loss.to_bits(),
            "round {}: loss diverged under recovered corruption",
            f.round
        );
        assert_eq!(
            f.test_accuracy.map(f64::to_bits),
            c.test_accuracy.map(f64::to_bits),
            "round {}: accuracy diverged under recovered corruption",
            f.round
        );
        assert_eq!(f.aborted, 0, "deep retries must never abort a round");
    }
    let corrupt: u64 = faulty.records.iter().map(|r| r.corrupt_frames).sum();
    let retrans: u64 = faulty.records.iter().map(|r| r.retransmits).sum();
    let backoff: f64 = faulty.records.iter().map(|r| r.backoff_secs).sum();
    assert!(corrupt > 0, "corruption must have been observed");
    assert_eq!(retrans, corrupt, "every corrupted frame was retransmitted");
    assert!(backoff > 0.0, "backoff must be charged to the simulated clock");
    // Recovery is billed: the faulty run ships strictly more uplink bits.
    let bits = |l: &MetricsLog| l.records.iter().map(|r| r.uplink_bits).sum::<u64>();
    assert!(bits(&faulty) > bits(&clean), "retransmits must be billed on the wire");
}

#[test]
fn crash_and_resume_under_active_faults_is_bit_identical() {
    let cfg = tiny_cfg("ef(topk:0.25)", "corrupt:0.3|crash:0.1|dup:0.2|quorum:0.5|retry:4");
    let spec = AlgorithmSpec::parse("fedcomloc-com").unwrap();
    let root = tmp_dir("resume");
    let observed = |ckpt: &mut Checkpointer| -> MetricsLog {
        let trainer =
            fedcomloc::runtime::build_trainer("native", Path::new("artifacts"), &cfg.model_spec());
        let mut transport = parse_transport("inproc", cfg.seed).unwrap();
        run_with_transport_observed(&cfg, trainer, &spec, transport.as_mut(), ckpt)
            .unwrap_or_else(|e| panic!("observed run failed: {e}"))
    };

    // Uninterrupted reference under the active plan.
    let dir_a = root.join("a");
    let mut ckpt_a = Checkpointer::new(&dir_a, spec.key());
    let log_a = observed(&mut ckpt_a);
    assert_eq!(log_a.records.len(), cfg.rounds);

    // Kill after round 3, restart in a fresh "process": the fault RNG
    // cursor rides in the transport's checkpoint section, so the restarted
    // run replays the identical fault stream.
    let dir_b = root.join("b");
    let mut crash = Checkpointer::new(&dir_b, spec.key()).crash_after(3);
    let partial = observed(&mut crash);
    assert_eq!(partial.records.len(), 3, "crash must stop the drive mid-run");
    let mut resume = Checkpointer::new(&dir_b, spec.key());
    let log_b = observed(&mut resume);
    assert_eq!(resume.resumed_from(), Some(3), "must resume at round 3");

    assert_eq!(
        lines(&log_a),
        lines(&log_b),
        "resumed run diverged under the active fault plan"
    );
    let _ = std::fs::remove_dir_all(&root);
}
