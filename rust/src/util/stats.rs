//! Summary statistics used by the bench harness and experiment reporting.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before any observation).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Full-sample summary with quantiles.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Sample count.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// 25th percentile (linear interpolation).
    pub p25: f64,
    /// 50th percentile.
    pub median: f64,
    /// 75th percentile.
    pub p75: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty sample set.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of empty sample set");
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        Summary {
            count: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: xs[0],
            p25: quantile_sorted(&xs, 0.25),
            median: quantile_sorted(&xs, 0.5),
            p75: quantile_sorted(&xs, 0.75),
            p95: quantile_sorted(&xs, 0.95),
            p99: quantile_sorted(&xs, 0.99),
            max: *xs.last().unwrap(),
        }
    }
}

/// Linear-interpolated quantile of a pre-sorted slice, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Human-friendly duration formatting for bench output.
pub fn format_duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-friendly byte-count formatting (SI).
pub fn format_bytes(bytes: f64) -> String {
    if bytes < 1e3 {
        format!("{bytes:.0} B")
    } else if bytes < 1e6 {
        format!("{:.2} KB", bytes / 1e3)
    } else if bytes < 1e9 {
        format!("{:.2} MB", bytes / 1e6)
    } else {
        format!("{:.2} GB", bytes / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 100.0);
        assert!((quantile_sorted(&xs, 0.5) - 50.5).abs() < 1e-12);
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_duration_ns(512.0), "512.0 ns");
        assert_eq!(format_duration_ns(2_500.0), "2.50 µs");
        assert_eq!(format_duration_ns(3_200_000.0), "3.20 ms");
        assert_eq!(format_bytes(999.0), "999 B");
        assert_eq!(format_bytes(1_500_000.0), "1.50 MB");
    }

    #[test]
    fn single_sample_summary() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }
}
