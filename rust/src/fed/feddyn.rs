//! FedDyn (Acar et al., 2021) — the additional baseline in Figure 9 — as a
//! [`FedAlgorithm`].
//!
//! Each client keeps a gradient correction λ_i (stored in `ClientState::h`)
//! and minimizes the dynamically-regularized local objective
//!     f_i(x) − ⟨λ_i, x⟩ + (α_dyn/2)·‖x − x_server‖²
//! by E SGD steps; afterwards λ_i ← λ_i − α_dyn·(x_i − x_server).
//! The server tracks s ← s − (α_dyn/n)·Σ_{i∈S}(x_i − x_server) and sets
//!     x_server = mean_{i∈S}(x_i) − s/α_dyn.
//! Communication is one d-vector [`Message`] each way — dense by default,
//! routed through the configured `compress_up`/`compress_down` pipelines
//! like every other driver.

use super::algorithm::{AlgoState, FedAlgorithm, RoundCtx, RoundOutcome};
use super::message::{Message, SERVER};
use super::{Federation, RunConfig};
use crate::tensor;
use crate::util::rng::Rng;

/// FedDyn with regularizer strength `alpha_dyn` (see module docs).
pub struct FedDyn {
    alpha_dyn: f64,
    server_state: Vec<f32>,
    /// Server-side randomness for a stochastic downlink codec.
    server_rng: Rng,
}

impl FedDyn {
    /// A fresh FedDyn with regularizer α_dyn (the registry default: 0.01).
    pub fn new(alpha_dyn: f64) -> FedDyn {
        FedDyn {
            alpha_dyn,
            server_state: Vec::new(),
            server_rng: Rng::seed_from_u64(0),
        }
    }
}

impl FedAlgorithm for FedDyn {
    fn name(&self) -> String {
        format!("feddyn[a={}]", self.alpha_dyn)
    }

    fn log_name(&self, fed: &Federation, cfg: &RunConfig) -> String {
        format!(
            "feddyn[a={}]-{}-a{}",
            self.alpha_dyn,
            fed.model.name(),
            cfg.dirichlet_alpha
        )
    }

    fn log_meta(&self, cfg: &RunConfig) -> Vec<(String, String)> {
        vec![
            ("algorithm".into(), "feddyn".into()),
            ("feddyn_alpha".into(), self.alpha_dyn.to_string()),
            ("gamma".into(), cfg.gamma.to_string()),
            ("local_steps".into(), cfg.local_steps.to_string()),
            ("alpha".into(), cfg.dirichlet_alpha.to_string()),
        ]
    }

    fn setup(&mut self, fed: &mut Federation, _cfg: &RunConfig) {
        self.server_state = vec![0.0f32; fed.x.len()];
        self.server_rng = fed.rng.derive(0xFEDD_D114);
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundOutcome {
        let cfg = ctx.cfg;
        let round = ctx.round;
        let a = self.alpha_dyn as f32;

        let msg = Message::through(
            round,
            SERVER,
            &ctx.fed.x,
            &mut ctx.fed.downlink,
            &mut self.server_rng,
        );
        let participants = ctx.transport.broadcast(&ctx.sampled, &msg);
        let x = msg.to_dense();

        let trainer = ctx.fed.trainer.clone();
        let gamma = cfg.gamma;
        let local_steps = cfg.local_steps;
        let d = x.len();
        let results: Vec<(Message, f64)> = ctx.map_clients_ws(&participants, |ci, state, ws| {
            let mut xi = ws.take_xi_primed(&x);
            // ∇[f_i(x) − ⟨λ,x⟩ + a/2‖x−x₀‖²] = g − λ + a(x − x₀).
            // Express as the Scaffnew step form with h = λ − a(x − x₀);
            // h depends on x, so rebuild it each step (into a buffer
            // reused across the segment).
            let mut h_eff = vec![0.0f32; d];
            let mut loss_sum = 0.0f64;
            // Empty shards (million-client populations smaller than the
            // dataset leave most clients without examples) skip local
            // training: the client echoes the broadcast model back.
            if !state.loader.is_empty() {
                for _ in 0..local_steps {
                    let batch = state.loader.next_batch();
                    for j in 0..d {
                        h_eff[j] = state.h[j] - a * (xi[j] - x[j]);
                    }
                    let loss = trainer.train_step_into(&xi[..d], &h_eff, &batch, gamma, ws);
                    std::mem::swap(&mut xi, &mut ws.step);
                    loss_sum += loss as f64;
                }
            }
            let upload =
                Message::through(round, ci as u32, &xi[..d], &mut state.up, &mut state.rng);
            ws.put_xi(xi);
            (upload, loss_sum)
        });

        let loss_sum: f64 = results.iter().map(|(_, l)| l).sum();
        let n_trained = results.len();
        let mut models: Vec<Vec<f32>> = Vec::with_capacity(n_trained);
        for ((upload, _), &ci) in results.into_iter().zip(&participants) {
            if let Some(received) = ctx.transport.uplink(ci, upload) {
                let xi = received.to_dense();
                // λ_i ← λ_i − a·(x_i − x_server), committed only once the
                // uplink is known delivered so a lossy transport cannot
                // advance a correction the server never saw.
                {
                    let mut state = ctx.fed.clients[ci].lock().unwrap();
                    for j in 0..xi.len() {
                        state.h[j] -= a * (xi[j] - x[j]);
                    }
                }
                models.push(xi);
            }
        }

        if !models.is_empty() {
            // Server: s ← s − (a/n)·Σ(x_i − x); x ← mean(x_i) − s/a.
            let dim = ctx.fed.x.len();
            for xi in &models {
                for j in 0..dim {
                    self.server_state[j] -= a / cfg.n_clients as f32 * (xi[j] - x[j]);
                }
            }
            let rows: Vec<&[f32]> = models.iter().map(|v| v.as_slice()).collect();
            crate::tensor::mean_into(&rows, &mut ctx.fed.x);
            tensor::axpy(-1.0 / a, &self.server_state, &mut ctx.fed.x);
        }

        RoundOutcome {
            local_steps: cfg.local_steps,
            train_loss: loss_sum / (n_trained * cfg.local_steps).max(1) as f64,
        }
    }

    fn save_state(&self) -> AlgoState {
        // Cross-round server state: the gradient tracker s and the downlink
        // codec stream (per-client λ_i live in `ClientState::h`).
        let mut state = AlgoState::new();
        state.push_vec("server_state", &self.server_state);
        state.push_rng("server_rng", &self.server_rng);
        state
    }

    fn restore_state(&mut self, mut state: AlgoState) -> Result<(), String> {
        self.server_state = state.take_vec("server_state")?;
        self.server_rng = state.take_rng("server_rng")?;
        state.finish()
    }
}
