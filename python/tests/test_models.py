"""L2 correctness: models over flat parameter vectors, train/eval programs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.models import cnn, mlp

RNG = np.random.default_rng(0)


def he_init_mlp():
    """He-init matching rust/src/model/mlp.rs (layout check only needs shape)."""
    p = np.zeros(mlp.DIM, np.float32)
    for name, (lo, hi, shape) in mlp.SLICES.items():
        if name.startswith("w"):
            fan_in = shape[0]
            p[lo:hi] = RNG.normal(0, np.sqrt(2 / fan_in), hi - lo)
    return jnp.asarray(p)


def batch_mlp(b=8):
    x = jnp.asarray(RNG.random((b, 784)).astype(np.float32))
    y = jnp.asarray(RNG.integers(0, 10, b).astype(np.int32))
    return x, y


def test_dims_match_rust_layout():
    assert mlp.DIM == 109_386
    assert cnn.DIM == 744_330
    # Slices tile the whole vector contiguously.
    for mod in (mlp, cnn):
        cursor = 0
        for name, (lo, hi, shape) in mod.SLICES.items():
            assert lo == cursor, name
            size = int(np.prod(shape))
            assert hi - lo == size
            cursor = hi
        assert cursor == mod.DIM


def test_mlp_forward_shapes_and_loss():
    p = he_init_mlp()
    x, y = batch_mlp(8)
    logits = mlp.forward(p, x)
    assert logits.shape == (8, 10)
    loss = mlp.loss_fn(p, x, y)
    # ~uniform logits at init -> loss ≈ ln(10)
    assert 1.5 < float(loss) < 3.5


def test_mlp_grad_descends():
    p = he_init_mlp()
    x, y = batch_mlp(16)
    fn = jax.jit(M.PROGRAMS["grad"]("mlp"))
    params = p
    losses = []
    for _ in range(15):
        g, loss = fn(params, x, y)
        params = params - 0.1 * g
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses


def test_train_step_equals_grad_then_update():
    p = he_init_mlp()
    h = jnp.asarray(RNG.normal(0, 0.01, mlp.DIM).astype(np.float32))
    x, y = batch_mlp(8)
    gamma = jnp.float32(0.07)
    new_p, loss1 = jax.jit(M.PROGRAMS["train_step"]("mlp"))(p, h, x, y, gamma)
    g, loss2 = jax.jit(M.PROGRAMS["grad"]("mlp"))(p, x, y)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(new_p), np.asarray(p - gamma * (g - h)), rtol=1e-5, atol=1e-6
    )


def test_train_step_local_density_one_matches_plain():
    p = he_init_mlp()
    h = jnp.zeros(mlp.DIM, jnp.float32)
    x, y = batch_mlp(8)
    plain, _ = jax.jit(M.PROGRAMS["train_step"]("mlp"))(p, h, x, y, jnp.float32(0.1))
    masked, _ = jax.jit(M.PROGRAMS["train_step_local"]("mlp"))(
        p, h, x, y, jnp.float32(0.1), jnp.float32(1.0)
    )
    np.testing.assert_allclose(np.asarray(plain), np.asarray(masked), atol=1e-6)
    low, _ = jax.jit(M.PROGRAMS["train_step_local"]("mlp"))(
        p, h, x, y, jnp.float32(0.1), jnp.float32(0.02)
    )
    assert not np.allclose(np.asarray(plain), np.asarray(low))


def test_evaluate_per_example_outputs():
    p = he_init_mlp()
    x, y = batch_mlp(12)
    losses, correct = jax.jit(M.PROGRAMS["evaluate"]("mlp"))(p, x, y)
    assert losses.shape == (12,)
    assert correct.shape == (12,)
    assert set(np.asarray(correct).tolist()) <= {0, 1}
    assert (np.asarray(losses) > 0).all()
    # Mean of per-example losses equals loss_fn.
    np.testing.assert_allclose(
        float(jnp.mean(losses)), float(mlp.loss_fn(p, x, y)), rtol=1e-5
    )


def test_cnn_forward_and_grad():
    p = np.zeros(cnn.DIM, np.float32)
    for name, (lo, hi, shape) in cnn.SLICES.items():
        if name.startswith("w"):
            fan_in = int(np.prod(shape[1:])) if len(shape) == 4 else shape[0]
            p[lo:hi] = RNG.normal(0, np.sqrt(2 / fan_in), hi - lo)
    p = jnp.asarray(p)
    x = jnp.asarray(RNG.random((4, 3, 32, 32)).astype(np.float32))
    y = jnp.asarray(RNG.integers(0, 10, 4).astype(np.int32))
    logits = cnn.forward(p, x)
    assert logits.shape == (4, 10)
    g, loss = jax.jit(M.PROGRAMS["grad"]("cnn"))(p, x, y)
    assert g.shape == (cnn.DIM,)
    assert float(loss) > 0
    # Gradient must touch every layer (no dead blocks).
    for name, (lo, hi, _) in cnn.SLICES.items():
        block = np.asarray(g[lo:hi])
        assert np.abs(block).max() > 0, f"zero gradient block {name}"


def test_example_args_shapes():
    for name in ("mlp", "cnn"):
        for program in M.PROGRAMS:
            args = M.example_args(name, program)
            assert args[0].shape == (M.MODELS[name].DIM,)
    with pytest.raises(ValueError):
        M.example_args("mlp", "nope")
