//! [`PjrtTrainer`]: the AOT compute plane behind [`LocalTrainer`].
//!
//! One compiled executable per program (train_step, train_step_local, grad,
//! evaluate); each local iteration is exactly one PJRT call. Numerics match
//! `model::native` (same parameter layout, same loss) up to f32 reduction
//! order — asserted by `rust/tests/runtime_artifacts.rs`.
//!
//! Artifacts are keyed by [`Model::artifact_name`] in the manifest; the
//! prebuilt set covers the seed `mlp`/`cnn` layouts. Loading any other
//! registry spec fails with a clear error and callers (e.g.
//! `experiments::ExpOptions::make_trainer`) fall back to the native plane.

use super::engine::{Engine, Input, RuntimeError};
use crate::data::loader::Batch;
use crate::model::{LocalTrainer, Model, Workspace};
use std::path::Path;
use std::sync::Arc;

/// The AOT compute plane: one compiled PJRT executable per program, adapted
/// to [`LocalTrainer`] (see module docs).
pub struct PjrtTrainer {
    engine: Arc<Engine>,
    model: Model,
    name: String,
    dim: usize,
    batch: usize,
    eval_batch: usize,
}

impl PjrtTrainer {
    /// Load and compile this model's artifacts from `dir`.
    pub fn load(dir: &Path, model: &Model) -> Result<PjrtTrainer, RuntimeError> {
        let name = model.artifact_name().to_string();
        let names: Vec<String> = ["train_step", "train_step_local", "grad", "evaluate"]
            .iter()
            .map(|p| format!("{name}_{p}"))
            .collect();
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let engine = Engine::load(dir, &name_refs)?;
        let spec = engine.manifest().model(&name)?.clone();
        Self::from_parts(Arc::new(engine), model.clone(), name, spec)
    }

    /// Share an existing engine (used by tests that also call the
    /// standalone `quantize` artifact).
    pub fn from_engine(engine: Arc<Engine>, model: &Model) -> Result<PjrtTrainer, RuntimeError> {
        let name = model.artifact_name().to_string();
        let spec = engine.manifest().model(&name)?.clone();
        Self::from_parts(engine, model.clone(), name, spec)
    }

    fn from_parts(
        engine: Arc<Engine>,
        model: Model,
        name: String,
        spec: super::artifacts::ModelArtifact,
    ) -> Result<PjrtTrainer, RuntimeError> {
        if spec.dim != model.dim() {
            return Err(RuntimeError::Xla(format!(
                "manifest model '{name}' has dim {} but spec '{}' builds dim {} — \
                 rebuild artifacts for this layout or use the native trainer",
                spec.dim,
                model.name(),
                model.dim()
            )));
        }
        Ok(PjrtTrainer {
            engine,
            model,
            name,
            dim: spec.dim,
            batch: spec.batch,
            eval_batch: spec.eval_batch,
        })
    }

    /// The shared PJRT engine behind this trainer.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Static train-batch size of the compiled executables.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Static eval-batch size of the compiled executables.
    pub fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    fn check_batch(&self, batch: &Batch) {
        assert_eq!(
            batch.batch_size, self.batch,
            "batch size must match compiled executable ({})",
            self.batch
        );
        assert_eq!(batch.feature_dim, self.model.input_dim());
    }

    fn unwrap(err: RuntimeError) -> ! {
        panic!("PJRT execution failed: {err}");
    }
}

impl LocalTrainer for PjrtTrainer {
    fn model(&self) -> &Model {
        &self.model
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn grad(&self, params: &[f32], batch: &Batch) -> (Vec<f32>, f32) {
        self.check_batch(batch);
        let outs = self
            .engine
            .call(
                &format!("{}_grad", self.name),
                &[
                    Input::F32(params),
                    Input::F32(&batch.x),
                    Input::I32(&batch.y),
                ],
            )
            .unwrap_or_else(|e| Self::unwrap(e));
        let g = outs[0].as_f32().to_vec();
        let loss = outs[1].scalar_f32();
        (g, loss)
    }

    fn train_step(&self, params: &[f32], h: &[f32], batch: &Batch, gamma: f32) -> (Vec<f32>, f32) {
        self.check_batch(batch);
        let outs = self
            .engine
            .call(
                &format!("{}_train_step", self.name),
                &[
                    Input::F32(params),
                    Input::F32(h),
                    Input::F32(&batch.x),
                    Input::I32(&batch.y),
                    Input::ScalarF32(gamma),
                ],
            )
            .unwrap_or_else(|e| Self::unwrap(e));
        (outs[0].as_f32().to_vec(), outs[1].scalar_f32())
    }

    fn train_step_masked(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
    ) -> (Vec<f32>, f32) {
        self.check_batch(batch);
        let outs = self
            .engine
            .call(
                &format!("{}_train_step_local", self.name),
                &[
                    Input::F32(params),
                    Input::F32(h),
                    Input::F32(&batch.x),
                    Input::I32(&batch.y),
                    Input::ScalarF32(gamma),
                    Input::ScalarF32(density as f32),
                ],
            )
            .unwrap_or_else(|e| Self::unwrap(e));
        (outs[0].as_f32().to_vec(), outs[1].scalar_f32())
    }

    // The `_into` fast paths delegate to the compiled artifacts (never the
    // host-side default compositions, which would bypass the in-graph
    // kernels): results are copied into the workspace buffers, so drivers
    // run one code path over both compute planes.

    fn grad_into(&self, params: &[f32], batch: &Batch, ws: &mut Workspace) -> f32 {
        let (g, loss) = self.grad(params, batch);
        ws.ensure(self.model(), batch.y.len());
        ws.grad[..g.len()].copy_from_slice(&g);
        loss
    }

    fn train_step_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        ws: &mut Workspace,
    ) -> f32 {
        let (x, loss) = self.train_step(params, h, batch, gamma);
        ws.step_mut(x.len()).copy_from_slice(&x);
        loss
    }

    fn train_step_masked_into(
        &self,
        params: &[f32],
        h: &[f32],
        batch: &Batch,
        gamma: f32,
        density: f64,
        ws: &mut Workspace,
    ) -> f32 {
        let (x, loss) = self.train_step_masked(params, h, batch, gamma, density);
        ws.step_mut(x.len()).copy_from_slice(&x);
        loss
    }

    fn eval_batch(
        &self,
        params: &[f32],
        batch: &Batch,
        valid: usize,
        _ws: &mut Workspace,
    ) -> (f64, usize) {
        assert_eq!(
            batch.batch_size, self.eval_batch,
            "eval batch size must match compiled executable ({})",
            self.eval_batch
        );
        let outs = self
            .engine
            .call(
                &format!("{}_evaluate", self.name),
                &[
                    Input::F32(params),
                    Input::F32(&batch.x),
                    Input::I32(&batch.y),
                ],
            )
            .unwrap_or_else(|e| Self::unwrap(e));
        let losses = outs[0].as_f32();
        let correct = outs[1].as_i32();
        let loss_sum: f64 = losses.iter().take(valid).map(|&l| l as f64).sum();
        let n_correct: usize = correct.iter().take(valid).map(|&c| c as usize).sum();
        (loss_sum, n_correct)
    }
}
