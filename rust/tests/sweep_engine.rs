//! End-to-end pins for the declarative sweep engine (ISSUE 3 acceptance):
//!
//! * the summary-CSV / round-JSONL schemas are golden;
//! * a multi-threaded sweep is **byte-identical** to the same sweep at
//!   `--threads 1` (per-run RNG streams make results order-independent);
//! * every run a sweep executes is bit-identical to driving the same
//!   `RunConfig` + algorithm spec through `fed::run_with_transport`
//!   directly — the successor to the legacy hand-written experiment
//!   modules' metric equality;
//! * `--resume` skips exactly the runs whose summary rows exist and
//!   reproduces the full canonical summary;
//! * shipped presets expand to the legacy experiment grids.

use fedcomloc::fed::transport::parse_transport;
use fedcomloc::fed::{run_with_transport, AlgorithmSpec};
use fedcomloc::sweep::{self, sink, SweepOptions, SweepSpec};
use std::path::{Path, PathBuf};

/// A fast sweep: convex softmax workload (d = 132), one SimNet block to
/// exercise the simulated-network columns.
const TINY_SWEEP: &str = r#"
schema = 1
name = "enginetest"
title = "engine test sweep"

[base]
preset = "smoke"
dataset = "synthetic:32-c4"
train_n = 400
test_n = 100
clients = 6
sampled = 3
rounds = 3
eval_every = 2
batch_size = 16
eval_batch = 32

[[grid]]
algos = ["fedcomloc-com:topk:0.5", "fedavg"]
alphas = [0.3, 0.8]

[[grid]]
algos = ["fedavg:q:8"]
transports = ["simnet:10:5:0.2:2"]

[[grid]]
algos = ["fedcomloc-com"]
compress_up = ["ef(topk:0.5)"]
compress_down = ["q8"]
"#;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedcomloc_sweep_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn opts(out: &Path, threads: usize) -> SweepOptions {
    SweepOptions {
        out_dir: out.to_path_buf(),
        threads,
        backend: "native".to_string(),
        ..SweepOptions::default()
    }
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

#[test]
fn summary_schema_is_golden() {
    let spec = SweepSpec::parse_str(TINY_SWEEP).unwrap();
    let out = tmp_dir("schema");
    let outcome = sweep::run_sweep(&spec, &opts(&out, 1)).unwrap();
    assert_eq!(outcome.executed, 6);
    let text = read(&sink::summary_path(&outcome.dir));
    let mut lines = text.lines();
    assert_eq!(lines.next(), Some(sink::SUMMARY_HEADER));
    assert_eq!(
        sink::SUMMARY_HEADER,
        "schema,run_id,sweep,algo,dataset,model,transport,backend,rounds,local_steps,p,alpha,gamma,seed,\
         train_n,test_n,clients,sampled,batch_size,eval_batch,eval_every,tau,data_dir,\
         compress_up,compress_down,scenario,faults,\
         best_accuracy,final_accuracy,final_train_loss,total_uplink_bits,total_downlink_bits,\
         total_cost,total_sim_secs,dropped_clients,stale_updates,churned_clients,\
         corrupt_frames,retransmits,backoff_secs,aborted_rounds",
        "summary schema v5 is pinned; bump sink::RESULT_SCHEMA to change it"
    );
    let rows: Vec<&str> = lines.collect();
    assert_eq!(rows.len(), 6);
    for (row, unit) in rows.iter().zip(&outcome.units) {
        let fields: Vec<&str> = row.split(',').collect();
        assert_eq!(fields.len(), 41, "{row}");
        assert_eq!(fields[0], "5");
        assert_eq!(fields[1], unit.id);
        assert_eq!(fields[2], "enginetest");
        assert_eq!(fields[3], unit.algo);
        assert_eq!(fields[4], "synthetic:32-c4");
        assert_eq!(fields[5], "softmax:32x4");
        assert_eq!(fields[7], "native", "backend column");
        assert_eq!(fields[14], "400", "train_n column");
        assert_eq!(fields[16], "6", "clients column");
        assert_eq!(fields[23], unit.cfg.compress_up, "compress_up column");
        assert_eq!(fields[24], unit.cfg.compress_down, "compress_down column");
        assert_eq!(fields[25], "sync", "scenario column");
        assert_eq!(fields[26], "none", "faults column");
        // Evaluated runs carry a best accuracy in (0, 1].
        let best: f64 = fields[27].parse().unwrap_or_else(|e| panic!("{row}: {e}"));
        assert!(best > 0.0 && best <= 1.0, "{row}");
    }
    // The EF/bidirectional run keeps the legacy id shape plus suffixes.
    assert_eq!(outcome.units[5].cfg.compress_up, "ef(topk:0.5)");
    assert_eq!(outcome.units[5].cfg.compress_down, "q8");
    assert!(outcome.units[5].id.contains("-u-ef_topk_0.5_"), "{}", outcome.units[5].id);
    // The SimNet run accumulated simulated seconds; InProc runs did not.
    assert!(rows[4].split(',').nth(33).unwrap().parse::<f64>().unwrap() > 0.0);
    assert_eq!(rows[0].split(',').nth(33), Some("0"));
    // Per-round JSONL exists for every run, with one line per round.
    for unit in &outcome.units {
        let jsonl = read(&sink::rounds_path(&outcome.dir, &unit.id));
        assert_eq!(jsonl.lines().count(), 3, "{}", unit.id);
        let first = fedcomloc::util::json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("schema").unwrap().as_usize().unwrap(), 5);
        assert_eq!(first.get("run").unwrap().as_str().unwrap(), unit.id);
        assert_eq!(first.get("round").unwrap().as_usize().unwrap(), 0);
        assert!(first.get("wall_secs").is_none(), "wall clock must not leak");
    }
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn multithreaded_sweep_is_bit_identical_to_single_threaded() {
    let spec = SweepSpec::parse_str(TINY_SWEEP).unwrap();
    let out1 = tmp_dir("det1");
    let out4 = tmp_dir("det4");
    let o1 = sweep::run_sweep(&spec, &opts(&out1, 1)).unwrap();
    let o4 = sweep::run_sweep(&spec, &opts(&out4, 4)).unwrap();
    assert_eq!(
        read(&sink::summary_path(&o1.dir)),
        read(&sink::summary_path(&o4.dir)),
        "summary.csv must not depend on --threads"
    );
    for unit in &o1.units {
        assert_eq!(
            read(&sink::rounds_path(&o1.dir, &unit.id)),
            read(&sink::rounds_path(&o4.dir, &unit.id)),
            "{}: rounds jsonl must not depend on --threads",
            unit.id
        );
    }
    let _ = std::fs::remove_dir_all(&out1);
    let _ = std::fs::remove_dir_all(&out4);
}

#[test]
fn sweep_runs_are_bit_identical_to_direct_fed_runs() {
    // The successor to the legacy experiment modules' metric equality: the
    // engine must add nothing and lose nothing relative to calling the
    // federated runtime directly with the same expanded RunConfig.
    let spec = SweepSpec::parse_str(TINY_SWEEP).unwrap();
    let out = tmp_dir("equiv");
    let outcome = sweep::run_sweep(&spec, &opts(&out, 4)).unwrap();
    for unit in &outcome.units {
        let algo = AlgorithmSpec::parse(&unit.algo).unwrap();
        let trainer = fedcomloc::runtime::build_trainer(
            "native",
            Path::new("artifacts"),
            &unit.cfg.model_spec(),
        );
        let mut transport =
            parse_transport(&unit.transport, unit.cfg.seed).unwrap();
        let log = run_with_transport(&unit.cfg, trainer, &algo, transport.as_mut());
        let direct: String = log
            .records
            .iter()
            .map(|r| sink::round_line(&unit.id, r) + "\n")
            .collect();
        assert_eq!(
            direct,
            read(&sink::rounds_path(&outcome.dir, &unit.id)),
            "{}: sweep output differs from a direct fed run",
            unit.id
        );
        let row = sink::summary_row("enginetest", "native", unit, &log);
        assert!(outcome.rows.contains(&row), "{}: summary row differs", unit.id);
    }
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn resume_skips_completed_runs_and_restores_the_canonical_summary() {
    let spec = SweepSpec::parse_str(TINY_SWEEP).unwrap();
    let out = tmp_dir("resume");
    let full = sweep::run_sweep(&spec, &opts(&out, 2)).unwrap();
    let spath = sink::summary_path(&full.dir);
    let complete = read(&spath);

    // Drop one run's row; a resumed sweep must re-execute exactly that run.
    let dropped_id = &full.units[2].id;
    let pruned: String = complete
        .lines()
        .filter(|l| !l.contains(dropped_id.as_str()))
        .map(|l| format!("{l}\n"))
        .collect();
    std::fs::write(&spath, pruned).unwrap();
    let resumed = sweep::run_sweep(
        &spec,
        &SweepOptions {
            resume: true,
            ..opts(&out, 2)
        },
    )
    .unwrap();
    assert_eq!(resumed.executed, 1);
    assert_eq!(resumed.skipped, 5);
    assert_eq!(read(&spath), complete, "resume must restore the canonical summary");

    // Resuming an untouched sweep executes nothing.
    let noop = sweep::run_sweep(
        &spec,
        &SweepOptions {
            resume: true,
            ..opts(&out, 2)
        },
    )
    .unwrap();
    assert_eq!(noop.executed, 0);
    assert_eq!(noop.skipped, 6);

    // A row whose configuration prefix no longer matches the expanded unit
    // (here: a different seed) must be re-executed, not silently reused.
    let unit = &full.units[1];
    let mut stale_unit = unit.clone();
    stale_unit.cfg.seed = 999;
    let good_key = sink::summary_key("enginetest", "native", unit);
    let stale_key = sink::summary_key("enginetest", "native", &stale_unit);
    let tampered = complete.replace(&good_key, &stale_key);
    assert_ne!(tampered, complete, "tampering must hit the target row");
    std::fs::write(&spath, tampered).unwrap();
    let revalidated = sweep::run_sweep(
        &spec,
        &SweepOptions {
            resume: true,
            ..opts(&out, 2)
        },
    )
    .unwrap();
    assert_eq!(revalidated.executed, 1, "config drift must re-run the unit");
    assert_eq!(read(&spath), complete, "re-run restores the true summary");
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn dry_run_writes_nothing_and_prints_the_matrix() {
    let spec = SweepSpec::parse_str(TINY_SWEEP).unwrap();
    let out = tmp_dir("dry");
    let outcome = sweep::run_sweep(
        &spec,
        &SweepOptions {
            dry_run: true,
            ..opts(&out, 1)
        },
    )
    .unwrap();
    assert_eq!(outcome.executed, 0);
    assert!(outcome.rows.is_empty());
    assert_eq!(outcome.units.len(), 6);
    assert!(!out.exists(), "dry run must not touch the filesystem");
    let matrix = sweep::format_matrix(&outcome.units);
    assert_eq!(matrix.lines().count(), 7, "header + one line per run");
    assert!(matrix.contains("fedavg:q:8"));
    assert!(matrix.contains("simnet:10:5:0.2:2"));
    assert!(matrix.contains("ef(topk:0.5)"), "compress columns in the matrix");
    let _ = std::fs::remove_dir_all(&out);
}

/// The scale axes at full size: a 10^6-client population sampled 100 per
/// round. The sparse cohort sampler and paged store must keep this cheap,
/// and — the actual pin — per-run RNG streams must make the schedule
/// order-independent, so `--threads 4` reproduces `--threads 1` byte for
/// byte even when runs materialize disjoint cohorts concurrently.
const SCALE_SWEEP: &str = r#"
schema = 1
name = "scaletest"
title = "million-client scale axes"

[base]
preset = "smoke"
dataset = "synthetic:32-c4"
train_n = 400
test_n = 100
rounds = 2
eval_every = 2
batch_size = 16
eval_batch = 32

[[grid]]
algos = ["fedavg", "fedcomloc-com:topk:0.5"]
clients = [1_000_000]
sampled = [100]
"#;

#[test]
fn million_client_scale_axis_sweep_is_bit_identical_across_threads() {
    let spec = SweepSpec::parse_str(SCALE_SWEEP).unwrap();
    let out1 = tmp_dir("scale1");
    let out4 = tmp_dir("scale4");
    let o1 = sweep::run_sweep(&spec, &opts(&out1, 1)).unwrap();
    let o4 = sweep::run_sweep(&spec, &opts(&out4, 4)).unwrap();
    assert_eq!(o1.executed, 2);
    for unit in &o1.units {
        assert!(unit.id.ends_with("-n-1000000-m-100"), "scale suffix missing: {}", unit.id);
        assert_eq!(unit.cfg.n_clients, 1_000_000);
        assert_eq!(unit.cfg.clients_per_round, 100);
    }
    assert_eq!(
        read(&sink::summary_path(&o1.dir)),
        read(&sink::summary_path(&o4.dir)),
        "summary.csv must not depend on --threads at the million-client scale"
    );
    for unit in &o1.units {
        assert_eq!(
            read(&sink::rounds_path(&o1.dir, &unit.id)),
            read(&sink::rounds_path(&o4.dir, &unit.id)),
            "{}: rounds jsonl must not depend on --threads",
            unit.id
        );
    }
    let _ = std::fs::remove_dir_all(&out1);
    let _ = std::fs::remove_dir_all(&out4);
}

#[test]
fn oversampled_scale_axis_fails_expansion_before_any_run() {
    // `sampled` > `clients` is caught when the matrix expands — before a
    // single run executes or the output directory is created.
    let bad = r#"
schema = 1
name = "scalebad"
title = "oversampled"

[base]
dataset = "synthetic:32-c4"
train_n = 400
test_n = 100

[[grid]]
algos = ["fedavg"]
clients = [1000]
sampled = [5000]
"#;
    let spec = SweepSpec::parse_str(bad).unwrap();
    let out = tmp_dir("scalebad");
    let err = sweep::run_sweep(&spec, &opts(&out, 1)).unwrap_err();
    assert!(err.contains("exceeds n_clients"), "unexpected error: {err}");
    assert!(!out.exists(), "failed expansion must not touch the filesystem");
}

#[test]
fn shipped_sparsity_preset_expands_to_the_legacy_density_grid() {
    let spec = sweep::preset_by_name("sparsity").unwrap().unwrap();
    let units = spec.expand(1.0, None).unwrap();
    let algos: Vec<&str> = units.iter().map(|u| u.algo.as_str()).collect();
    assert_eq!(
        algos,
        [
            "fedcomloc-com:none",
            "fedcomloc-com:topk:0.1",
            "fedcomloc-com:topk:0.3",
            "fedcomloc-com:topk:0.5",
            "fedcomloc-com:topk:0.7",
            "fedcomloc-com:topk:0.9",
        ],
        "Table 1 density grid"
    );
    // Legacy table1 ran the scaled-mnist defaults.
    for u in &units {
        assert_eq!(u.cfg.rounds, 60);
        assert_eq!(u.cfg.n_clients, 100);
        assert_eq!(u.cfg.dirichlet_alpha, 0.7);
        assert_eq!(u.transport, "inproc");
        assert_eq!(u.model_key(), "mlp");
    }
}

#[test]
fn shipped_heterogeneity_preset_expands_to_the_legacy_alpha_grid() {
    let spec = sweep::preset_by_name("heterogeneity").unwrap().unwrap();
    let units = spec.expand(1.0, None).unwrap();
    assert_eq!(units.len(), 18);
    // Canonical nesting: density (algo) outer, alpha inner — the legacy
    // table2 loop order.
    let alphas: Vec<f64> = units[..6].iter().map(|u| u.cfg.dirichlet_alpha).collect();
    assert_eq!(alphas, [0.1, 0.3, 0.5, 0.7, 0.9, 1.0]);
    assert!(units[..6].iter().all(|u| u.algo == "fedcomloc-com:none"));
    assert!(units[6..12].iter().all(|u| u.algo == "fedcomloc-com:topk:0.1"));
}
